"""Variant-batched tick latency vs the per-request serving path.

One pod tick's inference work — every stream's SRoI crops for the
variants it chose — executed two ways on the REAL Jax detector ladder
(CPU-reduced input sizes):

  * ``per_request`` — the pre-PR-2 pattern: one eager
    ``JaxDetectorBackend.infer_sroi`` forward per request;
  * ``batched``     — the pod path: requests grouped per variant and
    pushed through the shape-bucketed ``infer_srois_batched`` jitted
    forward (one dispatch per variant chunk).

``--devices D`` (PR 3) adds the multi-device axis: the variants
partition into per-variant replica groups (``repro.serving.placement``)
and every group's forward is launched (shard_map-sharded over the
group) before any result is resolved.  Two numbers come out of it:

  * ``sharded_us`` — measured wall time of the group-concurrent tick
    (on a real multi-accelerator host the groups overlap; forced host
    CPU devices share one threadpool, so treat it as a code-path
    exercise there);
  * ``tick_speedup`` — the device-aware latency model's tick
    throughput ratio (dispatch SUM on one device vs MAX over per-group
    sharded sums), the calibrated paper-regime metric every serving
    number in this repo uses, with per-group utilisation alongside.

``--pod-allocate`` (PR 4) instead measures the pod-level ALLOCATION
frontier: the same oracle pod served twice — per-stream (uncoupled)
knapsacks vs the capacity-enveloped fixed-point coupling
(``repro.serving.pod_allocation``) — recording the accuracy proxy
(mean allocator plan value per stream-frame) against the model-priced
mean tick inference latency.  Fully deterministic (oracle backend,
virtual device slots, calibrated latency model; no wall clock), so the
coupled-vs-uncoupled ratios are CI-gateable: at >= 8 streams the
coupled allocator must be strictly better on the accuracy proxy at
equal-or-lower tick latency.  Results merge into ``BENCH_SERVE.json``
under ``pod_grid`` without touching the wall-clock ``grid``.

``--open-loop`` (PR 6) measures the arrival-clocked OPEN-LOOP sweep:
the same oracle pod fed seeded open-loop traffic
(``repro.serving.traffic``) at a light and a saturated offered-load
point per stream count, served under admit-all vs SLO-aware admission
(``PodServer.run_open_loop``).  The gated metric is useful goodput —
within-SLO frames that did inference work — plus queueing delay, p99
E2E and shedding counts.  Deterministic (seeded arrival clocks, oracle
backend, calibrated model), so ``check_regression.py`` gates exactly:
SLO admission must strictly dominate admit-all at saturation and match
it under light load.  Results merge into ``BENCH_SERVE.json`` under
``open_grid``.

``--fleet`` (PR 8) measures the FLEET tier (``repro.serving.fleet``):
64-256 streams of the same seeded open-loop traffic served by 2-8
virtual pods behind each routing policy (sticky least-loaded vs
consistent-hash content affinity) against the single monolithic pod,
all on one fixed 8-slot device budget split per pod by
``serving_scale_plan``.  The monolith holds one replica group per
variant regardless of its width, so at saturation its pod-global
backlog sheds most arrivals; the fleet's independent per-pod group
chains keep useful goodput up.  Deterministic, so the gate is exact:
best-routing fleet >= mono everywhere, strictly better at >= 128
streams.  Results merge into ``BENCH_SERVE.json`` under
``fleet_grid``.

``--tasks mixed`` (PR 10) measures the MULTI-TASK pod sweep
(``repro.serving.tasks``): detection-only vs action-recognition-only
vs the alternating mixed pod at 8-32 streams, all under the coupled
allocator on one fixed device budget — ``solve_pod`` prices the two
variant ladders (single-frame detection vs tubelet clips) jointly in
one capacity envelope.  Deterministic, so the gate is exact: the
mixed pod's per-task accuracy proxies must each stay within a floor
fraction of the same task's single-task pod (no task collapses to
feed the other).  Results merge into ``BENCH_SERVE.json`` under
``task_grid``.

Sweeps stream counts and emits one CSV line per config plus
``BENCH_SERVE.json`` so future snapshots track the trajectory (the
nightly regression gate ``benchmarks/check_regression.py`` compares
the batched-vs-per-request ratio — and the pod-allocation accuracy
ratio — against the committed snapshot).  Warmup runs both paths
first so jit compiles (bounded by the bucket ladder) are not billed
to the measurement.

    PYTHONPATH=src:. python benchmarks/serving_bench.py --devices 8
    PYTHONPATH=src:. python benchmarks/serving_bench.py --pod-allocate
    PYTHONPATH=src:. python benchmarks/serving_bench.py --open-loop
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
import time

import numpy as np

SERVE_GRID = (1, 2, 4, 8, 16)   # streams per tick
SROIS_PER_STREAM = 2
SERVE_JSON_PATH = os.environ.get("BENCH_SERVE_JSON", "BENCH_SERVE.json")
# when set (env or --events-dir), every deterministic serving run also
# writes its structured JSONL telemetry log here
# (repro.serving.telemetry) — the nightly CI uploads the directory as
# an artifact next to the bench JSONs
EVENTS_DIR = os.environ.get("BENCH_EVENTS_DIR") or None

POD_GRID = (2, 4, 8, 16)        # streams for the pod-allocation frontier
POD_FRAMES = 12
POD_DEVICES = 8
POD_BUDGET_S = 1.8

POLICY_GRID = (2, 4, 8, 16)     # streams for the drain-policy frontier
POLICY_FRAMES = 12
# two per-variant replica groups: the async win shows both of its
# mechanisms — residual carry where the DEADLINE-AWARE vote allows it
# (low occupancy) and cross-group overlap where it does not (a single
# shared group at pod scale has a backlog >= any sane budget, so the
# deadline vote correctly refuses every carry there and async would
# degenerate to the sync barrier)
POLICY_DEVICES = 2
POLICIES = ("sync", "deadline", "async")

OPEN_GRID = (8, 16, 32)         # streams for the open-loop offered-load sweep
OPEN_DEVICES = POD_DEVICES
OPEN_SLO_S = 2.0
# 0.9s caps a frame's plan at one p5-896 forward (~0.66s), so a solo
# frame — and even a light-load pair collision — fits the 2.0s SLO;
# the saturated point then measures offered load, not plan size
OPEN_BUDGET_S = 0.9
OPEN_ADMISSIONS = ("admit-all", "slo")
# saturated: per-stream fps far beyond pod capacity, mild jitter
OPEN_SAT_FPS = 2.0
OPEN_SAT_JITTER = 0.1
OPEN_SAT_HORIZON_S = 40.0
# light: pod-wide offered rate held constant as streams grow (the pod's
# capacity does not scale with stream count), long horizon so every
# stream's predictor warms past its first empty-plan frames, jitter so
# equal-rate clocks don't collide at every emission
OPEN_LIGHT_POD_FPS = 0.6
OPEN_LIGHT_JITTER = 0.3
OPEN_LIGHT_HORIZON_S = 160.0

TASK_GRID = (8, 16, 32)         # streams for the multi-task pod sweep
TASK_FRAMES = 10
TASK_DEVICES = 8
TASK_BUDGET_S = 2.4
TASK_MODES = ("detection", "action", "mixed")

FLEET_GRID = (64, 128, 256)     # streams for the fleet-tier sweep
FLEET_PODS = (2, 4, 8)          # virtual pod counts vs the 1-pod monolith
FLEET_DEVICES = 8               # FLEET-WIDE device budget (fair split)
FLEET_ROUTINGS = ("least-loaded", "affinity")
FLEET_FPS = 0.5                 # per-stream rate: saturates the monolith
FLEET_JITTER = 0.1
FLEET_HORIZON_S = 24.0


def _make_backend(n_variants: int = 2):
    import jax

    from repro.models import detector as det_mod
    from repro.serving.batching import ShapeBuckets
    from repro.serving.scheduler import JaxDetectorBackend

    cfgs = [dataclasses.replace(det_mod.PAPER_LADDER[i],
                                input_size=64 if i == 0 else 96, n_classes=8)
            for i in range(n_variants)]
    params = [det_mod.init_params(jax.random.PRNGKey(i), c)
              for i, c in enumerate(cfgs)]
    sizes = tuple(sorted({c.input_size for c in cfgs}))
    return JaxDetectorBackend(cfgs, params, conf=0.01, use_kernel=False,
                              max_det=4,
                              buckets=ShapeBuckets((1, 2, 4, 8),
                                                   resolutions=sizes))


def _tick_requests(rng, n_streams, variants):
    """One tick's (variant, frame, region) work list: each stream
    contributes SROIS_PER_STREAM crops, variants assigned round-robin
    (the steady-state mix a pod sees)."""
    from repro.core import sroi as sroi_mod

    fov = (math.radians(60), math.radians(60))
    out = []
    for s in range(n_streams):
        frame = rng.random((64, 128, 3)).astype(np.float32)
        for k in range(SROIS_PER_STREAM):
            region = sroi_mod.SRoI(
                center=(float(rng.uniform(-2.5, 2.5)),
                        float(rng.uniform(-0.9, 0.9))), fov=fov)
            out.append((variants[(s + k) % len(variants)], frame, region))
    return out


def _tick_model_costs(by_variant, buckets, lat, placement=None):
    """Build one tick's dispatch schedule and price it on the model.

    Single device: every chunk serialises in one group (sum).  With a
    placement: chunks shard over their variant's replica group and
    groups run concurrently (max over per-group sums) — priced by
    ``OmniSenseLatencyModel.tick_schedule_delay``, the same curve the
    device-aware ``PodServer`` tick accounting uses.
    """
    schedule = []
    for name, items in sorted(by_variant.items()):
        v = items[0][0]
        group = placement.group_for(name) if placement is not None else None
        gidx = group.index if group is not None else 0
        n_dev = group.n_devices if group is not None else 1
        for b in buckets.split(len(items)):
            schedule.append((v, b, n_dev, gidx))
    return lat.tick_schedule_delay(schedule)


def run(csv=print, grid=SERVE_GRID, json_path=SERVE_JSON_PATH,
        devices: int = 1) -> dict:
    import jax

    from repro.serving import profiles
    from repro.serving.network import NetworkModel
    from repro.serving.scheduler import OmniSenseLatencyModel

    backend = _make_backend()
    variants = profiles.make_ladder(n_categories=8, seed=0)[:len(backend.cfgs)]
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    placement = None
    if devices > 1:
        from repro.serving.placement import VariantPlacement

        n_dev = len(jax.devices())
        if n_dev < devices:
            raise RuntimeError(
                f"{devices} devices requested but jax sees {n_dev}; on a "
                "CPU host force fake devices with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={devices} (the "
                "__main__ entry point sets this automatically)")
        placement = VariantPlacement(variants, devices=jax.devices()[:devices],
                                     cost_fn=lat._inf)
    rng = np.random.default_rng(0)

    # warmup: compile EVERY batch bucket per variant (the serving loop
    # pays these once per lifetime; the tick measurement must not)
    warm = _tick_requests(rng, max(grid), variants)
    for v in variants:
        items = [(f, r) for vv, f, r in warm if vv.name == v.name]
        for b in backend.buckets.batch_sizes:
            backend.infer_srois_batched(items[:b], v)
            if placement is not None:
                backend.infer_srois_batched(items[:b], v,
                                            group=placement.group_for(v.name))
        backend.infer_sroi(items[0][0], items[0][1], v)

    entries = []
    for n_streams in grid:
        work = _tick_requests(rng, n_streams, variants)
        repeats = 2 if n_streams <= 8 else 1

        t0 = time.perf_counter()
        for _ in range(repeats):
            for v, frame, region in work:
                backend.infer_sroi(frame, region, v)
        t_per_request = (time.perf_counter() - t0) / repeats * 1e6

        by_variant: dict[str, list] = {}
        for v, frame, region in work:
            by_variant.setdefault(v.name, []).append((v, frame, region))
        # one call per variant: infer_srois_batched applies the bucket
        # chunking itself, so the benchmark measures the real dispatch
        # schedule rather than re-implementing it
        dispatches = sum(len(backend.buckets.split(len(items)))
                         for items in by_variant.values())
        t0 = time.perf_counter()
        for _ in range(repeats):
            for name, items in sorted(by_variant.items()):
                backend.infer_srois_batched(
                    [(f, r) for _, f, r in items], items[0][0])
        t_batched = (time.perf_counter() - t0) / repeats * 1e6

        entry = dict(streams=n_streams,
                     requests=len(work),
                     variants=len(by_variant),
                     dispatches=dispatches,
                     per_request_us=round(t_per_request, 1),
                     batched_us=round(t_batched, 1),
                     speedup=round(t_per_request / max(t_batched, 1e-9), 2))
        if placement is not None:
            # group-concurrent tick: every group's sharded forward is
            # launched before any result is resolved
            t0 = time.perf_counter()
            for _ in range(repeats):
                resolvers = [
                    backend.launch_srois_batched(
                        [(f, r) for _, f, r in items], items[0][0],
                        placement.group_for(name))
                    for name, items in sorted(by_variant.items())]
                for resolve in resolvers:
                    resolve()
            t_sharded = (time.perf_counter() - t0) / repeats * 1e6
            single_tick, _ = _tick_model_costs(by_variant, backend.buckets,
                                               lat)
            sharded_tick, group_sums = _tick_model_costs(
                by_variant, backend.buckets, lat, placement)
            entry.update(
                sharded_us=round(t_sharded, 1),
                tick_model_single_s=round(single_tick, 4),
                tick_model_sharded_s=round(sharded_tick, 4),
                tick_speedup=round(single_tick / max(sharded_tick, 1e-9), 2),
                group_utilisation={
                    f"g{g}": round(s / max(sharded_tick, 1e-9), 3)
                    for g, s in sorted(group_sums.items())})
        entries.append(entry)
        csv(f"serving,tick_s{n_streams}_r{len(work)},us_per_tick_per_request,"
            f"{t_per_request:.0f},")
        csv(f"serving,tick_s{n_streams}_r{len(work)},us_per_tick_batched,"
            f"{t_batched:.0f},speedup={entry['speedup']}x "
            f"dispatches={dispatches}")
        if placement is not None:
            csv(f"serving,tick_s{n_streams}_r{len(work)},tick_speedup,"
                f"{entry['tick_speedup']},devices={devices} "
                f"util={entry['group_utilisation']}")

    out = {"bench": "variant_batched_serving",
           "backend": jax.default_backend(),
           "srois_per_stream": SROIS_PER_STREAM,
           "batch_buckets": list(backend.buckets.batch_sizes),
           "resolutions": list(backend.buckets.resolutions),
           "devices": devices,
           "grid": entries}
    if placement is not None:
        out["placement"] = placement.device_counts()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"serving,serve_json,path,0,{json_path}")
    return out


def _pod_variants():
    """The acceptance pod's ladder: p5-896 vs p6-1280 (distinct
    cost/accuracy, both edge-served, each on its own replica group)."""
    from repro.serving import profiles

    return profiles.make_ladder()[3:5]


def _policy_variants():
    """The drain-policy pod's ladder: yolo-tiny-416 vs yolo-p6-1280 —
    maximally spread in cost (0.002s on-device vs 1.12s edge), both
    heavily allocated under moderate budgets, AND the cheap one sorts
    LAST by name, so the sync policy's arbitrary sorted-variant drain
    order is pessimal and ordering/carry-over effects are visible."""
    from repro.serving import profiles

    ladder = profiles.make_ladder()
    return [ladder[0], ladder[4]]


def _events_sink(tag: str):
    """A JSONL telemetry sink under ``EVENTS_DIR`` (None when event
    logging is off)."""
    if EVENTS_DIR is None:
        return None
    from repro.serving.telemetry import JsonlSink

    os.makedirs(EVENTS_DIR, exist_ok=True)
    return JsonlSink(os.path.join(EVENTS_DIR, f"{tag}.jsonl"))


def _build_pod(n_streams: int, frames: int, devices: int,
               policy: str = "sync", pod_allocate: bool = False,
               variants=None, budget_fn=None, admission=None,
               telemetry=None):
    """One deterministic oracle pod (no wall clock in any metric).

    ``policy`` names a ``repro.serving.runtime`` drain policy;
    ``budget_fn(stream_idx)`` optionally spreads per-stream latency
    budgets (the deadline policy's ordering signal); ``admission``
    names the policy's admission hook (open-loop runs only).
    """
    from repro.core.omnisense import OmniSenseLoop
    from repro.data.synthetic import make_video
    from repro.serving.network import NetworkModel
    from repro.serving.placement import VariantPlacement
    from repro.serving.runtime import make_policy
    from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
    from repro.serving.server import PodServer
    from repro.serving import profiles

    variants = variants or _pod_variants()
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=frames + 8, n_objects=30 + 5 * (s % 4),
                           seed=100 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        budget = budget_fn(s) if budget_fn is not None else POD_BUDGET_S
        loops.append(OmniSenseLoop(variants, lat, backend,
                                   budget_s=budget,
                                   explore_costs=costs))
    placement = VariantPlacement.virtual(variants, devices, cost_fn=lat._inf)
    return PodServer(loops, backends, max_batch=8, placement=placement,
                     policy=make_policy(policy, pod_allocate=pod_allocate,
                                        admission=admission),
                     telemetry=telemetry)


def _pod_serve(n_streams: int, pod_allocate: bool, frames: int,
               devices: int, policy: str = "sync", variants=None,
               budget_fn=None, events_tag: str | None = None):
    telemetry = _events_sink(events_tag) if events_tag else None
    server = _build_pod(n_streams, frames, devices, policy=policy,
                        pod_allocate=pod_allocate, variants=variants,
                        budget_fn=budget_fn, telemetry=telemetry)
    stats = server.run(range(frames))
    if telemetry is not None:
        telemetry.close()
    return stats


def run_pod_allocation(csv=print, grid=POD_GRID, json_path=SERVE_JSON_PATH,
                       frames: int = POD_FRAMES,
                       devices: int = POD_DEVICES) -> dict:
    """The coupled-vs-uncoupled allocation frontier (``--pod-allocate``).

    Merges a ``pod_grid`` section into ``json_path`` WITHOUT touching
    the wall-clock ``grid`` section (the two measure different things:
    ``grid`` is measured dispatch time, ``pod_grid`` is the calibrated
    model's deterministic accuracy/tick frontier).
    """
    entries = []
    for n_streams in grid:
        base = _pod_serve(n_streams, False, frames, devices,
                          events_tag=f"pod_s{n_streams}_uncoupled")
        coup = _pod_serve(n_streams, True, frames, devices,
                          events_tag=f"pod_s{n_streams}_coupled")
        base_tick = base.sum_tick_inf_s / max(base.ticks, 1)
        coup_tick = coup.sum_tick_inf_s / max(coup.ticks, 1)
        entry = dict(
            streams=n_streams,
            frames=frames,
            accuracy_proxy_uncoupled=round(base.accuracy_proxy, 4),
            accuracy_proxy_coupled=round(coup.accuracy_proxy, 4),
            accuracy_ratio=round(coup.accuracy_proxy
                                 / max(base.accuracy_proxy, 1e-9), 4),
            tick_s_uncoupled=round(base_tick, 4),
            tick_s_coupled=round(coup_tick, 4),
            tick_ratio=round(coup_tick / max(base_tick, 1e-9), 4),
            rounds_per_tick=round(coup.pod_rounds
                                  / max(coup.pod_ticks, 1), 2),
            converged_ticks=f"{coup.pod_converged_ticks}/{coup.pod_ticks}",
        )
        entries.append(entry)
        csv(f"serving,pod_alloc_s{n_streams},accuracy_ratio,"
            f"{entry['accuracy_ratio']},tick_ratio={entry['tick_ratio']} "
            f"rounds={entry['rounds_per_tick']}")
    out = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            out = json.load(f)
    pod_variants = _pod_variants()
    out["pod_allocation"] = {
        "variants": [v.name for v in pod_variants],
        "devices": devices, "budget_s": POD_BUDGET_S, "frames": frames}
    out["pod_grid"] = entries
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"serving,pod_alloc_json,path,0,{json_path}")
    return out


def _policy_metrics(stats) -> dict:
    pct = stats.event_e2e_percentiles()
    e2e = stats.event_e2e or [0.0]
    return dict(
        mean_tick_s=round(stats.mean_tick, 4),
        mean_e2e_s=round(float(np.mean(e2e)), 4),
        p50_e2e_s=round(pct[50], 4),
        p95_e2e_s=round(pct[95], 4),
        p99_e2e_s=round(pct[99], 4),
        dispatches=stats.dispatches,
        carried_requests=stats.carried_requests,
        carry_tick_slots=stats.carry_tick_slots,
    )


def run_policy_grid(csv=print, grid=POLICY_GRID, json_path=SERVE_JSON_PATH,
                    frames: int = POLICY_FRAMES,
                    devices: int = POLICY_DEVICES) -> dict:
    """The drain-policy frontier (``--policy``): the same oracle pod
    served under every ``repro.serving.runtime`` policy.

    Per stream count and policy, records the event-clock mean tick and
    the per-frame E2E distribution (p50/p95/p99 of each frame's last
    dispatch completion minus its emission time).  Streams carry a
    spread of latency budgets (the deadline policy's ordering signal
    AND the deadline-aware carry vote's due dates) and the ladder
    pairs the cheapest variant with the most expensive
    (``_policy_variants``).  Fully deterministic — oracle backend,
    virtual device slots, calibrated latency model, no wall clock — so
    ``check_regression.py`` gates the async-vs-sync mean-tick ratio
    exactly: at >= 8 streams async drain must strictly undercut the
    sync barrier (via deadline-safe residual carry at low occupancy,
    cross-group overlap at pod scale).  Merges a ``policy_grid``
    section into ``json_path`` without touching ``grid``/``pod_grid``.
    """
    variants = _policy_variants()

    def budget_fn(s):  # deterministic per-stream deadline spread, loose
        # enough that low-occupancy residual carries pass the
        # deadline-aware vote (a tight spread would force every chunk
        # to dispatch immediately — by design)
        return 2.0 + 0.8 * (s % 3)

    entries = []
    for n_streams in grid:
        entry = dict(streams=n_streams, frames=frames)
        for policy in POLICIES:
            stats = _pod_serve(n_streams, False, frames, devices,
                               policy=policy, variants=variants,
                               budget_fn=budget_fn,
                               events_tag=f"policy_s{n_streams}_{policy}")
            entry[policy] = _policy_metrics(stats)
        entry["async_tick_ratio"] = round(
            entry["async"]["mean_tick_s"]
            / max(entry["sync"]["mean_tick_s"], 1e-9), 4)
        entries.append(entry)
        csv(f"serving,policy_s{n_streams},async_tick_ratio,"
            f"{entry['async_tick_ratio']},"
            f"sync_tick={entry['sync']['mean_tick_s']} "
            f"async_tick={entry['async']['mean_tick_s']} "
            f"deadline_p95={entry['deadline']['p95_e2e_s']} "
            f"sync_p95={entry['sync']['p95_e2e_s']}")
    out = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            out = json.load(f)
    out["policy_bench"] = {
        "variants": [v.name for v in variants],
        "devices": devices, "frames": frames,
        "budgets_s": sorted({budget_fn(s) for s in range(max(grid))}),
        "policies": list(POLICIES)}
    out["policy_grid"] = entries
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"serving,policy_json,path,0,{json_path}")
    return out


def _open_serve(n_streams: int, admission: str, fps: float, jitter: float,
                horizon_s: float, devices: int = OPEN_DEVICES,
                events_tag: str | None = None):
    """One open-loop run: arrival-clocked traffic into the oracle pod."""
    from repro.serving.traffic import ArrivalProcess

    frames = max(16, int(horizon_s * fps) + 8)
    telemetry = _events_sink(events_tag) if events_tag else None
    server = _build_pod(n_streams, frames, devices,
                        budget_fn=lambda s: OPEN_BUDGET_S,
                        admission=None if admission == "admit-all"
                        else admission, telemetry=telemetry)
    traffic = ArrivalProcess(n_streams, fps=fps, jitter=jitter, seed=0,
                             horizon_s=horizon_s)
    stats = server.run_open_loop(traffic, slo_s=OPEN_SLO_S)
    if telemetry is not None:
        telemetry.close()
    return stats


def _open_metrics(stats, horizon_s: float) -> dict:
    pct = stats.event_e2e_percentiles()
    return dict(
        arrivals=stats.arrivals,
        admitted=stats.admitted,
        degraded=stats.degraded,
        rejected=stats.rejected,
        missed=stats.missed,
        empty_frames=stats.empty_frames,
        slo_violations=stats.slo_violations,
        useful_goodput=stats.useful_goodput_frames,
        goodput_fps=round(stats.useful_goodput_frames / horizon_s, 4),
        mean_queue_delay_s=round(stats.mean_queue_delay, 4),
        p99_e2e_s=round(pct[99], 4),
    )


def run_open_grid(csv=print, grid=OPEN_GRID, json_path=SERVE_JSON_PATH,
                  devices: int = OPEN_DEVICES) -> dict:
    """The open-loop offered-load sweep (``--open-loop``): the same
    arrival-clocked traffic served under admit-all vs SLO-aware
    admission at every stream count, at a light and a saturated load
    point.

    The gated metric is USEFUL goodput — within-SLO frames that did
    inference work.  An admitted frame with an empty plan completes
    instantly (event E2E 0): under congestion collapse the starved
    predictor plans nothing for most frames, so raw goodput would
    REWARD admit-all for collapsing.  Fully deterministic (oracle
    backend, seeded arrival clocks, calibrated latency model — no wall
    clock), so ``check_regression.py`` gates exactly: at saturation
    SLO admission must strictly dominate admit-all on useful goodput;
    at light load it must match it while shedding nothing.  Merges an
    ``open_grid`` section into ``json_path`` without touching
    ``grid``/``pod_grid``/``policy_grid``.
    """
    points = (
        ("light", lambda n: OPEN_LIGHT_POD_FPS / n,
         OPEN_LIGHT_JITTER, OPEN_LIGHT_HORIZON_S),
        ("saturated", lambda n: OPEN_SAT_FPS,
         OPEN_SAT_JITTER, OPEN_SAT_HORIZON_S),
    )
    entries = []
    for n_streams in grid:
        for load, fps_fn, jitter, horizon_s in points:
            fps = fps_fn(n_streams)
            runs = {adm: _open_serve(
                        n_streams, adm, fps, jitter, horizon_s, devices,
                        events_tag=f"open_s{n_streams}_{load}_{adm}")
                    for adm in OPEN_ADMISSIONS}
            entry = dict(
                streams=n_streams, load=load,
                fps_per_stream=round(fps, 4),
                offered_fps=round(fps * n_streams, 4),
                jitter=jitter, horizon_s=horizon_s,
                admit_all=_open_metrics(runs["admit-all"], horizon_s),
                slo=_open_metrics(runs["slo"], horizon_s))
            entry["useful_goodput_ratio"] = round(
                entry["slo"]["useful_goodput"]
                / max(entry["admit_all"]["useful_goodput"], 1), 4)
            entries.append(entry)
            csv(f"serving,open_s{n_streams}_{load},useful_goodput_ratio,"
                f"{entry['useful_goodput_ratio']},"
                f"admit_all={entry['admit_all']['useful_goodput']} "
                f"slo={entry['slo']['useful_goodput']} "
                f"rejected={entry['slo']['rejected']} "
                f"p99={entry['slo']['p99_e2e_s']}")
    out = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            out = json.load(f)
    out["open_loop"] = {
        "variants": [v.name for v in _pod_variants()],
        "devices": devices, "budget_s": OPEN_BUDGET_S,
        "slo_s": OPEN_SLO_S, "admissions": list(OPEN_ADMISSIONS)}
    out["open_grid"] = entries
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"serving,open_json,path,0,{json_path}")
    return out


def _fleet_serve(n_streams: int, pods: int, routing: str,
                 events_tag: str | None = None):
    """One fleet run: the same seeded open-loop traffic served by a
    ``pods``-pod :class:`~repro.serving.fleet.FleetServer` over a
    FIXED ``FLEET_DEVICES`` budget (``serving_scale_plan`` splits the
    slots per pod, so 1 pod x 8 devices and 8 pods x 1 device spend
    the same hardware — the fair fleet-vs-monolith comparison)."""
    from repro.core.omnisense import OmniSenseLoop
    from repro.data.synthetic import make_video
    from repro.distributed.elastic import serving_scale_plan
    from repro.serving import profiles
    from repro.serving.fleet import FleetServer
    from repro.serving.network import NetworkModel
    from repro.serving.placement import VariantPlacement
    from repro.serving.runtime import make_policy
    from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
    from repro.serving.server import PodServer

    variants = _pod_variants()
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    frames = max(16, int(FLEET_HORIZON_S * FLEET_FPS) + 8)
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=frames + 8, n_objects=30 + 5 * (s % 4),
                           seed=100 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        loops.append(OmniSenseLoop(variants, lat, backend,
                                   budget_s=OPEN_BUDGET_S,
                                   explore_costs=costs))
    per_pod = serving_scale_plan(FLEET_DEVICES, pods)["per_pod_devices"]

    def make_pod(pod_id: int) -> PodServer:
        return PodServer(
            loops, backends, max_batch=8,
            placement=VariantPlacement.virtual(variants, per_pod,
                                               cost_fn=lat._inf),
            policy=make_policy("async", admission="slo"))

    telemetry = _events_sink(events_tag) if events_tag else None
    fleet = FleetServer(make_pod, pods, routing=routing,
                        telemetry=telemetry)
    from repro.serving.traffic import ArrivalProcess

    traffic = ArrivalProcess(n_streams, fps=FLEET_FPS, jitter=FLEET_JITTER,
                             seed=0, horizon_s=FLEET_HORIZON_S)
    stats = fleet.run_open_loop(traffic, slo_s=OPEN_SLO_S)
    if telemetry is not None:
        telemetry.close()
    return stats


def _fleet_metrics(stats) -> dict:
    out = _open_metrics(stats, FLEET_HORIZON_S)
    out.update(routes=stats.routes, migrations=stats.migrations)
    return out


def run_fleet_grid(csv=print, grid=FLEET_GRID, json_path=SERVE_JSON_PATH
                   ) -> dict:
    """The fleet-tier sweep (``--fleet``): 64-256 streams served by
    2-8 virtual pods behind each routing policy vs the single
    monolithic pod, all over the SAME ``FLEET_DEVICES``-slot budget.

    The monolith has only one replica group per variant no matter how
    many device slots it holds, so at saturation its pod-global
    backlog rejects most arrivals; a P-pod fleet runs P independent
    group chains per variant and keeps per-pod backlogs under the SLO
    envelope.  Fully deterministic (seeded arrival clocks, oracle
    backends, calibrated latency model — no wall clock), so
    ``check_regression.py`` gates exactly: at EVERY grid point the
    best-routing fleet useful goodput must be >= the monolith's, and
    STRICTLY greater at >= 128 streams.  Merges a ``fleet_grid``
    section into ``json_path`` without touching the other sections.
    """
    entries = []
    for n_streams in grid:
        mono = _fleet_metrics(_fleet_serve(
            n_streams, 1, "least-loaded",
            events_tag=f"fleet_s{n_streams}_mono"))
        for pods in FLEET_PODS:
            entry = dict(
                streams=n_streams, pods=pods,
                fps_per_stream=FLEET_FPS, jitter=FLEET_JITTER,
                horizon_s=FLEET_HORIZON_S, mono=mono)
            for routing in FLEET_ROUTINGS:
                key = routing.replace("-", "_")
                entry[key] = _fleet_metrics(_fleet_serve(
                    n_streams, pods, routing,
                    events_tag=f"fleet_s{n_streams}_p{pods}_{key}"))
            best = max(entry["least_loaded"]["useful_goodput"],
                       entry["affinity"]["useful_goodput"])
            entry["goodput_ratio"] = round(
                best / max(mono["useful_goodput"], 1), 4)
            entries.append(entry)
            csv(f"serving,fleet_s{n_streams}_p{pods},goodput_ratio,"
                f"{entry['goodput_ratio']},"
                f"mono={mono['useful_goodput']} "
                f"least_loaded={entry['least_loaded']['useful_goodput']} "
                f"affinity={entry['affinity']['useful_goodput']}")
    out = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            out = json.load(f)
    out["fleet"] = {
        "variants": [v.name for v in _pod_variants()],
        "devices": FLEET_DEVICES, "budget_s": OPEN_BUDGET_S,
        "slo_s": OPEN_SLO_S, "policy": "async", "admission": "slo",
        "pods": list(FLEET_PODS), "routings": list(FLEET_ROUTINGS)}
    out["fleet_grid"] = entries
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"serving,fleet_json,path,0,{json_path}")
    return out


def _task_serve(n_streams: int, mode: str,
                events_tag: str | None = None):
    """One deterministic multi-task pod run: ``mode`` names the task
    mix (``repro.serving.tasks.stream_tasks_for``), streams built
    through the registry, served closed-loop under the coupled
    pod-level allocator on ``TASK_DEVICES`` virtual slots."""
    from repro.data.synthetic import make_video
    from repro.serving import tasks as task_registry
    from repro.serving.placement import VariantPlacement
    from repro.serving.runtime import make_policy
    from repro.serving.server import PodServer

    stream_tasks = task_registry.stream_tasks_for(mode, n_streams)
    videos = [make_video(n_frames=TASK_FRAMES + 8,
                         n_objects=30 + 5 * (s % 4), seed=100 + s)
              for s in range(n_streams)]
    variants, loops, backends, cost_fn = task_registry.build_task_streams(
        stream_tasks, videos, [TASK_BUDGET_S] * n_streams)
    telemetry = _events_sink(events_tag) if events_tag else None
    server = PodServer(
        loops, backends, max_batch=8,
        placement=VariantPlacement.virtual(variants, TASK_DEVICES,
                                           cost_fn=cost_fn),
        policy=make_policy("sync", pod_allocate=True), telemetry=telemetry)
    stats = server.run(range(TASK_FRAMES))
    if telemetry is not None:
        telemetry.close()
    return stats


def _task_metrics(stats) -> dict:
    return dict(
        frames=stats.frames,
        accuracy_proxy=round(stats.accuracy_proxy, 4),
        frames_by_task=dict(sorted(stats.frames_by_task.items())),
        accuracy_proxy_by_task={
            t: round(p, 4)
            for t, p in stats.accuracy_proxy_by_task.items()},
        tick_s=round(stats.sum_tick_inf_s / max(stats.ticks, 1), 4),
        dispatches=stats.dispatches,
        rounds_per_tick=round(stats.pod_rounds / max(stats.pod_ticks, 1), 2),
        converged_ticks=f"{stats.pod_converged_ticks}/{stats.pod_ticks}",
    )


def run_task_grid(csv=print, grid=TASK_GRID,
                  json_path=SERVE_JSON_PATH) -> dict:
    """The multi-task pod sweep (``--tasks mixed``): detection-only vs
    action-only vs the alternating MIXED pod at every stream count, all
    on the same ``TASK_DEVICES``-slot budget under the coupled
    allocator (``solve_pod`` pricing both variant ladders jointly in
    one capacity envelope).

    The gated property is NO COLLAPSE: the mixed pod's per-task
    accuracy proxy must stay within a floor fraction of the same
    task's single-task pod at the same stream count — the joint
    allocator may trade capacity across the heterogeneous ladders but
    must not starve either task to feed the other.  Fully
    deterministic (oracle backends, virtual slots, calibrated latency
    models — no wall clock), so ``check_regression.py`` gates exactly.
    Merges a ``task_grid`` section into ``json_path`` without touching
    the other sections.
    """
    from repro.serving import tasks as task_registry

    entries = []
    for n_streams in grid:
        runs = {mode: _task_serve(n_streams, mode,
                                  events_tag=f"task_s{n_streams}_{mode}")
                for mode in TASK_MODES}
        entry = dict(streams=n_streams, frames=TASK_FRAMES,
                     **{mode: _task_metrics(runs[mode])
                        for mode in TASK_MODES})
        mixed = entry["mixed"]["accuracy_proxy_by_task"]
        entry["mixed_detection_ratio"] = round(
            mixed.get("detection", 0.0)
            / max(entry["detection"]["accuracy_proxy"], 1e-9), 4)
        entry["mixed_action_ratio"] = round(
            mixed.get("action_recognition", 0.0)
            / max(entry["action"]["accuracy_proxy"], 1e-9), 4)
        entries.append(entry)
        csv(f"serving,task_s{n_streams}_mixed,mixed_detection_ratio,"
            f"{entry['mixed_detection_ratio']},"
            f"action_ratio={entry['mixed_action_ratio']} "
            f"det_only={entry['detection']['accuracy_proxy']} "
            f"act_only={entry['action']['accuracy_proxy']} "
            f"mixed={mixed}")
    out = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            out = json.load(f)
    out["tasks"] = {
        "modes": list(TASK_MODES),
        "detection_variants": [
            v.name for v in task_registry.get_task("detection").make_ladder()],
        "action_variants": [
            v.name for v in
            task_registry.get_task("action_recognition").make_ladder()],
        "devices": TASK_DEVICES, "budget_s": TASK_BUDGET_S,
        "frames": TASK_FRAMES, "policy": "sync", "pod_allocate": True}
    out["task_grid"] = entries
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"serving,task_json,path,0,{json_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=0,
                    help="shard per-variant forwards over replica groups "
                         "cut from this many devices (default: 1 for the "
                         f"wall-clock grid, {POD_DEVICES} virtual slots "
                         "for --pod-allocate)")
    ap.add_argument("--pod-allocate", action="store_true",
                    help="measure the pod-level allocation frontier "
                         "(coupled vs uncoupled knapsacks) instead of the "
                         "wall-clock dispatch grid; merges a pod_grid "
                         "section into the JSON (virtual device slots — no "
                         "jax devices needed)")
    ap.add_argument("--policy", choices=POLICIES, default=None,
                    help="measure the drain-policy frontier instead: the "
                         "oracle pod under EVERY runtime policy (the named "
                         "one is just the headline), recording per-policy "
                         "mean tick + E2E percentiles into a policy_grid "
                         "section (virtual device slots — no jax devices "
                         "needed)")
    ap.add_argument("--open-loop", action="store_true",
                    help="measure the open-loop offered-load sweep instead: "
                         "arrival-clocked traffic (light + saturated points "
                         "per stream count) under admit-all vs SLO-aware "
                         "admission, recording useful-goodput/queueing/"
                         "shedding into an open_grid section (virtual "
                         "device slots — no jax devices needed)")
    ap.add_argument("--fleet", action="store_true",
                    help="measure the fleet-tier sweep instead: 64-256 "
                         "streams over 2-8 virtual pods (both routing "
                         "policies) vs the single monolithic pod on the "
                         "same fixed device budget, recording useful-"
                         "goodput/shedding/routing into a fleet_grid "
                         "section (virtual device slots — no jax devices "
                         "needed)")
    ap.add_argument("--tasks", choices=("mixed",), default=None,
                    help="measure the multi-task pod sweep instead: "
                         "detection-only vs action-only vs the mixed "
                         "pod (repro.serving.tasks registry) under the "
                         "coupled allocator on one device budget, "
                         "recording per-task accuracy proxies into a "
                         "task_grid section (virtual device slots — no "
                         "jax devices needed)")
    ap.add_argument("--json", default=SERVE_JSON_PATH)
    ap.add_argument("--events-dir", default=None, metavar="DIR",
                    help="also write one JSONL telemetry event log per "
                         "deterministic serving run under DIR "
                         "(default: $BENCH_EVENTS_DIR; the nightly CI "
                         "uploads these next to the bench JSONs)")
    args = ap.parse_args()
    if args.events_dir:
        global EVENTS_DIR
        EVENTS_DIR = args.events_dir
    if args.tasks:
        run_task_grid(json_path=args.json)
        return
    if args.fleet:
        run_fleet_grid(json_path=args.json)
        return
    if args.open_loop:
        run_open_grid(json_path=args.json,
                      devices=args.devices or OPEN_DEVICES)
        return
    if args.policy:
        # the grid always measures all policies — a lone async number
        # could not show dominance over sync
        run_policy_grid(json_path=args.json,
                        devices=args.devices or POLICY_DEVICES)
        return
    if args.pod_allocate:
        # 0 is the "not given" sentinel, so an explicit --devices 1
        # really does measure the single-group pod frontier
        run_pod_allocation(json_path=args.json,
                           devices=args.devices or POD_DEVICES)
        return
    if args.devices > 1 and "jax" not in sys.modules:
        # must happen before the first jax import anywhere in-process
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    run(devices=args.devices or 1, json_path=args.json)


if __name__ == "__main__":
    main()

"""Roofline report generator: dry-run JSONs -> EXPERIMENTS.md tables.

Reads ``experiments/dryrun/*.json`` and emits the section Roofline table
(three terms per cell, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS) plus
the hillclimb-candidate ranking.
"""

from __future__ import annotations

import json
import pathlib
import sys


def load(out_dir="experiments/dryrun", mesh="singlepod"):
    rows = []
    for p in sorted(pathlib.Path(out_dir).glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        rows.append(rec)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(rows, csv=print):
    csv("| arch | shape | kind | compute | memory | collective | dominant "
        "| useful | frac | note |")
    csv("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if "skipped" in r:
            csv(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — "
                f"| {r['skipped'].split(':')[0]} |")
            continue
        rf = r["roofline"]
        note = ""
        temp_gb = r["memory"]["temp_size_in_bytes"] / 1e9
        if temp_gb > 16:
            note = f"temp {temp_gb:.0f}GB/dev >16GB!"
        csv(f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | {rf['dominant'].replace('_s','')} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} | {note} |")


def candidates(rows, csv=print):
    live = [r for r in rows if "skipped" not in r and r["kind"] == "train"]
    live_all = [r for r in rows if "skipped" not in r]
    by_frac = sorted(live, key=lambda r: r["roofline"]["roofline_fraction"])
    by_coll = sorted(live_all,
                     key=lambda r: -r["roofline"]["collective_s"])
    csv("\nworst roofline fraction (train cells):")
    for r in by_frac[:5]:
        csv(f"  {r['arch']}/{r['shape']}: frac={r['roofline']['roofline_fraction']:.4f} "
            f"dom={r['roofline']['dominant']}")
    csv("most collective-bound:")
    for r in by_coll[:5]:
        csv(f"  {r['arch']}/{r['shape']}: coll={fmt_s(r['roofline']['collective_s'])} "
            f"(vs compute {fmt_s(r['roofline']['compute_s'])})")


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "singlepod"
    rows = load(mesh=mesh)
    table(rows)
    candidates(rows)


if __name__ == "__main__":
    main()

"""Paper Fig. 9 — sensitivity to image compression quality and bandwidth.

9a: PNG (lossless) vs JPEG quality 100/75/50/25.  Lossy compression
shrinks the wire payload (latency saved -> model upgrades) but degrades
every model's accuracy; moderate compression should WIN over lossless
and aggressive compression should LOSE (the paper's finding).

9b: uplink 8.95 / 17.9 / 35.8 / 71.6 Mbps at a fixed budget: accuracy
should rise with bandwidth and saturate once delivery stops being the
bottleneck.
"""

from __future__ import annotations

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.evaluation import sph_map
from repro.serving.network import NetworkModel
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend

N_FRAMES = 30
BUDGET = 1.6

# accuracy penalty of feeding models JPEG-degraded inputs (paper: mild
# until quality < ~75, then steep)
QUALITY_PENALTY = {"png": 1.0, "jpg-100": 0.995, "jpg-75": 0.97,
                   "jpg-50": 0.92, "jpg-25": 0.82}


def _run(video, variants, costs, bandwidth_mbps: float):
    lat = OmniSenseLatencyModel(costs, NetworkModel(bandwidth_mbps))
    backend = OracleBackend(video)
    loop = OmniSenseLoop(variants, lat, backend, budget_s=BUDGET)
    preds, e2e = [], []
    frames = range(N_FRAMES)
    for f in frames:
        backend.set_frame(f)
        res = loop.process_frame(None)
        preds.extend((f, d) for d in res.detections)
        e2e.append(res.planned_latency)
    gts = [(f, d) for f in frames for d in video.visible_objects(f)]
    return sph_map(preds, gts), float(np.mean(e2e))


def run(csv=print) -> dict:
    video = make_video(n_frames=N_FRAMES + 4, n_objects=60, seed=3)
    out = {"9a": {}, "9b": {}}

    for tag, penalty in QUALITY_PENALTY.items():
        if tag == "png":
            costs = profiles.paper_profile()
        else:
            costs = profiles.jpeg_profile(int(tag.split("-")[1]))
        variants = profiles.make_ladder(quality_penalty=penalty)
        acc, t = _run(video, variants, costs, 17.9)
        out["9a"][tag] = (acc, t)
        csv(f"fig9a,{tag},sph_map,{acc:.4f},{t:.3f}")

    variants = profiles.make_ladder()
    for bw in (8.95, 17.9, 35.8, 71.6):
        acc, t = _run(video, variants, profiles.paper_profile(), bw)
        out["9b"][bw] = (acc, t)
        csv(f"fig9b,{bw}Mbps,sph_map,{acc:.4f},{t:.3f}")
    return out


def main():
    return run()


if __name__ == "__main__":
    main()

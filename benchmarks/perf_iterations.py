"""Reproduces the EXPERIMENTS.md section-Perf hillclimb measurements.

Each entry re-lowers one hillclimb variant on the production mesh and
prints its roofline terms.  Run with:

    PYTHONPATH=src python -m benchmarks.perf_iterations [cell]

cells: granite_base granite_sp granite_sp_flashproj qwen3_base qwen3_sp
       qwen3_a2a convnext_base convnext_group
(default: all — takes a few minutes of compile time)
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import cells as cm  # noqa: E402
from repro.launch import mesh as mm  # noqa: E402
from repro.launch.dryrun import roofline_terms  # noqa: E402
from repro.launch.hloanalysis import analyze  # noqa: E402
from repro.training import optimizer as opt_mod  # noqa: E402
from repro.training import steps as steps_mod  # noqa: E402


def _measure(step, cell, mesh, in_specs=None, chips=None, ctx=None,
             subtract_pattern=None):
    import contextlib

    c2 = cm.Cell(cell.arch_id, cell.shape_name, cell.kind, step or cell.step,
                 cell.abstract_args, in_specs or cell.in_specs,
                 cell.model_flops)
    with mesh, (ctx or contextlib.nullcontext()):
        compiled = jax.jit(c2.step, in_shardings=c2.in_shardings(mesh)) \
            .lower(*cell.abstract_args).compile()
        mem = compiled.memory_analysis()
    a = analyze(compiled.as_text(), detail=subtract_pattern is not None)
    hbm = a["hbm_bytes"]
    if subtract_pattern is not None:
        pat = re.compile(subtract_pattern)
        hbm -= sum(f for f, d in a["top_bytes"] if pat.search(d))
    n = chips or 256
    rec = {"hlo_flops": a["flops"] * n, "hlo_bytes": hbm * n,
           "collective_bytes": a["collective_bytes"] * n, "devices": n,
           "model_flops": cell.model_flops}
    r = roofline_terms(rec, chips=n)
    return r, mem.temp_size_in_bytes / 1e9


def _lm_variant(arch_mod, arch_id, shape, sp):
    cell = cm.build_cell(arch_id, shape)
    cfg = dataclasses.replace(arch_mod.full_config(), sequence_parallel=sp)
    step = steps_mod.lm_train_step(cfg, opt_mod.adamw(1e-4))
    return cell, step


def run(which="all", csv=print):
    mesh = mm.make_production_mesh()
    import repro.configs.granite_34b as g
    import repro.configs.qwen3_moe_235b_a22b as q
    import repro.configs.convnext_b as cb
    from repro.models import vision as V

    def report(tag, r, temp):
        csv(f"perf,{tag},compute_s,{r['compute_s']:.3f},")
        csv(f"perf,{tag},memory_s,{r['memory_s']:.3f},")
        csv(f"perf,{tag},collective_s,{r['collective_s']:.3f},")
        csv(f"perf,{tag},roofline_fraction,{r['roofline_fraction']:.4f},"
            f"temp={temp:.1f}GB")

    if which in ("all", "granite_base"):
        cell, step = _lm_variant(g, "granite_34b", "train_4k", sp=False)
        report("granite_base", *_measure(step, cell, mesh))
    if which in ("all", "granite_sp"):
        cell, step = _lm_variant(g, "granite_34b", "train_4k", sp=True)
        report("granite_sp", *_measure(step, cell, mesh))
    if which in ("all", "granite_sp_flashproj"):
        cell, step = _lm_variant(g, "granite_34b", "train_4k", sp=True)
        report("granite_sp_flashproj", *_measure(
            step, cell, mesh, subtract_pattern=r"\[16,3,4096,1024\]"))
    if which in ("all", "qwen3_base"):
        cell, step = _lm_variant(q, "qwen3_moe_235b_a22b", "train_4k", sp=False)
        report("qwen3_base", *_measure(step, cell, mesh))
    if which in ("all", "qwen3_sp"):
        cell, step = _lm_variant(q, "qwen3_moe_235b_a22b", "train_4k", sp=True)
        report("qwen3_sp_bf16combine", *_measure(step, cell, mesh))
    if which in ("all", "qwen3_a2a"):
        cell = cm.build_cell("qwen3_moe_235b_a22b", "train_4k")
        cfg = dataclasses.replace(q.full_config(), sequence_parallel=True,
                                  moe_a2a=True)
        step = steps_mod.lm_train_step(cfg, opt_mod.adamw(1e-4))
        report("qwen3_sp_a2a_moe", *_measure(step, cell, mesh))
    if which in ("all", "convnext_base"):
        cell = cm.build_cell("convnext_b", "serve_b128")
        report("convnext_base", *_measure(None, cell, mesh))
    if which in ("all", "convnext_group"):
        cell = cm.build_cell("convnext_b", "serve_b128")
        params_abs = cm._eval_params(
            lambda: V.convnext_init(jax.random.PRNGKey(0), cb.full_config()))
        param_specs = jax.tree.map(lambda _: P(), params_abs)
        group = jax.make_mesh((16, 1), ("data", "model"),
                              devices=jax.devices()[:16])
        report("convnext_replica_group16", *_measure(
            None, cell, group, in_specs=(param_specs, cell.in_specs[1]),
            chips=16, ctx=shd.no_activation_constraints()))


def main():
    run(sys.argv[1] if len(sys.argv) > 1 else "all")


if __name__ == "__main__":
    main()

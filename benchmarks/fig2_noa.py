"""Paper Fig. 2/3 — NOA distribution of the synthetic dataset.

Checks that the generated scenes reproduce the measurement findings the
system design rests on: tiny median NOA, multi-decade spread, and the
per-category size variation of Fig. 3.
"""

from __future__ import annotations

import numpy as np

from repro.data.synthetic import make_video, noa_histogram


def run(csv=print) -> dict:
    out = {}
    for name, kw in [("drive", dict(seed=3, n_objects=120)),
                     ("walk", dict(seed=11, n_objects=80))]:
        video = make_video(name=name, n_frames=40, **kw)
        noas = noa_histogram(video, range(0, 40, 5))
        qs = np.quantile(noas, [0.1, 0.5, 0.9])
        decades = float(np.log10(noas.max() / noas.min()))
        out[name] = {"q10": qs[0], "median": qs[1], "q90": qs[2],
                     "decades": decades}
        csv(f"fig2,{name},median_noa,{qs[1]:.2e},decades={decades:.1f}")
        # Fig. 3: per-category spread
        by_cat = {}
        for f in range(0, 40, 5):
            for d in video.visible_objects(f):
                by_cat.setdefault(d.category, []).append(d.noa())
        spreads = [np.log10(max(v) / min(v)) for v in by_cat.values()
                   if len(v) > 3 and min(v) > 0]
        if spreads:
            csv(f"fig3,{name},max_category_spread_decades,"
                f"{max(spreads):.1f},")
    return out


def main():
    return run()


if __name__ == "__main__":
    main()

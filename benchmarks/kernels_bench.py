"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU —
numbers establish per-call overhead shape, not TPU throughput; the TPU
roofline story lives in EXPERIMENTS.md section Perf) and the pure-jnp
reference paths that actually execute on this host.
"""

from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, repeats=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def run(csv=print) -> dict:
    from repro.core import projection
    from repro.core.sphere import sph_iou_matrix
    from repro.kernels.sphiou.ops import sphiou_matrix

    out = {}
    rng = np.random.default_rng(0)

    # gnomonic jnp path (the production CPU path; kernel is TPU-target)
    erp = jnp.asarray(rng.random((512, 1024, 3)).astype(np.float32))
    fov = (math.radians(60), math.radians(60))
    t = _time(lambda e: projection.project_sroi(
        e, jnp.asarray(0.3), jnp.asarray(0.1), fov, (416, 416)), erp)
    out["gnomonic_jnp_416"] = t
    csv(f"kernels,gnomonic_jnp_416,us_per_call,{t:.0f},512x1024->416x416")

    # sphiou: jnp oracle vs pallas-interpret
    boxes = jnp.asarray(np.stack([
        rng.uniform(-3, 3, 256), rng.uniform(-1.2, 1.2, 256),
        rng.uniform(0.1, 1.0, 256), rng.uniform(0.1, 1.0, 256)],
        axis=-1).astype(np.float32))
    t_ref = _time(lambda b: sph_iou_matrix(b, b), boxes)
    out["sphiou_jnp_256"] = t_ref
    csv(f"kernels,sphiou_jnp_256x256,us_per_call,{t_ref:.0f},")
    t_k = _time(lambda b: sphiou_matrix(b, b), boxes)
    out["sphiou_pallas_interp_256"] = t_k
    csv(f"kernels,sphiou_pallas_interpret_256x256,us_per_call,{t_k:.0f},"
        "interpret-mode (correctness harness)")

    # attention: chunked jnp (production fallback) per 1k tokens
    from repro.kernels.attention.ops import flash_attention_ref

    q = jnp.asarray(rng.standard_normal((1, 256, 8, 64)).astype(np.float32))
    t_att = _time(lambda q: flash_attention_ref(q, q, q, causal=True), q)
    out["attention_ref_256"] = t_att
    csv(f"kernels,attention_ref_b1s256h8d64,us_per_call,{t_att:.0f},")
    return out


def main():
    return run()


if __name__ == "__main__":
    main()

"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU —
numbers establish per-call overhead shape, not TPU throughput; the TPU
roofline story lives in EXPERIMENTS.md section Perf) and the pure-jnp
reference paths that actually execute on this host.
"""

from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# NMS bench sweep: rows-per-tick x boxes-per-row (pod-scale shapes)
NMS_GRID = [(b, n) for n in (64, 512, 4096) for b in (1, 32)]
# cap per-config host-loop probe rows so N=4096 stays minutes, not hours
_NMS_HOST_PROBE_ELEMS = 1 << 26
NMS_JSON_PATH = os.environ.get("BENCH_NMS_JSON", "BENCH_NMS.json")

# fused-tick sweep: crops per tick through the real detector backend
FUSED_TICK_BS = (1, 4, 8)
# bf16 SphIoU keep-mask flip envelope (measured ~0.1% on random box
# sets; the regression gate holds the line at 1%)
BF16_FLIP_BOUND = 0.01
BF16_NEAR_MARGIN = 0.05


def _time(fn, *args, repeats=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def run(csv=print) -> dict:
    from repro.core import projection
    from repro.core.sphere import sph_iou_matrix
    from repro.kernels.sphiou.ops import sphiou_matrix

    out = {}
    rng = np.random.default_rng(0)

    # gnomonic jnp path (the production CPU path; kernel is TPU-target)
    erp = jnp.asarray(rng.random((512, 1024, 3)).astype(np.float32))
    fov = (math.radians(60), math.radians(60))
    t = _time(lambda e: projection.project_sroi(
        e, jnp.asarray(0.3), jnp.asarray(0.1), fov, (416, 416)), erp)
    out["gnomonic_jnp_416"] = t
    csv(f"kernels,gnomonic_jnp_416,us_per_call,{t:.0f},512x1024->416x416")

    # sphiou: jnp oracle vs pallas-interpret
    boxes = jnp.asarray(np.stack([
        rng.uniform(-3, 3, 256), rng.uniform(-1.2, 1.2, 256),
        rng.uniform(0.1, 1.0, 256), rng.uniform(0.1, 1.0, 256)],
        axis=-1).astype(np.float32))
    t_ref = _time(lambda b: sph_iou_matrix(b, b), boxes)
    out["sphiou_jnp_256"] = t_ref
    csv(f"kernels,sphiou_jnp_256x256,us_per_call,{t_ref:.0f},")
    t_k = _time(lambda b: sphiou_matrix(b, b), boxes)
    out["sphiou_pallas_interp_256"] = t_k
    csv(f"kernels,sphiou_pallas_interpret_256x256,us_per_call,{t_k:.0f},"
        "interpret-mode (correctness harness)")

    # attention: chunked jnp (production fallback) per 1k tokens
    from repro.kernels.attention.ops import flash_attention_ref

    q = jnp.asarray(rng.standard_normal((1, 256, 8, 64)).astype(np.float32))
    t_att = _time(lambda q: flash_attention_ref(q, q, q, causal=True), q)
    out["attention_ref_256"] = t_att
    csv(f"kernels,attention_ref_b1s256h8d64,us_per_call,{t_att:.0f},")
    return out


def nms_bench(csv=print, grid=None, json_path=NMS_JSON_PATH,
              fused=True) -> dict:
    """Per-stream host greedy NMS vs the batched subsystem.

    Emits one CSV line per (B, N) plus a JSON file so future
    ``BENCH_*.json`` snapshots can track the trajectory.  The host
    baseline is the pre-refactor serving pattern — one
    ``sph_nms_host`` call per stream — while the batched column is one
    ``sph_nms_batch`` dispatch for the whole tick.  For configs whose
    IoU tensor exceeds the probe cap the host loop is measured on a row
    subset and extrapolated (recorded in the ``derived`` column — no
    silent truncation).
    """
    from repro.core.sphere import sph_nms_batch, sph_nms_host

    # TPU: the batched Pallas kernel; CPU: the XLA-compiled jnp IoU
    # (Pallas-interpret is a correctness harness, not a fast path)
    batched_backend = "device" if jax.default_backend() == "tpu" else "jit"
    rng = np.random.default_rng(0)
    entries = []
    for b, n in (grid or NMS_GRID):
        boxes = np.stack([
            rng.uniform(-math.pi, math.pi, (b, n)),
            rng.uniform(-1.2, 1.2, (b, n)),
            rng.uniform(0.05, 0.6, (b, n)),
            rng.uniform(0.05, 0.6, (b, n))], axis=-1).astype(np.float32)
        scores = rng.uniform(0, 1, (b, n)).astype(np.float32)
        repeats = 3 if n <= 512 else 1

        keep_batch = sph_nms_batch(boxes, scores,
                                   backend=batched_backend)  # warm/compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            sph_nms_batch(boxes, scores, backend=batched_backend)
        t_batch = (time.perf_counter() - t0) / repeats * 1e6

        probe_rows = max(1, min(b, _NMS_HOST_PROBE_ELEMS // max(n * n, 1)))
        sph_nms_host(boxes[0], scores[0])  # warm numpy/backend init
        t0 = time.perf_counter()
        for _ in range(repeats):
            for r in range(probe_rows):
                sph_nms_host(boxes[r], scores[r])
        t_host = (time.perf_counter() - t0) / repeats * 1e6 * (b / probe_rows)
        derived = ("" if probe_rows == b
                   else f"extrapolated_from_{probe_rows}_rows")

        entry = dict(b=b, n=n, host_us=round(t_host, 1),
                     batch_us=round(t_batch, 1),
                     speedup=round(t_host / max(t_batch, 1e-9), 2),
                     host_probe_rows=probe_rows,
                     survivors=int(keep_batch.sum()))
        entries.append(entry)
        csv(f"kernels,nms_b{b}_n{n},us_per_tick_host,{t_host:.0f},{derived}")
        csv(f"kernels,nms_b{b}_n{n},us_per_tick_batched,{t_batch:.0f},"
            f"speedup={entry['speedup']}x")

    out = {"bench": "spherical_nms", "backend": jax.default_backend(),
           "batched_backend": batched_backend, "grid": entries}
    if fused:
        # the fused-tick grid and bf16 flip measurement ride in the
        # same snapshot so check_regression's armed gate sees them
        out.update(fused_tick_bench(csv))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"kernels,nms_json,path,0,{json_path}")
    return out


def _dets_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    for row_a, row_b in zip(a, b):
        if len(row_a) != len(row_b):
            return False
        for da, db in zip(row_a, row_b):
            if (da.category != db.category or da.score != db.score
                    or not np.array_equal(np.asarray(da.box),
                                          np.asarray(db.box))):
                return False
    return True


def fused_tick_bench(csv=print, bs=FUSED_TICK_BS) -> dict:
    """Staged vs fused detector tick (PR 9) + bf16 flip measurement.

    The staged path is the pre-fused serving pattern — one gnomonic
    projection dispatch per crop, host ``stack``, per-detection
    back-projection — while the fused path is one batched projection
    program, the cross-tick crop cache, and one back-projection call
    per row.  Ticks repeat with identical region geometry, so the
    fused columns are the STEADY-STATE cost (cache-warm: the regime a
    tracking viewport lives in); ``bit_identical`` asserts the f32
    fused output equals the staged output bitwise.

    Two granularities per (B,): the full tick (``staged_us`` /
    ``fused_us``), where on CPU the detector forward dominates both
    paths, and the projection stage alone (``staged_project_us`` /
    ``fused_project_us``), which is exactly what the fused path
    changed and where the dispatch savings are wall-clock-robust —
    the regression gate holds the STRICT line on the stage and a
    no-regress band on the tick.  The ``bf16`` section measures the
    keep-mask flip rate of the reduced-precision SphIoU against the
    f32 NMS on the same box sets, which the gate bounds.
    """
    import dataclasses

    from repro.core import sphere
    from repro.core.sroi import SRoI
    from repro.models import detector as det_mod
    from repro.serving import profiles
    from repro.serving.batching import ShapeBuckets
    from repro.serving.scheduler import JaxDetectorBackend

    cfg = dataclasses.replace(det_mod.PAPER_LADDER[0], input_size=64,
                              n_classes=8)
    params = det_mod.init_params(jax.random.PRNGKey(0), cfg)
    variant = profiles.make_ladder(seed=0)[0]
    rng = np.random.default_rng(0)
    frame = rng.random((64, 128, 3)).astype(np.float32)
    fov = (math.radians(60), math.radians(60))

    def make_backend(fused):
        return JaxDetectorBackend(
            [cfg], [params], conf=0.01, use_kernel=False, max_det=4,
            fused=fused,
            buckets=ShapeBuckets((1, 2, 4, 8), resolutions=(64,)))

    entries = []
    for b in bs:
        items = [(frame, SRoI(center=(float(rng.uniform(-2.5, 2.5)),
                                      float(rng.uniform(-0.9, 0.9))),
                              fov=fov)) for _ in range(b)]
        fused_be, staged_be = make_backend(True), make_backend(False)
        out_f = fused_be.infer_srois_batched(items, variant)  # compile
        out_s = staged_be.infer_srois_batched(items, variant)
        bit = _dets_equal(out_f, out_s)
        repeats = 3
        t0 = time.perf_counter()
        for _ in range(repeats):
            staged_be.infer_srois_batched(items, variant)
        t_staged = (time.perf_counter() - t0) / repeats * 1e6
        t0 = time.perf_counter()
        for _ in range(repeats):
            fused_be.infer_srois_batched(items, variant)
        t_fused = (time.perf_counter() - t0) / repeats * 1e6

        # projection stage alone: the per-crop dispatch loop + host
        # stack vs the single batched program (cache-warm)
        size = cfg.input_size
        stage_reps = 10
        t0 = time.perf_counter()
        for _ in range(stage_reps):
            jax.block_until_ready(jnp.stack(
                [staged_be._project(f, r, size) for f, r in items]))
        t_sp = (time.perf_counter() - t0) / stage_reps * 1e6
        t0 = time.perf_counter()
        for _ in range(stage_reps):
            jax.block_until_ready(fused_be._project_chunk(items, size)[0])
        t_fp = (time.perf_counter() - t0) / stage_reps * 1e6

        entry = dict(b=b, staged_us=round(t_staged, 1),
                     fused_us=round(t_fused, 1),
                     speedup=round(t_staged / max(t_fused, 1e-9), 2),
                     staged_project_us=round(t_sp, 1),
                     fused_project_us=round(t_fp, 1),
                     project_speedup=round(t_sp / max(t_fp, 1e-9), 2),
                     bit_identical=bit,
                     cache_hits=fused_be.crop_cache_hits)
        entries.append(entry)
        csv(f"kernels,fused_tick_b{b},us_per_tick_staged,{t_staged:.0f},")
        csv(f"kernels,fused_tick_b{b},us_per_tick_fused,{t_fused:.0f},"
            f"speedup={entry['speedup']}x bit_identical={bit}")
        csv(f"kernels,fused_tick_b{b},us_per_project_staged,{t_sp:.0f},")
        csv(f"kernels,fused_tick_b{b},us_per_project_fused,{t_fp:.0f},"
            f"speedup={entry['project_speedup']}x cache-warm")

    # bf16 keep-mask flips vs the f32 NMS on the same random box sets;
    # rows with no IoU pair near the threshold must never flip
    flips = total = far_flips = far_rows = 0
    for trial in range(10):
        trng = np.random.default_rng(trial)
        bb, n = 8, 24
        boxes = np.stack([trng.uniform(-3, 3, (bb, n)),
                          trng.uniform(-1.2, 1.2, (bb, n)),
                          trng.uniform(0.3, 1.2, (bb, n)),
                          trng.uniform(0.3, 1.2, (bb, n))],
                         -1).astype(np.float32)
        scores = trng.uniform(0.1, 1, (bb, n)).astype(np.float32)
        k32 = sphere.sph_nms_batch(boxes, scores, backend="jit")
        k16 = sphere.sph_nms_batch(boxes, scores, backend="jit",
                                   iou_dtype=jnp.bfloat16)
        diff = np.asarray(k32) != np.asarray(k16)
        flips += int(diff.sum())
        total += int(diff.size)
        iou = np.stack([sphere.sph_iou_matrix_np(
            boxes[i].astype(np.float64), boxes[i].astype(np.float64))
            for i in range(bb)])
        near = np.abs(iou - 0.6) <= BF16_NEAR_MARGIN
        np.einsum("bii->bi", near)[:] = False
        far = ~near.any(axis=(1, 2))
        far_rows += int(far.sum())
        far_flips += int((diff.any(axis=1) & far).sum())
    bf16 = dict(flip_rate=round(flips / max(total, 1), 5), flips=flips,
                entries=total, far_row_flips=far_flips, far_rows=far_rows,
                near_margin=BF16_NEAR_MARGIN, bound=BF16_FLIP_BOUND)
    csv(f"kernels,bf16_sphiou,keep_flip_rate,{bf16['flip_rate']},"
        f"bound={BF16_FLIP_BOUND} far_row_flips={far_flips}/{far_rows}")
    return {"fused_grid": entries, "bf16": bf16}


def main():
    out = run()
    out["nms"] = nms_bench()
    return out


if __name__ == "__main__":
    main()

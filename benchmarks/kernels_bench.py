"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU —
numbers establish per-call overhead shape, not TPU throughput; the TPU
roofline story lives in EXPERIMENTS.md section Perf) and the pure-jnp
reference paths that actually execute on this host.
"""

from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# NMS bench sweep: rows-per-tick x boxes-per-row (pod-scale shapes)
NMS_GRID = [(b, n) for n in (64, 512, 4096) for b in (1, 32)]
# cap per-config host-loop probe rows so N=4096 stays minutes, not hours
_NMS_HOST_PROBE_ELEMS = 1 << 26
NMS_JSON_PATH = os.environ.get("BENCH_NMS_JSON", "BENCH_NMS.json")


def _time(fn, *args, repeats=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def run(csv=print) -> dict:
    from repro.core import projection
    from repro.core.sphere import sph_iou_matrix
    from repro.kernels.sphiou.ops import sphiou_matrix

    out = {}
    rng = np.random.default_rng(0)

    # gnomonic jnp path (the production CPU path; kernel is TPU-target)
    erp = jnp.asarray(rng.random((512, 1024, 3)).astype(np.float32))
    fov = (math.radians(60), math.radians(60))
    t = _time(lambda e: projection.project_sroi(
        e, jnp.asarray(0.3), jnp.asarray(0.1), fov, (416, 416)), erp)
    out["gnomonic_jnp_416"] = t
    csv(f"kernels,gnomonic_jnp_416,us_per_call,{t:.0f},512x1024->416x416")

    # sphiou: jnp oracle vs pallas-interpret
    boxes = jnp.asarray(np.stack([
        rng.uniform(-3, 3, 256), rng.uniform(-1.2, 1.2, 256),
        rng.uniform(0.1, 1.0, 256), rng.uniform(0.1, 1.0, 256)],
        axis=-1).astype(np.float32))
    t_ref = _time(lambda b: sph_iou_matrix(b, b), boxes)
    out["sphiou_jnp_256"] = t_ref
    csv(f"kernels,sphiou_jnp_256x256,us_per_call,{t_ref:.0f},")
    t_k = _time(lambda b: sphiou_matrix(b, b), boxes)
    out["sphiou_pallas_interp_256"] = t_k
    csv(f"kernels,sphiou_pallas_interpret_256x256,us_per_call,{t_k:.0f},"
        "interpret-mode (correctness harness)")

    # attention: chunked jnp (production fallback) per 1k tokens
    from repro.kernels.attention.ops import flash_attention_ref

    q = jnp.asarray(rng.standard_normal((1, 256, 8, 64)).astype(np.float32))
    t_att = _time(lambda q: flash_attention_ref(q, q, q, causal=True), q)
    out["attention_ref_256"] = t_att
    csv(f"kernels,attention_ref_b1s256h8d64,us_per_call,{t_att:.0f},")
    return out


def nms_bench(csv=print, grid=None, json_path=NMS_JSON_PATH) -> dict:
    """Per-stream host greedy NMS vs the batched subsystem.

    Emits one CSV line per (B, N) plus a JSON file so future
    ``BENCH_*.json`` snapshots can track the trajectory.  The host
    baseline is the pre-refactor serving pattern — one
    ``sph_nms_host`` call per stream — while the batched column is one
    ``sph_nms_batch`` dispatch for the whole tick.  For configs whose
    IoU tensor exceeds the probe cap the host loop is measured on a row
    subset and extrapolated (recorded in the ``derived`` column — no
    silent truncation).
    """
    from repro.core.sphere import sph_nms_batch, sph_nms_host

    # TPU: the batched Pallas kernel; CPU: the XLA-compiled jnp IoU
    # (Pallas-interpret is a correctness harness, not a fast path)
    batched_backend = "device" if jax.default_backend() == "tpu" else "jit"
    rng = np.random.default_rng(0)
    entries = []
    for b, n in (grid or NMS_GRID):
        boxes = np.stack([
            rng.uniform(-math.pi, math.pi, (b, n)),
            rng.uniform(-1.2, 1.2, (b, n)),
            rng.uniform(0.05, 0.6, (b, n)),
            rng.uniform(0.05, 0.6, (b, n))], axis=-1).astype(np.float32)
        scores = rng.uniform(0, 1, (b, n)).astype(np.float32)
        repeats = 3 if n <= 512 else 1

        keep_batch = sph_nms_batch(boxes, scores,
                                   backend=batched_backend)  # warm/compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            sph_nms_batch(boxes, scores, backend=batched_backend)
        t_batch = (time.perf_counter() - t0) / repeats * 1e6

        probe_rows = max(1, min(b, _NMS_HOST_PROBE_ELEMS // max(n * n, 1)))
        sph_nms_host(boxes[0], scores[0])  # warm numpy/backend init
        t0 = time.perf_counter()
        for _ in range(repeats):
            for r in range(probe_rows):
                sph_nms_host(boxes[r], scores[r])
        t_host = (time.perf_counter() - t0) / repeats * 1e6 * (b / probe_rows)
        derived = ("" if probe_rows == b
                   else f"extrapolated_from_{probe_rows}_rows")

        entry = dict(b=b, n=n, host_us=round(t_host, 1),
                     batch_us=round(t_batch, 1),
                     speedup=round(t_host / max(t_batch, 1e-9), 2),
                     host_probe_rows=probe_rows,
                     survivors=int(keep_batch.sum()))
        entries.append(entry)
        csv(f"kernels,nms_b{b}_n{n},us_per_tick_host,{t_host:.0f},{derived}")
        csv(f"kernels,nms_b{b}_n{n},us_per_tick_batched,{t_batch:.0f},"
            f"speedup={entry['speedup']}x")

    out = {"bench": "spherical_nms", "backend": jax.default_backend(),
           "batched_backend": batched_backend, "grid": entries}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        csv(f"kernels,nms_json,path,0,{json_path}")
    return out


def main():
    out = run()
    out["nms"] = nms_bench()
    return out


if __name__ == "__main__":
    main()

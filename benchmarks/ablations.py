"""Component ablations of OmniSense (beyond-paper analysis).

Quantifies each design element's contribution at a fixed budget by
disabling one at a time:

  * ``no_discovery``   — spherical object discovery off (paper argues
    the history-only loop enters a vicious circle; this measures it);
  * ``no_pipelining``  — the allocator plans with SERIAL latencies
    (d_pre + d_inf sequential per SRoI), i.e. paper Fig. 6 disabled;
  * ``content_blind``  — the gav.ccv estimation replaced by each
    model's mean accuracy (no content awareness: the allocator still
    budgets, but cannot match models to region content);
  * ``no_special``     — oversized objects are not given special SRoIs
    (they are simply dropped from prediction).

    PYTHONPATH=src:. python -m benchmarks.ablations
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import sroi as sroi_mod
from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.evaluation import sph_map
from repro.serving.network import NetworkModel
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend

N_FRAMES = 30
BUDGET = 1.8


class SerialLatencyModel(OmniSenseLatencyModel):
    """Moves all cost into d_pre so the DP's pipelining recurrence
    degenerates to the serial sum (ablates paper Fig. 6)."""

    def delays(self, srois, variants):
        d_pre, d_inf = super().delays(srois, variants)
        return d_pre + d_inf, np.zeros_like(d_inf)


def _content_blind(loop: OmniSenseLoop):
    def blind_matrix(srois):
        m, r = len(loop.variants), len(srois)
        out = np.zeros((1 + m, r))
        for j, s in enumerate(srois):
            for i, var in enumerate(loop.variants):
                out[1 + i, j] = s.alpha * float(np.mean(var.gav))
        return out

    loop._weighted_acc_matrix = blind_matrix
    return loop


def _run(loop, backend, video, frames):
    preds = []
    for f in frames:
        backend.set_frame(f)
        res = loop.process_frame(None)
        preds.extend((f, d) for d in res.detections)
    gts = [(f, d) for f in frames for d in video.visible_objects(f)]
    return sph_map(preds, gts)


def run(csv=print) -> dict:
    video = make_video(n_frames=N_FRAMES + 4, n_objects=50, seed=3)
    frames = range(N_FRAMES)
    variants = profiles.make_ladder()
    out = {}

    def fresh(latency_cls=OmniSenseLatencyModel, **loop_kw):
        lat = latency_cls(profiles.paper_profile(), NetworkModel())
        backend = OracleBackend(video)
        costs = [lat._pre(v) + lat._inf(v) for v in variants]
        kw = dict(budget_s=BUDGET, explore_costs=costs)
        kw.update(loop_kw)
        return OmniSenseLoop(variants, lat, backend, **kw), backend

    loop, backend = fresh()
    out["full"] = _run(loop, backend, video, frames)

    loop, backend = fresh(explore_every=0)
    loop._discovery.patience = 10 ** 9  # discovery fully off
    out["no_discovery"] = _run(loop, backend, video, frames)

    loop, backend = fresh(latency_cls=SerialLatencyModel)
    out["no_pipelining"] = _run(loop, backend, video, frames)

    loop, backend = fresh()
    out["content_blind"] = _run(_content_blind(loop), backend, video, frames)

    # no_special: strip oversized objects before prediction
    loop, backend = fresh()
    orig = sroi_mod.predict_srois

    def no_special(history, **kw):
        f = kw.get("f", math.radians(60.0))
        kept = [o for o in history if o.fov[0] <= f and o.fov[1] <= f]
        return orig(kept, **kw)

    sroi_mod.predict_srois = no_special
    try:
        import repro.core.omnisense as om
        om.sroi.predict_srois = no_special
        out["no_special"] = _run(loop, backend, video, frames)
    finally:
        sroi_mod.predict_srois = orig
        om.sroi.predict_srois = orig

    for k, v in out.items():
        delta = "" if k == "full" else \
            f"{100 * (v - out['full']) / max(out['full'], 1e-9):+.1f}% vs full"
        csv(f"ablation,{k},sph_map,{v:.4f},{delta}")
    return out


def main():
    return run()


if __name__ == "__main__":
    main()

"""Paper Fig. 7 — overall accuracy/latency of OmniSense vs baselines.

For each video: ERP-i and CubeMap-i (i = 1..5) sweep the fixed-model
baselines; OmniSense runs at the paper's representative budgets
T_e4 (95% of ERP-4's E2E), T_c2, T_c3, T_c4 (95% of CubeMap-2/3/4).

Validated claims:
  * at matched latency, OmniSense's Sph-mAP exceeds the comparable
    baseline's (paper: +19.8% .. +114.6% relative);
  * OmniSense reaches the best baseline accuracy at a fraction of its
    latency (paper: 2.0x - 2.4x speedup).
"""

from __future__ import annotations

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import baselines, profiles
from repro.serving.evaluation import sph_map
from repro.serving.network import NetworkModel
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend

VIDEOS = [
    ("synthetic-drive", dict(seed=3, n_objects=60, yaw_rate_deg=1.2)),
    ("synthetic-walk", dict(seed=11, n_objects=40, yaw_rate_deg=0.4)),
]
N_FRAMES = 36


def _fresh(video):
    variants = profiles.make_ladder(seed=0)
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    backend = OracleBackend(video)
    return variants, lat, backend


def run_omnisense(video, budget_s: float, frames: range):
    variants, lat, backend = _fresh(video)
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    loop = OmniSenseLoop(variants, lat, backend, budget_s=budget_s,
                         explore_costs=costs)
    preds = []
    e2e = []
    overheads = []
    for f in frames:
        backend.set_frame(f)
        res = loop.process_frame(None)
        preds.extend((f, d) for d in res.detections)
        e2e.append(max(res.planned_latency, res.overhead_s))
        overheads.append(res.overhead_s)
    return preds, float(np.mean(e2e)), float(np.mean(overheads))


def run(csv=print) -> dict:
    results = {}
    for name, kw in VIDEOS:
        video = make_video(name=name, n_frames=N_FRAMES + 4, **kw)
        frames = range(N_FRAMES)
        gts = [(f, d) for f in frames for d in video.visible_objects(f)]

        rows = {}
        for i in range(5):
            variants, lat, backend = _fresh(video)
            p, t = baselines.run_erp_baseline(video, backend, lat,
                                              variants[i], frames)
            rows[f"erp-{i + 1}"] = (sph_map(p, gts), t)
            variants, lat, backend = _fresh(video)
            p, t = baselines.run_cubemap_baseline(video, backend, lat,
                                                  variants[i], frames)
            rows[f"cubemap-{i + 1}"] = (sph_map(p, gts), t)

        budgets = {
            "T_e4": 0.95 * rows["erp-4"][1],
            "T_c2": 0.95 * rows["cubemap-2"][1],
            "T_c3": 0.95 * rows["cubemap-3"][1],
            "T_c4": 0.95 * rows["cubemap-4"][1],
            # speedup probe: can half the best baseline's latency match
            # its accuracy? (the paper's 2.0x-2.4x claim)
            "half_c5": 0.5 * rows["cubemap-5"][1],
        }
        for tag, budget in budgets.items():
            p, t, ovh = run_omnisense(video, budget, frames)
            rows[f"omnisense-{tag}"] = (sph_map(p, gts), t, ovh)

        results[name] = rows
        for k, v in rows.items():
            csv(f"fig7,{name},{k},{v[0]:.4f},{v[1]:.3f}")
    return results


def derived_claims(results: dict, csv=print) -> None:
    """The two headline claims, per video."""
    for name, rows in results.items():
        # claim 1: matched-latency accuracy gain vs the comparable baseline
        pairs = [("omnisense-T_c2", "cubemap-2"), ("omnisense-T_c3", "cubemap-3"),
                 ("omnisense-T_c4", "cubemap-4"), ("omnisense-T_e4", "erp-4")]
        gains = []
        for ours, base in pairs:
            if rows[base][0] > 0:
                gains.append((rows[ours][0] - rows[base][0]) / rows[base][0])
        csv(f"fig7-claim1,{name},accuracy_gain_pct,"
            f"{100 * min(gains):.1f},{100 * max(gains):.1f}")
        # claim 2: speedup at >= (near-)best-baseline accuracy
        best_acc = max(v[0] for k, v in rows.items()
                       if k.startswith(("erp", "cubemap")))
        best_lat = max(v[1] for k, v in rows.items()
                       if k.startswith(("erp", "cubemap")) and v[0] >= 0.95 * best_acc)
        ours = [(k, v) for k, v in rows.items() if k.startswith("omnisense")
                and v[0] >= 0.95 * best_acc]
        if ours:
            fastest = min(v[1] for _, v in ours)
            csv(f"fig7-claim2,{name},speedup_at_matched_accuracy,"
                f"{best_lat / fastest:.2f},x")
        else:
            # report the closest budget's accuracy fraction for honesty
            cand = max((v for k, v in rows.items()
                        if k.startswith("omnisense")), key=lambda v: v[0])
            csv(f"fig7-claim2,{name},speedup_at_matched_accuracy,n/a,"
                f"best_ours={cand[0]:.3f}@{cand[1]:.2f}s_vs_{best_acc:.3f}@{best_lat:.2f}s")


def main():
    results = run()
    derived_claims(results)
    return results


if __name__ == "__main__":
    main()

"""Benchmark entrypoint: one function per paper table/figure.

Prints ``name,key,metric,value,derived`` CSV lines.  Figures covered:
  * Fig. 2/3  — NOA distributions of the dataset (measurement study)
  * Fig. 7    — OmniSense vs ERP/CubeMap accuracy & latency + claims
  * Fig. 8    — mobile-side system overhead breakdown
  * Fig. 9a/b — compression-quality and bandwidth sensitivity
  * kernels   — per-kernel microbenchmarks
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import ablations, fig2_noa, fig7_overall, \
        fig8_overhead, fig9_sensitivity, kernels_bench, serving_bench

    print("table,key,metric,value,derived")
    fig2_noa.run()
    results = fig7_overall.run()
    fig7_overall.derived_claims(results)
    fig8_overhead.run()
    fig9_sensitivity.run()
    ablations.run()
    kernels_bench.run()
    kernels_bench.nms_bench()
    serving_bench.run()


if __name__ == "__main__":
    main()

"""Paper Fig. 8 — system overhead on the mobile device.

Breakdown of SRoI prediction + model allocation + post-processing time
as a fraction of mean E2E latency.  The paper reports <2.5% for the
busier video and <1% for the calmer one; we assert the same order.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import allocation, sroi
from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import sph_nms_host
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend


def run(csv=print) -> dict:
    out = {}
    for name, n_obj in [("busy-drive", 80), ("calm-walk", 20)]:
        video = make_video(name=name, n_frames=40, n_objects=n_obj, seed=5)
        variants = profiles.make_ladder()
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        backend = OracleBackend(video)
        loop = OmniSenseLoop(variants, lat, backend, budget_s=2.0)

        pred_t, alloc_t, post_t, e2e = [], [], [], []
        for f in range(32):
            backend.set_frame(f)
            # instrument the stages separately
            t0 = time.perf_counter()
            srois = sroi.predict_srois(loop._flat_history(), f=loop.f,
                                       gamma=loop.gamma)
            t1 = time.perf_counter()
            if srois:
                acc = loop._weighted_acc_matrix(srois)
                d_pre, d_inf = lat.delays(srois, variants)
                allocation.allocate(acc, d_pre, d_inf, loop.budget_s)
            t2 = time.perf_counter()
            res = loop.process_frame(None)
            dets = res.detections
            t3 = time.perf_counter()
            if dets:
                boxes = np.stack([d.box for d in dets])
                scores = np.array([d.score for d in dets])
                sph_nms_host(boxes, scores)
            t4 = time.perf_counter()
            pred_t.append(t1 - t0)
            alloc_t.append(t2 - t1)
            post_t.append(t4 - t3)
            e2e.append(max(res.planned_latency, 1e-3))
        total_overhead = np.mean(pred_t) + np.mean(alloc_t) + np.mean(post_t)
        frac = total_overhead / np.mean(e2e)
        out[name] = {
            "sroi_prediction_ms": 1e3 * float(np.mean(pred_t)),
            "allocation_ms": 1e3 * float(np.mean(alloc_t)),
            "postprocess_ms": 1e3 * float(np.mean(post_t)),
            "overhead_fraction": float(frac),
        }
        csv(f"fig8,{name},sroi_ms,{out[name]['sroi_prediction_ms']:.3f},")
        csv(f"fig8,{name},alloc_ms,{out[name]['allocation_ms']:.3f},")
        csv(f"fig8,{name},post_ms,{out[name]['postprocess_ms']:.3f},")
        csv(f"fig8,{name},overhead_fraction,{100 * frac:.2f},%")
    return out


def main():
    return run()


if __name__ == "__main__":
    main()

"""Nightly bench-regression gate for the serving benchmark.

Compares a freshly measured ``BENCH_SERVE.json`` against the snapshot
committed in the repo and FAILS (exit 1) when the batched-vs-per-request
speedup has regressed by more than ``--max-regression`` (default 25%).

Grid entries match on stream count; the gate compares the MEAN ratio
over matching entries so a single noisy CI tick doesn't flap the job,
and ignores entries present on only one side (grid growth is not a
regression).  Wall-clock noise moves both paths of a ratio together,
which is why the ratio — not raw microseconds — is gated.

    python benchmarks/check_regression.py \
        --baseline BENCH_SERVE.json --fresh fresh_serve.json

Invoked from .github/workflows/ci.yml's nightly job after the bench
writes the fresh snapshot next to the checked-out baseline.
"""

from __future__ import annotations

import argparse
import json
import sys


def compare(baseline: dict, fresh: dict, max_regression: float,
            key: str = "speedup", log=print) -> bool:
    """True when ``fresh`` holds the line vs ``baseline``."""
    base = {e["streams"]: e[key] for e in baseline.get("grid", [])
            if key in e}
    new = {e["streams"]: e[key] for e in fresh.get("grid", [])
           if key in e}
    common = sorted(set(base) & set(new))
    if not common:
        log(f"check_regression: no comparable grid entries for {key!r}")
        return False
    base_mean = sum(base[s] for s in common) / len(common)
    new_mean = sum(new[s] for s in common) / len(common)
    floor = base_mean * (1.0 - max_regression)
    for s in common:
        log(f"  streams={s:>3}  baseline {key}={base[s]:.2f}  "
            f"fresh {key}={new[s]:.2f}")
    log(f"check_regression: mean {key} baseline={base_mean:.2f} "
        f"fresh={new_mean:.2f} floor={floor:.2f} "
        f"(max regression {max_regression:.0%})")
    if new_mean < floor:
        log(f"::error::serving {key} regressed: {new_mean:.2f} < "
            f"{floor:.2f} ({base_mean:.2f} baseline - {max_regression:.0%})")
        return False
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_SERVE.json",
                    help="committed snapshot (the repo checkout's copy)")
    ap.add_argument("--fresh", required=True,
                    help="just-measured snapshot to gate")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="tolerated relative drop of the mean ratio")
    ap.add_argument("--key", default="speedup",
                    help="grid metric to gate (batched-vs-per-request "
                         "ratio by default)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    ok = compare(baseline, fresh, args.max_regression, key=args.key)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

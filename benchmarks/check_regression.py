"""Nightly bench-regression gate for the serving benchmark.

Compares a freshly measured ``BENCH_SERVE.json`` against the snapshot
committed in the repo and FAILS (exit 1) when the batched-vs-per-request
speedup has regressed by more than ``--max-regression`` (default 25%).

Grid entries match on stream count; the gate compares the MEAN ratio
over matching entries so a single noisy CI tick doesn't flap the job,
and ignores entries present on only one side (grid growth is not a
regression).  Wall-clock noise moves both paths of a ratio together,
which is why the ratio — not raw microseconds — is gated.

When both snapshots carry a ``pod_grid`` section (PR 4,
``serving_bench.py --pod-allocate``) the coupled-vs-uncoupled
accuracy-proxy ratio is gated the same way, PLUS a hard dominance
floor: at >= ``--pod-min-streams`` streams the coupled allocator must
stay strictly better on the accuracy proxy at equal-or-lower tick
latency (the pod-allocation acceptance invariant; deterministic, so it
is gated exactly rather than within a noise band).

When the snapshots carry a ``policy_grid`` section (PR 5,
``serving_bench.py --policy``), the drain-policy dominance floor is
gated too: at >= ``--pod-min-streams`` streams the async-drain policy's
mean event-clock tick must STRICTLY undercut the sync barrier's
(deterministic oracle pod, gated exactly).

When the snapshots carry an ``open_grid`` section (PR 6,
``serving_bench.py --open-loop``), the open-loop admission floor is
gated: SLO-aware admission must STRICTLY dominate admit-all on useful
goodput at every saturated point and match it — shedding nothing — at
every light point (deterministic seeded traffic, gated exactly).

When the snapshots carry a ``fleet_grid`` section (PR 8,
``serving_bench.py --fleet``), the fleet-tier dominance floor is
gated: on the same fixed device budget the best-routing fleet must
match the monolithic pod's useful goodput at every grid point and
STRICTLY beat it at >= 128 streams (deterministic, gated exactly),
and (PR 9, tightened to exact in PR 10) no routing arm's p99 E2E may
exceed the sweep's SLO envelope (see ``fleet_p99_within_slo``).

When the snapshots carry a ``task_grid`` section (PR 10,
``serving_bench.py --tasks mixed``), the multi-task no-collapse floor
is gated: the mixed pod's per-task accuracy proxies must each stay
within a floor fraction of the same task served alone at the same
stream count, and both tasks must finish frames (deterministic, gated
exactly) — the coupled allocator may trade capacity across the two
ladders but must not starve either task (see ``mixed_no_collapse``).

BENCH_NMS.json (PR 9) additionally carries the fused-tick grid and
the bf16 SphIoU flip measurement; the schema REQUIRES both (the
committed snapshot has them, so a fresh one without means the bench
vanished — the NMS lane is schema-only, with no baseline to diff),
and ``--schema-only`` also enforces the fused acceptance floor:
f32 bit-identity, strict projection-stage win at B >= 8, a
no-regress band on the full tick, and the bf16 keep-mask flip
bound.

Both snapshots are validated against an EXPLICIT schema first
(required keys per grid section, per nested policy/admission arm), so
a malformed snapshot fails with a named error instead of a KeyError
traceback; ``--schema-only PATH...`` runs just that validation (the
nightly's BENCH_NMS.json check, which has no ratio gate):

    python benchmarks/check_regression.py \
        --baseline BENCH_SERVE.json --fresh fresh_serve.json
    python benchmarks/check_regression.py --schema-only BENCH_NMS.json

Invoked from .github/workflows/ci.yml's nightly job after the bench
writes the fresh snapshot next to the checked-out baseline.
"""

from __future__ import annotations

import argparse
import json
import sys

# explicit snapshot schemas: per section, the required keys of every
# grid entry plus the required keys of each nested per-arm dict.  The
# gates below index these keys directly; validating HERE turns a
# malformed snapshot (bench crashed mid-merge, grid renamed, arm
# dropped) into a named error instead of a bare KeyError traceback.
SERVE_SCHEMAS: dict[str, tuple[frozenset, dict[str, frozenset]]] = {
    "grid": (frozenset({"streams", "per_request_us", "batched_us",
                        "speedup"}), {}),
    "pod_grid": (frozenset({"streams", "accuracy_ratio", "tick_ratio"}),
                 {}),
    "policy_grid": (frozenset({"streams", "async_tick_ratio"}),
                    {"sync": frozenset({"mean_tick_s"}),
                     "deadline": frozenset({"mean_tick_s"}),
                     "async": frozenset({"mean_tick_s"})}),
    "open_grid": (frozenset({"streams", "load"}),
                  {"admit_all": frozenset({"useful_goodput", "rejected"}),
                   "slo": frozenset({"useful_goodput", "rejected"})}),
    "fleet_grid": (frozenset({"streams", "pods", "goodput_ratio"}),
                   {"mono": frozenset({"useful_goodput", "rejected",
                                       "p99_e2e_s"}),
                    "least_loaded": frozenset({"useful_goodput",
                                               "rejected", "routes",
                                               "p99_e2e_s"}),
                    "affinity": frozenset({"useful_goodput", "rejected",
                                           "routes", "p99_e2e_s"})}),
    "task_grid": (frozenset({"streams", "mixed_detection_ratio",
                             "mixed_action_ratio"}),
                  {"detection": frozenset({"accuracy_proxy",
                                           "accuracy_proxy_by_task",
                                           "frames_by_task"}),
                   "action": frozenset({"accuracy_proxy",
                                        "accuracy_proxy_by_task",
                                        "frames_by_task"}),
                   "mixed": frozenset({"accuracy_proxy",
                                       "accuracy_proxy_by_task",
                                       "frames_by_task"})}),
}

NMS_ENTRY_KEYS = frozenset({"b", "n", "host_us", "batch_us", "speedup"})
NMS_FUSED_KEYS = frozenset({"b", "staged_us", "fused_us", "speedup",
                            "staged_project_us", "fused_project_us",
                            "project_speedup", "bit_identical"})
NMS_BF16_KEYS = frozenset({"flip_rate", "flips", "entries",
                           "far_row_flips", "far_rows", "bound"})


def _check_entry(entry, required: frozenset, where: str, log) -> bool:
    if not isinstance(entry, dict):
        log(f"::error::{where}: grid entry is {type(entry).__name__}, "
            "not an object")
        return False
    missing = required - entry.keys()
    if missing:
        log(f"::error::{where}: entry missing required keys "
            f"{sorted(missing)} (has {sorted(entry)})")
        return False
    return True


def validate_serve(snapshot: dict, label: str, log=print) -> bool:
    """Validate a BENCH_SERVE.json snapshot against the explicit
    per-grid schemas; True when every PRESENT section conforms (absent
    sections are the armed-baseline checks' concern, not a schema
    error)."""
    ok = True
    present = [s for s in SERVE_SCHEMAS if snapshot.get(s)]
    if not present:
        log(f"::error::{label}: no known grid sections "
            f"({sorted(SERVE_SCHEMAS)}) in snapshot")
        return False
    for section in present:
        required, subs = SERVE_SCHEMAS[section]
        entries = snapshot[section]
        if not isinstance(entries, list):
            log(f"::error::{label}: {section} is "
                f"{type(entries).__name__}, not a list")
            ok = False
            continue
        for i, entry in enumerate(entries):
            where = f"{label}: {section}[{i}]"
            if not _check_entry(entry, required, where, log):
                ok = False
                continue
            for arm, arm_keys in subs.items():
                if arm not in entry:
                    log(f"::error::{where}: missing the {arm!r} arm "
                        f"(policy/admission run absent from the merge)")
                    ok = False
                elif not _check_entry(entry[arm], arm_keys,
                                      f"{where}.{arm}", log):
                    ok = False
    if ok:
        log(f"schema ok [{label}]: " + ", ".join(
            f"{s}({len(snapshot[s])})" for s in present))
    return ok


def validate_nms(snapshot: dict, label: str, log=print) -> bool:
    """Validate a BENCH_NMS.json snapshot.

    Besides the per-entry key checks, the ``fused_grid`` and ``bf16``
    sections (PR 9, the fused-tick bench) are REQUIRED: the committed
    snapshot carries them, so a fresh snapshot without them means the
    fused-tick bench silently vanished from the nightly — the
    schema-only NMS lane has no baseline to diff against, so the
    armed-gate check lives here instead.
    """
    entries = snapshot.get("grid")
    if not isinstance(entries, list) or not entries:
        log(f"::error::{label}: NMS snapshot has no grid entries")
        return False
    ok = all(_check_entry(e, NMS_ENTRY_KEYS, f"{label}: grid[{i}]", log)
             for i, e in enumerate(entries))
    fused = snapshot.get("fused_grid")
    if not isinstance(fused, list) or not fused:
        log(f"::error::{label}: NMS snapshot has no fused_grid entries; "
            "did the fused-tick bench run? (kernels_bench.nms_bench "
            "emits it by default — fused=False must not reach CI)")
        ok = False
    else:
        ok = all(_check_entry(e, NMS_FUSED_KEYS,
                              f"{label}: fused_grid[{i}]", log)
                 for i, e in enumerate(fused)) and ok
    if not _check_entry(snapshot.get("bf16"), NMS_BF16_KEYS,
                        f"{label}: bf16", log):
        ok = False
    if ok:
        log(f"schema ok [{label}]: grid({len(entries)}), "
            f"fused_grid({len(fused)}), bf16")
    return ok


def validate_snapshot(snapshot: dict, label: str, log=print) -> bool:
    """Dispatch on the snapshot's ``bench`` tag (serve vs NMS)."""
    bench = snapshot.get("bench")
    if bench == "spherical_nms":
        return validate_nms(snapshot, label, log)
    if bench == "variant_batched_serving":
        return validate_serve(snapshot, label, log)
    log(f"::error::{label}: unknown bench tag {bench!r} "
        "(expected 'variant_batched_serving' or 'spherical_nms')")
    return False


def compare(baseline: dict, fresh: dict, max_regression: float,
            key: str = "speedup", section: str = "grid",
            log=print) -> bool:
    """True when ``fresh`` holds the line vs ``baseline``."""
    base = {e["streams"]: e[key] for e in baseline.get(section, [])
            if key in e}
    new = {e["streams"]: e[key] for e in fresh.get(section, [])
           if key in e}
    common = sorted(set(base) & set(new))
    if not common:
        log(f"check_regression: no comparable {section} entries for {key!r}")
        return False
    base_mean = sum(base[s] for s in common) / len(common)
    new_mean = sum(new[s] for s in common) / len(common)
    floor = base_mean * (1.0 - max_regression)
    for s in common:
        log(f"  streams={s:>3}  baseline {key}={base[s]:.2f}  "
            f"fresh {key}={new[s]:.2f}")
    log(f"check_regression: mean {key} baseline={base_mean:.2f} "
        f"fresh={new_mean:.2f} floor={floor:.2f} "
        f"(max regression {max_regression:.0%})")
    if new_mean < floor:
        log(f"::error::serving {key} regressed: {new_mean:.2f} < "
            f"{floor:.2f} ({base_mean:.2f} baseline - {max_regression:.0%})")
        return False
    return True


def pod_dominates(fresh: dict, min_streams: int = 8, log=print) -> bool:
    """The pod-allocation acceptance floor (strict, not a noise band).

    Every fresh ``pod_grid`` entry at >= ``min_streams`` streams must
    show the coupled allocator strictly better on the accuracy proxy
    (``accuracy_ratio > 1``) at equal-or-lower mean tick latency
    (``tick_ratio <= 1``).  The frontier is computed by a deterministic
    oracle pod on the calibrated latency model — no wall clock — so
    exact gating does not flap.
    """
    entries = [e for e in fresh.get("pod_grid", [])
               if e.get("streams", 0) >= min_streams]
    if not entries:
        log(f"check_regression: no pod_grid entries at "
            f">= {min_streams} streams")
        return False
    ok = True
    for e in entries:
        dominates = (e["accuracy_ratio"] > 1.0
                     and e["tick_ratio"] <= 1.0 + 1e-6)
        log(f"  pod streams={e['streams']:>3}  accuracy_ratio="
            f"{e['accuracy_ratio']:.4f}  tick_ratio={e['tick_ratio']:.4f}"
            f"{'' if dominates else '  <-- FAILS dominance'}")
        if not dominates:
            log(f"::error::pod allocation no longer dominates at "
                f"{e['streams']} streams: accuracy_ratio="
                f"{e['accuracy_ratio']:.4f} tick_ratio="
                f"{e['tick_ratio']:.4f}")
            ok = False
    return ok


def policy_async_dominates(fresh: dict, min_streams: int = 8,
                           log=print) -> bool:
    """The drain-policy acceptance floor (strict, not a noise band).

    Every fresh ``policy_grid`` entry at >= ``min_streams`` streams
    must show async drain strictly undercutting the sync barrier on
    mean tick inference latency (``serving_bench.py --policy``): the
    carried residual chunks merge into fuller batches, so at pod scale
    the event-clock tick must be cheaper, not just equal.  The grid is
    computed by a deterministic oracle pod on the calibrated latency
    model — no wall clock — so exact gating does not flap.
    """
    entries = [e for e in fresh.get("policy_grid", [])
               if e.get("streams", 0) >= min_streams]
    if not entries:
        log(f"check_regression: no policy_grid entries at "
            f">= {min_streams} streams")
        return False
    ok = True
    for e in entries:
        a, s = e["async"]["mean_tick_s"], e["sync"]["mean_tick_s"]
        dominates = a < s
        log(f"  policy streams={e['streams']:>3}  sync tick={s:.4f}  "
            f"async tick={a:.4f}  ratio={e['async_tick_ratio']:.4f}"
            f"{'' if dominates else '  <-- FAILS dominance'}")
        if not dominates:
            log(f"::error::async drain no longer undercuts the sync "
                f"barrier at {e['streams']} streams: async={a:.4f} "
                f"sync={s:.4f}")
            ok = False
    return ok


def open_slo_dominates(fresh: dict, log=print) -> bool:
    """The open-loop admission acceptance floor (strict, not a band).

    Every fresh ``open_grid`` entry (``serving_bench.py --open-loop``)
    compares SLO-aware admission against admit-all on USEFUL goodput
    (within-SLO frames that did inference work — empty-plan frames
    complete instantly and must not count):

      * ``saturated`` points: SLO admission must STRICTLY dominate
        (shedding keeps served frames inside the SLO envelope while
        admit-all's queue — and its E2E — grow without bound);
      * ``light`` points: SLO admission must match admit-all exactly
        on useful goodput while shedding nothing (``rejected == 0``)
        — a policy that pays for its saturation wins by turning away
        comfortable load has regressed.

    The sweep is deterministic (seeded arrival clocks, oracle pod,
    calibrated latency model — no wall clock), so exact gating does
    not flap.
    """
    entries = fresh.get("open_grid", [])
    if not entries:
        log("check_regression: no open_grid entries")
        return False
    ok = True
    for e in entries:
        aa = e["admit_all"]["useful_goodput"]
        sl = e["slo"]["useful_goodput"]
        if e["load"] == "saturated":
            good = sl > aa
            want = "slo useful goodput must strictly exceed admit-all"
        else:
            good = sl >= aa and e["slo"]["rejected"] == 0
            want = ("slo useful goodput must match admit-all "
                    "with nothing rejected")
        log(f"  open streams={e['streams']:>3} {e['load']:>9}  "
            f"admit-all useful={aa}  slo useful={sl}  "
            f"slo rejected={e['slo']['rejected']}"
            f"{'' if good else '  <-- FAILS dominance'}")
        if not good:
            log(f"::error::open-loop SLO admission fails at "
                f"{e['streams']} streams ({e['load']}): {want} "
                f"(admit-all={aa}, slo={sl}, "
                f"rejected={e['slo']['rejected']})")
            ok = False
    return ok


def fleet_dominates(fresh: dict, strict_min_streams: int = 128,
                    log=print) -> bool:
    """The fleet-tier acceptance floor (strict, not a noise band).

    Every fresh ``fleet_grid`` entry (``serving_bench.py --fleet``)
    compares the BEST routing policy's fleet against the single
    monolithic pod on the same fixed device budget, on useful goodput:

      * at EVERY grid point the fleet must be >= the monolith (more
        independent replica-group chains can never serve less);
      * at >= ``strict_min_streams`` streams it must be STRICTLY
        greater — the scale regime the fleet tier exists for, where
        the monolith's pod-global backlog sheds most arrivals.

    The sweep is deterministic (seeded arrival clocks, oracle pods,
    calibrated latency model — no wall clock), so exact gating does
    not flap.
    """
    entries = fresh.get("fleet_grid", [])
    if not entries:
        log("check_regression: no fleet_grid entries")
        return False
    ok = True
    for e in entries:
        mono = e["mono"]["useful_goodput"]
        best = max(e["least_loaded"]["useful_goodput"],
                   e["affinity"]["useful_goodput"])
        strict = e["streams"] >= strict_min_streams
        good = best > mono if strict else best >= mono
        log(f"  fleet streams={e['streams']:>3} pods={e['pods']}  "
            f"mono useful={mono}  least_loaded="
            f"{e['least_loaded']['useful_goodput']}  affinity="
            f"{e['affinity']['useful_goodput']}  "
            f"ratio={e['goodput_ratio']:.4f}"
            f"{'' if good else '  <-- FAILS dominance'}")
        if not good:
            want = ("strictly exceed" if strict else "be >=")
            log(f"::error::fleet no longer dominates the monolith at "
                f"{e['streams']} streams / {e['pods']} pods: best "
                f"routing useful goodput {best} must {want} mono "
                f"{mono}")
            ok = False
    return ok


def fleet_p99_within_slo(fresh: dict, band: float = 0.0,
                         log=print) -> bool:
    """Fleet-level p99-E2E gate: every routed arm inside the envelope.

    For every fresh ``fleet_grid`` entry each routing arm's
    ``p99_e2e_s`` must stay <= the sweep's ``slo_s`` (recorded in the
    ``fleet`` meta section).  The gate is exact (``band`` 0): the
    sweep is deterministic, and since PR 10 the deadline-aware
    ``AsyncDrainPolicy`` carry plus the fleet-global ``solve_slo_s``
    envelope (``FleetServer.run_open_loop`` tightens every pod's
    capacity cap by the worst residual backlog each control round)
    keep every routed arm's p99 under the SLO on the committed
    frontier — the historical >= 4-pod ~3.5% overshoot, and the 5%
    allowance band that pinned it, are gone.  Any admission, carry or
    router change that pushes a tail past the SLO fails loudly — a
    regression the goodput dominance gate alone would not catch.
    """
    entries = fresh.get("fleet_grid", [])
    if not entries:
        log("check_regression: no fleet_grid entries for the p99 gate")
        return False
    slo = fresh.get("fleet", {}).get("slo_s")
    if slo is None:
        log("::error::fleet_grid present but the fleet meta section "
            "has no slo_s; cannot gate p99 E2E")
        return False
    ceiling = slo * (1 + band)
    ok = True
    for e in entries:
        worst = max(e["least_loaded"]["p99_e2e_s"],
                    e["affinity"]["p99_e2e_s"])
        good = worst <= ceiling + 1e-9
        log(f"  fleet streams={e['streams']:>3} pods={e['pods']}  "
            f"p99 least_loaded={e['least_loaded']['p99_e2e_s']:.4f}  "
            f"affinity={e['affinity']['p99_e2e_s']:.4f}  "
            f"slo={slo} (+{band:.0%})"
            f"{'' if good else '  <-- BLOWS THE SLO BAND'}")
        if not good:
            log(f"::error::fleet p99 E2E blows the SLO band at "
                f"{e['streams']} streams / {e['pods']} pods: "
                f"{worst:.4f}s > {ceiling:.4f}s ({slo}s + {band:.0%})")
            ok = False
    return ok


def mixed_no_collapse(fresh: dict, floor: float = 0.5, log=print) -> bool:
    """The multi-task acceptance floor (PR 10, strict, not a band).

    Every fresh ``task_grid`` entry (``serving_bench.py --tasks
    mixed``) compares the MIXED pod's per-task accuracy proxy against
    the same task served alone at the same stream count on the same
    device budget.  The coupled allocator prices both variant ladders
    in one capacity envelope, so it may trade capacity across tasks —
    but a mixed pod that starves one task to feed the other has
    collapsed: each per-task ratio must stay >= ``floor``, and every
    task must actually finish frames.  (The committed frontier sits at
    0.91-1.0, so the 0.5 floor only trips on a real starvation
    regression, not allocator drift.)  The sweep is deterministic
    (oracle backends, virtual slots, calibrated latency models — no
    wall clock), so exact gating does not flap.
    """
    entries = fresh.get("task_grid", [])
    if not entries:
        log("check_regression: no task_grid entries")
        return False
    ok = True
    for e in entries:
        frames = e["mixed"]["frames_by_task"]
        served = all(frames.get(t, 0) > 0
                     for t in ("detection", "action_recognition"))
        good = (e["mixed_detection_ratio"] >= floor
                and e["mixed_action_ratio"] >= floor and served)
        log(f"  task streams={e['streams']:>3}  "
            f"mixed/detection={e['mixed_detection_ratio']:.4f}  "
            f"mixed/action={e['mixed_action_ratio']:.4f}  "
            f"frames_by_task={frames}"
            f"{'' if good else '  <-- TASK COLLAPSED'}")
        if not good:
            log(f"::error::mixed-task pod collapsed a task at "
                f"{e['streams']} streams: detection ratio="
                f"{e['mixed_detection_ratio']:.4f}, action ratio="
                f"{e['mixed_action_ratio']:.4f} (floor {floor}), "
                f"frames_by_task={frames}")
            ok = False
    return ok


def fused_dominates(fresh: dict, min_b: int = 8, tick_band: float = 0.15,
                    log=print) -> bool:
    """The fused-tick acceptance floor (PR 9).

    For every fresh ``fused_grid`` entry the f32 fused path must be
    ``bit_identical`` to the staged path (exact — the crop cache and
    batched projection are exactness-preserving by construction), and
    at >= ``min_b`` crops the fused projection stage must STRICTLY
    beat the staged per-crop dispatch loop (``project_speedup > 1``;
    measured ~9x, so exact gating does not flap) while the full tick
    stays within a ``tick_band`` no-regress band (on CPU the detector
    forward dominates both paths, so the tick ratio is ~1 with up to
    ~8% wall-clock noise either way — the band is sized so only a
    real regression moves it).  The ``bf16`` keep-mask flip rate must
    stay under its recorded bound with ZERO flips on rows that have no
    IoU pair near the threshold.
    """
    entries = fresh.get("fused_grid", [])
    if not entries:
        log("check_regression: no fused_grid entries")
        return False
    ok = True
    for e in entries:
        strict = e["b"] >= min_b
        good = bool(e["bit_identical"])
        if strict:
            good = (good and e["fused_project_us"] < e["staged_project_us"]
                    and e["fused_us"] <= e["staged_us"] * (1 + tick_band))
        log(f"  fused b={e['b']:>2}  tick {e['staged_us']:.0f}->"
            f"{e['fused_us']:.0f}us  project {e['staged_project_us']:.0f}"
            f"->{e['fused_project_us']:.0f}us "
            f"({e['project_speedup']:.2f}x)  "
            f"bit_identical={e['bit_identical']}"
            f"{'' if good else '  <-- FAILS fused floor'}")
        if not good:
            log(f"::error::fused tick fails the acceptance floor at "
                f"b={e['b']}: bit_identical={e['bit_identical']} "
                f"project {e['fused_project_us']}us vs staged "
                f"{e['staged_project_us']}us, tick {e['fused_us']}us "
                f"vs staged {e['staged_us']}us (+{tick_band:.0%} band)")
            ok = False
    bf16 = fresh.get("bf16")
    if not bf16:
        log("::error::fused_grid present but no bf16 section; did the "
            "flip measurement run?")
        return False
    flips_ok = (bf16["flip_rate"] <= bf16["bound"]
                and bf16["far_row_flips"] == 0)
    log(f"  bf16 flip_rate={bf16['flip_rate']} (bound {bf16['bound']})  "
        f"far_row_flips={bf16['far_row_flips']}/{bf16['far_rows']}"
        f"{'' if flips_ok else '  <-- FAILS flip bound'}")
    if not flips_ok:
        log(f"::error::bf16 SphIoU keep-mask flips out of bound: "
            f"rate={bf16['flip_rate']} (bound {bf16['bound']}), "
            f"far-row flips={bf16['far_row_flips']} (must be 0)")
        ok = False
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_SERVE.json",
                    help="committed snapshot (the repo checkout's copy)")
    ap.add_argument("--fresh", default=None,
                    help="just-measured snapshot to gate (required "
                         "unless --schema-only)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="tolerated relative drop of the mean ratio")
    ap.add_argument("--key", default="speedup",
                    help="grid metric to gate (batched-vs-per-request "
                         "ratio by default)")
    ap.add_argument("--pod-min-streams", type=int, default=8,
                    help="stream floor above which the pod-allocation "
                         "dominance invariant is enforced")
    ap.add_argument("--schema-only", nargs="+", default=None,
                    metavar="PATH",
                    help="just validate these snapshots against the "
                         "explicit schemas (bench kind auto-detected "
                         "from the 'bench' tag) and exit; no baseline "
                         "comparison")
    args = ap.parse_args(argv)
    if args.schema_only:
        ok = True
        for path in args.schema_only:
            with open(path) as f:
                snapshot = json.load(f)
            ok = validate_snapshot(snapshot, path) and ok
            if snapshot.get("bench") == "spherical_nms" \
                    and snapshot.get("fused_grid"):
                # the fused-tick floor needs no baseline (bit-identity
                # and within-snapshot ratios), so the NMS schema lane
                # gates it too
                ok = fused_dominates(snapshot) and ok
        return 0 if ok else 1
    if args.fresh is None:
        ap.error("--fresh is required (or use --schema-only)")
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    # schema first: the gates below index these keys directly, so a
    # malformed snapshot fails with a named error, not a KeyError
    if not (validate_serve(baseline, args.baseline)
            and validate_serve(fresh, args.fresh)):
        return 1
    ok = compare(baseline, fresh, args.max_regression, key=args.key)
    if baseline.get("pod_grid") and not fresh.get("pod_grid"):
        # a baseline with a pod_grid means the pod gate is armed; a
        # fresh snapshot without one means the --pod-allocate bench
        # never ran (or its merge failed) — fail loudly instead of
        # silently skipping the dominance gate
        print("::error::baseline has pod_grid but fresh snapshot does "
              "not; did the --pod-allocate bench step run?")
        ok = False
    elif fresh.get("pod_grid"):
        if baseline.get("pod_grid"):
            # the coupled-vs-uncoupled accuracy gain must hold the line
            ok = compare(baseline, fresh, args.max_regression,
                         key="accuracy_ratio", section="pod_grid") and ok
        # the dominance invariant is exact (deterministic bench)
        ok = pod_dominates(fresh, args.pod_min_streams) and ok
    if baseline.get("policy_grid") and not fresh.get("policy_grid"):
        # armed policy gate, missing fresh grid: the --policy bench
        # step did not run (or its merge failed) — fail loudly
        print("::error::baseline has policy_grid but fresh snapshot "
              "does not; did the --policy bench step run?")
        ok = False
    elif fresh.get("policy_grid"):
        # async drain must strictly undercut the sync barrier
        ok = policy_async_dominates(fresh, args.pod_min_streams) and ok
    if baseline.get("open_grid") and not fresh.get("open_grid"):
        # armed open-loop gate, missing fresh grid: the --open-loop
        # bench step did not run (or its merge failed) — fail loudly
        print("::error::baseline has open_grid but fresh snapshot "
              "does not; did the --open-loop bench step run?")
        ok = False
    elif fresh.get("open_grid"):
        # SLO admission must dominate admit-all at saturation and
        # match it (shedding nothing) under light load
        ok = open_slo_dominates(fresh) and ok
    if baseline.get("fleet_grid") and not fresh.get("fleet_grid"):
        # armed fleet gate, missing fresh grid: the --fleet bench step
        # did not run (or its merge failed) — fail loudly
        print("::error::baseline has fleet_grid but fresh snapshot "
              "does not; did the --fleet bench step run?")
        ok = False
    elif fresh.get("fleet_grid"):
        # the fleet must match the monolith everywhere and beat it in
        # the >= 128-stream regime it exists for
        ok = fleet_dominates(fresh) and ok
        # ...without ever letting a routed arm's p99 E2E blow the SLO
        ok = fleet_p99_within_slo(fresh) and ok
    if baseline.get("task_grid") and not fresh.get("task_grid"):
        # armed multi-task gate, missing fresh grid: the --tasks mixed
        # bench step did not run (or its merge failed) — fail loudly
        print("::error::baseline has task_grid but fresh snapshot "
              "does not; did the --tasks mixed bench step run?")
        ok = False
    elif fresh.get("task_grid"):
        # the mixed pod must keep both tasks alive under one envelope
        ok = mixed_no_collapse(fresh) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

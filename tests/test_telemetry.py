"""Structured telemetry + deterministic replay (PR 7).

Pins the event-log/replay substrate:

  * schema enforcement at emit time: every record type a serving run
    produces is in ``EVENT_FIELDS`` with its required keys present;
    unknown types and missing keys are rejected at the PRODUCER;
  * JSONL round-trip: a log written by ``JsonlSink`` reads back
    (``read_events``) EQUAL to the in-memory record stream of the
    identical seeded run — floats, dicts, digests and all;
  * telemetry is a pure observer: a run with a sink attached yields
    the same deterministic ``ServeStats`` as one without;
  * replay determinism (the CI lane's in-repo twin): a recorded
    corpus re-driven under its own policy reproduces the stats
    fingerprint and every per-frame detection digest BIT-IDENTICALLY
    — closed loop, open loop with churn, and ``AsyncDrainPolicy``
    carry-over; tampering with the log is caught as drift;
  * the policy-diff path replays the same content under a different
    policy and reports, never claims identity;
  * ``format_timeline_report`` renders its summary from a log ALONE.
"""

import json

import pytest

from repro.serving.replay import (CorpusSpec, build_pod, format_policy_diff,
                                  record, replay, stats_fingerprint)
from repro.serving.telemetry import (EVENT_FIELDS, JsonlSink, MemorySink,
                                     TelemetrySink, detections_digest,
                                     format_timeline_report, read_events,
                                     validate_event)
from repro.serving.traffic import Arrival, arrivals_from_records

# small corpora keep the module in the fast tier; churn + async carry
# exercise the interesting event types (carry, admission, rebalance).
# The closed corpus needs a budget loose enough that DEADLINE-AWARE
# carry still withholds residual chunks (a tight budget now forces
# immediate dispatch — by design).
CLOSED_SPEC = CorpusSpec(mode="closed", n_streams=3, frames=6,
                         policy="async", devices=4, budget_s=3.0)
OPEN_SPEC = CorpusSpec(mode="open", n_streams=3, frames=4, budget_s=0.9,
                       devices=4, admission="slo", slo_s=2.0, fps=0.8,
                       jitter=0.2, horizon_s=8.0,
                       churn=((2.0, 1, False), (5.0, 1, True)))


@pytest.fixture(scope="module")
def closed_log():
    sink = MemorySink()
    stats = record(CLOSED_SPEC, sink)
    return sink.events, stats


@pytest.fixture(scope="module")
def open_log():
    sink = MemorySink()
    stats = record(OPEN_SPEC, sink)
    return sink.events, stats


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


class TestSchema:
    def test_unknown_event_type_rejected(self):
        with pytest.raises(ValueError, match="unknown telemetry event"):
            validate_event({"event": "teleport", "t_s": 0.0})
        with pytest.raises(ValueError, match="unknown telemetry event"):
            MemorySink().emit("teleport", t_s=0.0)

    def test_missing_required_key_rejected_at_emit(self):
        with pytest.raises(ValueError, match="missing required keys"):
            MemorySink().emit("arrival", t_s=0.0, stream=0)  # no frame_idx

    def test_extra_keys_tolerated(self):
        sink = MemorySink()
        sink.emit("arrival", t_s=0.0, stream=0, frame_idx=0,
                  future_field=1)  # readers must tolerate forward growth
        assert sink.events[0]["future_field"] == 1

    def test_every_emitted_type_is_schema_complete(self, closed_log,
                                                   open_log):
        """Each record carries its type's required keys (enforced at
        emit), and between them the two corpora exercise every event
        type in EVENT_FIELDS except ``rebalance`` (placement-shift
        dependent — covered by validate_event directly) and the
        fleet-tier ``route``/``scale`` (single-pod corpora never route
        or scale — emitted and checked in tests/test_fleet.py)."""
        seen = set()
        for events, _ in (closed_log, open_log):
            for e in events:
                assert EVENT_FIELDS[e["event"]] <= e.keys()
                seen.add(e["event"])
        optional = {"rebalance", "route", "scale"}
        assert set(EVENT_FIELDS) - seen <= optional
        validate_event({"event": "rebalance", "t_s": 0.0,
                        "groups": {"v": 2}})

    def test_open_log_has_admission_and_carry_coverage(self, open_log,
                                                       closed_log):
        events, _ = open_log
        verdicts = {e["verdict"] for e in events
                    if e["event"] == "admission"}
        assert "admit" in verdicts
        closed_events, _ = closed_log
        assert any(e["event"] == "carry" for e in closed_events), \
            "async closed corpus should carry residual chunks"

    def test_detections_digest_discriminates(self):
        class Det:
            def __init__(self, box, category, score):
                self.box, self.category, self.score = box, category, score

        a = [Det((0.1, 0.2, 0.3, 0.4), 3, 0.9)]
        b = [Det((0.1, 0.2, 0.3, 0.4), 3, 0.9)]
        c = [Det((0.1, 0.2, 0.3, 0.40000001), 3, 0.9)]
        assert detections_digest(a) == detections_digest(b)
        assert detections_digest(a) != detections_digest(c)
        assert detections_digest([]) != detections_digest(a)


# ---------------------------------------------------------------------------
# round-trip + observer purity
# ---------------------------------------------------------------------------


class TestRoundTrip:
    def test_jsonl_round_trips_the_memory_stream(self, tmp_path,
                                                 closed_log):
        """Writing the identical seeded run through a JsonlSink and
        reading it back yields records EQUAL to the in-memory ones."""
        mem_events, _ = closed_log
        path = str(tmp_path / "corpus.jsonl")
        record(CLOSED_SPEC, JsonlSink(path))
        assert read_events(path) == mem_events

    def test_read_events_rejects_bad_lines(self, tmp_path):
        path = str(tmp_path / "bad.jsonl")
        with open(path, "w") as f:
            f.write(json.dumps({"event": "arrival", "t_s": 0.0,
                                "stream": 0, "frame_idx": 0}) + "\n")
            f.write("{not json\n")
        with pytest.raises(ValueError, match="bad.jsonl:2"):
            read_events(path)

    def test_default_sink_is_a_pure_observer(self, closed_log):
        """A run with NO sink (the default no-op) produces the same
        deterministic stats as the recorded run — telemetry never
        perturbs scheduling, pricing or detections."""
        _, recorded_stats = closed_log
        bare = build_pod(CLOSED_SPEC)
        assert isinstance(bare.telemetry, TelemetrySink)
        assert not bare.telemetry.enabled
        stats = bare.run(range(CLOSED_SPEC.frames))
        assert stats_fingerprint(stats) == stats_fingerprint(recorded_stats)

    def test_wall_clock_field_excluded_from_fingerprint(self, closed_log):
        _, stats = closed_log
        assert "sum_overhead" not in stats_fingerprint(stats)

    def test_arrivals_round_trip_through_records(self, open_log):
        events, _ = open_log
        arrivals = arrivals_from_records(events)
        assert arrivals == sorted(OPEN_SPEC.traffic().arrivals(),
                                  key=lambda a: (a.t_s, a.stream))
        assert all(isinstance(a, Arrival) for a in arrivals)


# ---------------------------------------------------------------------------
# replay determinism (the CI lane's twin)
# ---------------------------------------------------------------------------


class TestReplayDeterminism:
    def test_closed_async_replay_bit_identical(self, tmp_path):
        """Closed loop under AsyncDrainPolicy carry-over: same policy
        -> same fingerprint, same digests, through a real file."""
        path = str(tmp_path / "closed.jsonl")
        record(CLOSED_SPEC, JsonlSink(path))
        result = replay(path)
        assert result.same_policy
        assert result.identical, "\n".join(result.drift())
        assert result.recorded_digests  # digests actually compared
        assert "bit-identical" in format_policy_diff(result)[0]

    def test_open_churn_replay_bit_identical(self, open_log):
        events, _ = open_log
        result = replay(events)
        assert result.identical, "\n".join(result.drift())
        # churn baked into the trace: stream 1 emitted nothing in its
        # disconnected window, and the replay saw the same arrivals
        assert result.replayed_stats["arrivals"] == \
            result.recorded_stats["arrivals"]

    def test_tampered_log_is_caught_as_drift(self, closed_log):
        events, _ = closed_log
        tampered = [dict(e) for e in events]
        for e in tampered:
            if e["event"] == "run_stats":
                e["stats"] = dict(e["stats"],
                                  total_detections=e["stats"]
                                  ["total_detections"] + 1)
        result = replay(tampered)
        assert not result.identical
        assert any("total_detections" in line for line in result.drift())

    def test_policy_override_reports_not_identity(self, closed_log):
        from repro.serving.runtime import SyncTickPolicy

        events, _ = closed_log
        result = replay(events, policy=SyncTickPolicy())
        assert not result.same_policy
        lines = format_policy_diff(result)
        assert "policy diff" in lines[0]
        assert result.replayed_stats["policy"] == "sync"

    def test_replay_requires_spec_and_stats(self, closed_log):
        events, _ = closed_log
        with pytest.raises(ValueError, match="corpus_spec"):
            replay([e for e in events if e["event"] != "corpus_spec"])
        with pytest.raises(ValueError, match="run_stats"):
            replay([e for e in events if e["event"] != "run_stats"])

    def test_spec_round_trips_and_rejects_unknown_fields(self):
        assert CorpusSpec.from_dict(OPEN_SPEC.to_dict()) == OPEN_SPEC
        with pytest.raises(ValueError, match="unknown fields"):
            CorpusSpec.from_dict({"mode": "closed", "warp": 9})


# ---------------------------------------------------------------------------
# offline report
# ---------------------------------------------------------------------------


class TestTimelineReport:
    def test_report_from_log_alone(self, open_log):
        events, stats = open_log
        lines = format_timeline_report(events)
        text = "\n".join(lines)
        assert "open-loop" in lines[0]
        assert f"{stats.frames} frames finished" in lines[0]
        assert "group utilisation" in text
        assert "admission verdicts" in text
        assert f"admit={stats.admitted}" in text
        assert "queueing delay" in text

    def test_report_closed_log_omits_admission(self, closed_log):
        events, _ = closed_log
        text = "\n".join(format_timeline_report(events))
        assert "admission verdicts" not in text
        assert "carry-over" in text  # async corpus carried work

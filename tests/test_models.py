"""Model-zoo consistency: attention impls, prefill/decode, MoE, families."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import detector as det_mod
from repro.models import diffusion as diff_mod
from repro.models import transformer as T
from repro.models import vision as V

# every test jit-compiles a model; the module runs ~2 min on CPU, so it
# lives in the slow tier (test_arch_smoke covers the archs there too)
pytestmark = pytest.mark.slow

RNG = jax.random.PRNGKey(0)


def tiny_lm(**kw):
    base = dict(name="tiny", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                d_head=16, d_ff=128, vocab_size=97, attention_impl="chunked",
                attn_chunk=16, ce_chunk=8, remat=False)
    base.update(kw)
    return T.TransformerConfig(**base)


class TestTransformer:
    def test_loss_near_uniform_at_init(self):
        cfg = tiny_lm()
        p = T.init_params(RNG, cfg)
        toks = jax.random.randint(RNG, (2, 24), 0, cfg.vocab_size)
        loss = T.lm_loss(p, {"tokens": toks, "targets": toks}, cfg)
        assert abs(float(loss) - np.log(cfg.vocab_size)) < 0.5

    def test_attention_impls_agree(self):
        cfg_c = tiny_lm(attention_impl="chunked")
        cfg_n = tiny_lm(attention_impl="naive")
        cfg_p = tiny_lm(attention_impl="pallas", d_head=32,
                        n_heads=2, n_kv_heads=2)
        p = T.init_params(RNG, cfg_c)
        toks = jax.random.randint(RNG, (2, 32), 0, 97)
        h_c, _ = T.forward(p, toks, cfg_c)
        h_n, _ = T.forward(p, toks, cfg_n)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_n),
                                   atol=5e-5)
        p2 = T.init_params(RNG, cfg_p)
        h_p, _ = T.forward(p2, toks, cfg_p)
        cfg_p_ref = dataclasses.replace(cfg_p, attention_impl="naive")
        h_pr, _ = T.forward(p2, toks, cfg_p_ref)
        np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_pr),
                                   atol=5e-5)

    @pytest.mark.parametrize("window", [None, 8])
    def test_decode_matches_forward(self, window):
        cfg = tiny_lm(attention_impl="naive", window=window)
        p = T.init_params(RNG, cfg)
        toks = jax.random.randint(RNG, (2, 20), 0, 97)
        full = T.logits_fn(p, toks, cfg)
        lg, cache = T.prefill(p, toks[:, :-1], cfg, max_len=32)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -2]),
                                   atol=5e-5)
        lg2, cache2 = T.decode_step(p, toks[:, -1], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, -1]),
                                   atol=5e-5)
        assert int(cache2.length) == 20

    def test_moe_decode_matches_forward_without_drops(self):
        cfg = tiny_lm(attention_impl="naive", moe=True, n_experts=8,
                      moe_top_k=2, d_ff=0, d_ff_expert=48,
                      capacity_factor=16.0)
        p = T.init_params(RNG, cfg)
        toks = jax.random.randint(RNG, (2, 16), 0, 97)
        full = T.logits_fn(p, toks, cfg)
        _, cache = T.prefill(p, toks[:, :-1], cfg, max_len=24)
        lg, _ = T.decode_step(p, toks[:, -1], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                                   atol=5e-5)

    def test_moe_capacity_drops_bounded(self):
        cfg = tiny_lm(moe=True, n_experts=4, moe_top_k=2, d_ff=0,
                      d_ff_expert=32, capacity_factor=1.0)
        p = T.init_params(RNG, cfg)
        toks = jax.random.randint(RNG, (2, 16), 0, 97)
        loss = T.lm_loss(p, {"tokens": toks, "targets": toks}, cfg)
        assert np.isfinite(float(loss))

    def test_swa_ring_buffer_wraps(self):
        cfg = tiny_lm(attention_impl="naive", window=8)
        p = T.init_params(RNG, cfg)
        toks = jax.random.randint(RNG, (1, 12), 0, 97)
        full = T.logits_fn(p, toks, cfg)
        _, cache = T.prefill(p, toks[:, :4], cfg, max_len=8)
        logits = None
        for i in range(4, 12):
            logits, cache = T.decode_step(p, toks[:, i], cache, cfg)
            if i < 11:  # compare next-token logits vs full forward
                np.testing.assert_allclose(
                    np.asarray(logits), np.asarray(full[:, i]), atol=5e-5)
        assert cache.k.shape[2] == 8  # cache stayed window-bounded


class TestVision:
    @pytest.mark.parametrize("family", ["vit", "convnext", "resnet"])
    def test_forward_shapes_and_finite(self, family):
        img = jax.random.normal(RNG, (2, 64, 64, 3))
        if family == "vit":
            cfg = V.ViTConfig("t", 64, 16, 2, 32, 4, 64, 10, remat=False)
            p = V.vit_init(RNG, cfg)
            logits, _ = V.vit_apply(p, img, cfg)
        elif family == "convnext":
            cfg = V.ConvNeXtConfig("t", 64, (2, 2, 2, 2), (16, 32, 64, 128),
                                   10, remat=False)
            p = V.convnext_init(RNG, cfg)
            logits, _ = V.convnext_apply(p, img, cfg)
        else:
            cfg = V.ResNetConfig("t", 64, (2, 2, 2, 2), 16, 10, remat=False)
            p = V.resnet_init(RNG, cfg)
            logits, _ = V.resnet_apply(p, img, cfg, train=False)
        assert logits.shape == (2, 10)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_resnet_bn_stats_update_only_in_train(self):
        cfg = V.ResNetConfig("t", 32, (2, 2, 2, 2), 16, 10, remat=False)
        p = V.resnet_init(RNG, cfg)
        img = jax.random.normal(RNG, (2, 32, 32, 3)) * 3 + 1
        _, p_train = V.resnet_apply(p, img, cfg, train=True)
        _, p_eval = V.resnet_apply(p, img, cfg, train=False)
        assert not np.allclose(np.asarray(p_train["bn_stem"]["mean"]),
                               np.asarray(p["bn_stem"]["mean"]))
        assert np.allclose(np.asarray(p_eval["bn_stem"]["mean"]),
                           np.asarray(p["bn_stem"]["mean"]))


class TestDiffusion:
    def test_mmdit_velocity_shape(self):
        cfg = diff_mod.MMDiTConfig("t", 8, 4, 2, 64, 4, 2, 3, 32, 8, 16,
                                   remat=False)
        p = diff_mod.mmdit_init(RNG, cfg)
        v = diff_mod.mmdit_apply(
            p, jax.random.normal(RNG, (2, 8, 8, 4)), jnp.array([0.2, 0.9]),
            jax.random.normal(RNG, (2, 8, 32)),
            jax.random.normal(RNG, (2, 16)), jnp.zeros(2), cfg)
        assert v.shape == (2, 8, 8, 4) and bool(jnp.all(jnp.isfinite(v)))

    def test_unet_eps_and_ddim(self):
        cfg = diff_mod.UNetConfig("t", 16, 4, 32, (1, 2, 4), 2, (1, 1, 2),
                                  24, 7, 20, 16, remat=False)
        p = diff_mod.unet_init(RNG, cfg)
        lat = jax.random.normal(RNG, (2, 16, 16, 4))
        ctx = jax.random.normal(RNG, (2, 7, 24))
        add = jax.random.normal(RNG, (2, 20))
        loss = diff_mod.unet_eps_loss(
            p, {"latents": lat, "ctx": ctx, "add_emb": add}, cfg, RNG)
        assert np.isfinite(float(loss))
        x = diff_mod.unet_ddim_step(p, lat, jnp.array([500., 500.]),
                                    jnp.array([480., 480.]), ctx, add, cfg)
        assert x.shape == lat.shape

    def test_rf_loss_decreases_with_perfect_model(self):
        # sanity: the rf loss of the zero-velocity model equals E|eps-x0|^2
        cfg = diff_mod.MMDiTConfig("t", 8, 4, 2, 32, 4, 1, 1, 16, 4, 8,
                                   remat=False)
        p = diff_mod.mmdit_init(RNG, cfg)
        lat = jnp.zeros((4, 8, 8, 4))
        loss = diff_mod.flux_rf_loss(
            p, {"latents": lat, "ctx": jnp.zeros((4, 4, 16)),
                "pooled": jnp.zeros((4, 8))}, cfg, RNG)
        assert 0.5 < float(loss) < 2.0  # ~E|eps|^2 = 1 for x0 = 0


class TestDetector:
    def test_ladder_flops_monotone(self):
        flops = [det_mod.flops_per_image(c) for c in det_mod.PAPER_LADDER]
        assert flops == sorted(flops)

    def test_heads_and_decode(self):
        cfg = det_mod.DetectorConfig("s", 64, 0.25, 0.34, n_classes=8)
        p = det_mod.init_params(RNG, cfg)
        outs = det_mod.apply(p, jax.random.normal(RNG, (2, 64, 64, 3)), cfg)
        assert [o.shape[1] for o in outs] == [8, 4, 2]
        boxes, scores, cls = det_mod.decode(outs, cfg, conf_threshold=0.0,
                                            max_det=16)
        assert boxes.shape == (2, 16, 4)
        assert bool(jnp.all(scores >= 0))

    def test_loss_finite(self):
        from repro.data.pipeline import rasterize_targets

        cfg = det_mod.DetectorConfig("s", 64, 0.25, 0.34, n_classes=8)
        p = det_mod.init_params(RNG, cfg)
        batch = {"images": jax.random.normal(RNG, (2, 64, 64, 3))}
        batch.update({k: jnp.asarray(v) for k, v in
                      rasterize_targets(cfg, 2).items()})
        loss = det_mod.detection_loss(p, batch, cfg)
        assert np.isfinite(float(loss))


class TestBackboneDetector:
    """Detection heads mounted on assigned vision backbones (the
    beyond-paper ladder extension of DESIGN.md section 2)."""

    @pytest.mark.parametrize("backbone", ["resnet", "convnext"])
    def test_heads_and_decode(self, backbone):
        if backbone == "resnet":
            bb = V.ResNetConfig("bb", 64, (2, 2, 2, 2), 16, 10, remat=False)
        else:
            bb = V.ConvNeXtConfig("bb", 64, (2, 2, 2, 2),
                                  (16, 32, 64, 128), 10, remat=False)
        cfg = det_mod.BackboneDetectorConfig(
            f"{backbone}-det", bb, input_size=64, n_classes=8, head_width=32)
        p = det_mod.backbone_detector_init(RNG, cfg)
        outs = det_mod.backbone_detector_apply(
            p, jax.random.normal(RNG, (2, 64, 64, 3)), cfg)
        assert [o.shape[1] for o in outs] == [8, 4, 2]
        boxes, scores, cls = det_mod.decode(outs, cfg, conf_threshold=0.0,
                                            max_det=8)
        assert boxes.shape == (2, 8, 4)
        assert bool(jnp.all(jnp.isfinite(boxes)))

    def test_classifier_path_unchanged(self):
        # the feature-pyramid refactor must not change classifier logits
        bb = V.ResNetConfig("bb", 32, (2, 2, 2, 2), 16, 10, remat=False)
        p = V.resnet_init(RNG, bb)
        img = jax.random.normal(RNG, (2, 32, 32, 3))
        logits, _ = V.resnet_apply(p, img, bb, train=False)
        feats, _ = V.resnet_features(p, img, bb, train=False)
        assert feats[-1].shape[-1] == 16 * 8 * 4
        assert logits.shape == (2, 10)

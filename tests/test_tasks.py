"""Multi-task analytics: task registry + mixed-task pods (PR 10).

Pins the ``repro.serving.tasks`` subsystem:

  * registry discipline: duplicate task names and cross-task variant
    name collisions are rejected (plain NAME strings key the queues,
    so task ladders must own disjoint name spaces);
  * detection THROUGH the registry is bit-identical to the
    pre-registry construction (same fingerprint, same digests — the
    refactor moved the wiring, not the numbers);
  * the oracle action backend's semantic batch equals its inline path
    and its tubelet window warms up / resets deterministically;
  * the Jax action backend's jit cache is bounded by
    (variants x batch buckets), like the detector's;
  * a mixed-task pod serves both tasks end to end: per-task frame
    counters and accuracy proxies, per-task open-loop conservation
    (``arrivals == admitted + rejected + missed`` per task);
  * coupled pricing generalises: ``pre_amortization`` is the identity
    at b=1 for BOTH tasks' curves, and ``solve_pod`` with per-stream
    overrides equal to the pod ladder returns the single-task answer;
  * the fleet-global SLO envelope reaches every active pod's
    ``solve_slo_s``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import accuracy as acc_mod
from repro.core import sroi as sroi_mod
from repro.data.synthetic import make_video
from repro.serving import pod_allocation as pa
from repro.serving import profiles
from repro.serving import tasks as task_registry
from repro.serving.batching import ShapeBuckets
from repro.serving.network import NetworkModel
from repro.serving.replay import (CorpusSpec, build_fleet, build_pod,
                                  record, stats_fingerprint)
from repro.serving.scheduler import OmniSenseLatencyModel
from repro.serving.tasks import (ACTION_LADDER, AnalyticsTask,
                                 OracleActionBackend, action_ladder,
                                 build_task_streams, get_task,
                                 register_task, stream_tasks_for,
                                 task_for_variant)
from repro.serving.telemetry import MemorySink

MIXED6 = ("detection", "action_recognition") * 3

CLOSED_MIXED = CorpusSpec(mode="closed", n_streams=6, frames=5,
                          budget_s=2.4, devices=8, tasks=MIXED6)
OPEN_MIXED = CorpusSpec(mode="open", n_streams=6, frames=4, budget_s=2.4,
                        devices=8, policy="async", admission="slo",
                        slo_s=2.0, fps=0.5, jitter=0.2, horizon_s=10.0,
                        tasks=MIXED6)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_both_tasks_registered(self):
        det = get_task("detection")
        act = get_task("action_recognition")
        assert det.accuracy_proxy == "sph_map"
        assert act.accuracy_proxy == "action_top1"
        assert act.ladder_names() == tuple(n for n, _, _ in ACTION_LADDER)
        # disjoint name spaces: (task, variant) == name
        assert not set(det.ladder_names()) & set(act.ladder_names())

    def test_duplicate_task_name_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_task(dataclasses.replace(get_task("detection")))

    def test_cross_task_variant_collision_rejected(self):
        clone = dataclasses.replace(get_task("detection"),
                                    name="detection_v2")
        with pytest.raises(ValueError, match="already registered to task"):
            register_task(clone)
        assert "detection_v2" not in task_registry.TASKS

    def test_unknown_task_is_a_named_error(self):
        with pytest.raises(ValueError, match="unknown task"):
            get_task("segmentation")

    def test_task_for_variant(self):
        assert task_for_variant("act-p2-8x96") == "action_recognition"
        for v in profiles.make_ladder():
            assert task_for_variant(v.name) == "detection"
        # unregistered toy ladders keep the pre-registry default
        assert task_for_variant("toy-variant") == "detection"

    def test_registry_entries_are_analytics_tasks(self):
        for task in task_registry.TASKS.values():
            assert isinstance(task, AnalyticsTask)
            assert task.ladder_names()


# ---------------------------------------------------------------------------
# stream builders
# ---------------------------------------------------------------------------


class TestBuildStreams:
    def test_stream_tasks_for_modes(self):
        assert stream_tasks_for("detection", 3) == ["detection"] * 3
        assert stream_tasks_for("action", 2) == ["action_recognition"] * 2
        assert stream_tasks_for("mixed", 4) == [
            "detection", "action_recognition",
            "detection", "action_recognition"]
        with pytest.raises(ValueError, match="unknown task mode"):
            stream_tasks_for("tracking", 4)

    def _videos(self, n, frames=6):
        return [make_video(n_frames=frames, n_objects=20, seed=100 + s)
                for s in range(n)]

    def test_mixed_union_ladder_and_per_task_pricing(self):
        variants, loops, backends, cost_fn = build_task_streams(
            ["detection", "action_recognition"], self._videos(2),
            [1.8, 1.8])
        det_names = get_task("detection").ladder_names()
        act_names = get_task("action_recognition").ladder_names()
        # union in first-seen task order, each full ladder contiguous
        assert tuple(v.name for v in variants) == det_names + act_names
        assert [loop.task for loop in loops] == ["detection",
                                                 "action_recognition"]
        # cost_fn prices each union variant on ITS task's curve: the
        # action rungs scale by clip length, which detection's
        # single-frame curve would not reproduce
        act_lat = loops[1].latency_model
        for v in loops[1].variants:
            assert cost_fn(v) == act_lat._inf(v)

    def test_unknown_detection_variants_rejected(self):
        with pytest.raises(ValueError, match="unknown variants"):
            build_task_streams(["detection"], self._videos(1), [1.8],
                               detection_variants=("no-such-rung",))

    def test_shape_buckets_union(self):
        buckets = task_registry.shape_buckets_for(
            ["detection", "action_recognition"])
        sizes = {v.input_size
                 for t in ("detection", "action_recognition")
                 for v in get_task(t).make_ladder()}
        assert set(buckets.resolutions) == sizes


# ---------------------------------------------------------------------------
# detection through the registry: bit-identical
# ---------------------------------------------------------------------------


class TestDetectionBitIdentity:
    def test_registry_construction_is_bit_identical(self):
        """A spec with ``tasks=()`` (the pre-registry default) and one
        naming detection explicitly build the SAME pod: identical
        stats fingerprint, identical per-frame digests."""
        base = CorpusSpec(mode="closed", n_streams=3, frames=4, devices=4)
        named = dataclasses.replace(base, tasks=("detection",) * 3)
        sink_a, sink_b = MemorySink(), MemorySink()
        stats_a = record(base, sink_a)
        stats_b = record(named, sink_b)
        assert stats_fingerprint(stats_a) == stats_fingerprint(stats_b)
        digests = [(e["stream"], e["frame_idx"], e["det_digest"])
                   for e in sink_a.events if e["event"] == "frame_finish"]
        assert digests == [
            (e["stream"], e["frame_idx"], e["det_digest"])
            for e in sink_b.events if e["event"] == "frame_finish"]
        assert digests

    def test_spec_tasks_round_trip(self):
        spec = CLOSED_MIXED
        assert CorpusSpec.from_dict(spec.to_dict()) == spec
        with pytest.raises(ValueError, match="names 2 streams"):
            record(dataclasses.replace(
                spec, tasks=("detection", "action_recognition")),
                MemorySink())


# ---------------------------------------------------------------------------
# oracle action backend
# ---------------------------------------------------------------------------


class TestOracleActionBackend:
    def _regions(self, k):
        return [sroi_mod.SRoI(center=(0.6 * i - 1.0, 0.1 * i), fov=(1.0, 0.9))
                for i in range(k)]

    def test_batched_equals_inline(self):
        """The semantic batch is bit-identical to per-request calls —
        the batched-vs-inline equivalence every backend must hold."""
        video = make_video(n_frames=6, n_objects=25, seed=3)
        inline, batched = OracleActionBackend(video), \
            OracleActionBackend(video)
        variant = action_ladder()[1]
        frame_img = np.zeros((4, 8, 3), np.float32)
        regions = self._regions(3)
        for f in range(4):
            inline.set_frame(f)
            batched.set_frame(f)
            want = [inline.infer_sroi(frame_img, r, variant)
                    for r in regions]
            got = batched.infer_srois_batched(
                [(frame_img, r) for r in regions], variant)
            assert len(got) == len(want)
            for a, b in zip(got, want):
                assert [(tuple(d.box), d.category, d.score) for d in a] \
                    == [(tuple(d.box), d.category, d.score) for d in b]

    def test_window_fill_warms_up_and_resets(self):
        backend = OracleActionBackend(make_video(n_frames=20, seed=0))
        variant = action_ladder()[1]  # clip_len 8
        region = self._regions(1)[0]
        fills = []
        for f in (0, 1, 2, 3):
            backend.set_frame(f)
            fills.append(backend._window_fill(region, variant))
        assert fills == [1 / 8, 2 / 8, 3 / 8, 4 / 8]
        # a repeat observation of the same frame is idempotent
        assert backend._window_fill(region, variant) == 4 / 8
        # a gap (frames the scheduler skipped this region) resets
        backend.set_frame(9)
        assert backend._window_fill(region, variant) == 1 / 8
        # a full consecutive run saturates at 1.0
        for f in range(10, 10 + 8):
            backend.set_frame(f)
            fill = backend._window_fill(region, variant)
        assert fill == 1.0


# ---------------------------------------------------------------------------
# jax action backend: compile discipline
# ---------------------------------------------------------------------------


class TestJaxActionBackend:
    def test_trace_count_bounded_by_variants_x_buckets(self):
        from repro.models import action as act_mod
        from repro.serving.tasks import JaxActionBackend

        import jax

        cfgs = [act_mod.ActionConfig(name=f"t{i}", input_size=16,
                                     clip_len=2 + 2 * i, patch=8,
                                     d_model=8, n_heads=2, d_ff=16,
                                     n_actions=4)
                for i in range(2)]
        params = [act_mod.init_params(jax.random.PRNGKey(i), c)
                  for i, c in enumerate(cfgs)]
        backend = JaxActionBackend(
            cfgs, params, use_kernel=False,
            buckets=ShapeBuckets((1, 2), resolutions=(16,)))
        variants = [acc_mod.ModelProfile(
            name=c.name, index=i + 1, input_size=16, location="edge",
            gav=np.full(12, 0.5), infer_s=0.01, model_bytes=2 ** 20)
            for i, c in enumerate(cfgs)]
        frame_img = np.random.default_rng(0).random((32, 64, 3)) \
            .astype(np.float32)
        regions = [sroi_mod.SRoI(center=(0.3 * k, 0.0), fov=(1.0, 1.0))
                   for k in range(2)]
        for f in range(3):
            backend.set_frame(f)
            for v in variants:
                for b in (1, 2):
                    out = backend.infer_srois_batched(
                        [(frame_img, r) for r in regions[:b]], v)
                    assert len(out) == b
                    assert all(len(dets) == 1 for dets in out)
        # every (variant, padded batch) compiled once — repeats hit the
        # jit cache, so a serving lifetime is bounded like the detector
        assert backend.trace_count <= len(cfgs) * 2
        before = backend.trace_count
        backend.infer_sroi(frame_img, regions[0], variants[0])
        assert backend.trace_count == before


# ---------------------------------------------------------------------------
# mixed-task pods end to end
# ---------------------------------------------------------------------------


class TestMixedPod:
    def test_closed_mixed_pod_counts_both_tasks(self):
        server = build_pod(CLOSED_MIXED)
        assert server.tasks == ("detection", "action_recognition")
        stats = server.run(range(CLOSED_MIXED.frames))
        n_each = CLOSED_MIXED.frames * 3
        assert stats.frames_by_task == {"detection": n_each,
                                        "action_recognition": n_each}
        proxies = stats.accuracy_proxy_by_task
        assert set(proxies) == {"detection", "action_recognition"}
        assert all(p > 0 for p in proxies.values())
        # per-task proxies partition the pod-level one
        total = sum(stats.plan_value_by_task.values())
        assert total == pytest.approx(
            stats.accuracy_proxy * stats.frames, rel=1e-9)

    def test_cross_task_variant_collision_rejected_by_pod(self):
        from repro.core.omnisense import OmniSenseLoop
        from repro.serving.scheduler import OracleBackend
        from repro.serving.server import PodServer

        variants = profiles.make_ladder()[:2]
        lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                    NetworkModel())
        loops, backends = [], []
        for s, task in enumerate(("detection", "action_recognition")):
            backend = OracleBackend(make_video(n_frames=4, seed=s))
            loop = OmniSenseLoop(variants, lat, backend, budget_s=1.8)
            loop.task = task  # same variant NAMES, different task
            loops.append(loop)
            backends.append(backend)
        with pytest.raises(ValueError, match="disjoint name spaces"):
            PodServer(loops, backends)

    def test_open_loop_per_task_conservation(self):
        stats = record(OPEN_MIXED, MemorySink())
        tasks = ("detection", "action_recognition")
        for t in tasks:
            assert stats.arrivals_by_task[t] == (
                stats.admitted_by_task.get(t, 0)
                + stats.rejected_by_task.get(t, 0)
                + stats.missed_by_task.get(t, 0)), t
        # the per-task splits partition the pod-level totals
        assert sum(stats.arrivals_by_task.values()) == stats.arrivals
        assert sum(stats.admitted_by_task.values()) == stats.admitted
        assert sum(stats.rejected_by_task.values()) == stats.rejected
        assert sum(stats.missed_by_task.values()) == stats.missed
        assert all(stats.arrivals_by_task[t] > 0 for t in tasks)

    def test_mixed_replay_bit_identical(self):
        from repro.serving.replay import replay

        sink = MemorySink()
        record(OPEN_MIXED, sink)
        result = replay(sink.events)
        assert result.identical, "\n".join(result.drift())

    def test_task_tags_in_telemetry(self):
        sink = MemorySink()
        record(OPEN_MIXED, sink)
        meta = next(e for e in sink.events if e["event"] == "run_meta")
        assert meta["tasks"] == ["detection", "action_recognition"]
        tasks_seen = {e["task"] for e in sink.events
                      if e["event"] == "admission"}
        assert tasks_seen == {"detection", "action_recognition"}
        for ev in ("emit", "dispatch_launch", "frame_finish"):
            assert all("task" in e for e in sink.events
                       if e["event"] == ev)


# ---------------------------------------------------------------------------
# coupled pricing across two curves
# ---------------------------------------------------------------------------


class TestCoupledPricing:
    def test_pre_amortization_identity_at_b1_both_tasks(self):
        det_lat = get_task("detection").make_latency_model()
        act_lat = get_task("action_recognition").make_latency_model()
        for lat, ladder in ((det_lat, profiles.make_ladder()),
                            (act_lat, action_ladder())):
            for v in ladder:
                assert lat.pre_amortization(v, 1) == 1.0
                assert lat.pre_amortization(v, 4) < 1.0

    def test_solve_pod_overrides_equal_base_is_identity(self):
        """Per-stream overrides naming the pod's own ladder + latency
        model must reproduce the no-override solution exactly — the
        seam the mixed-task solver rests on."""
        rng = np.random.default_rng(7)
        variants = tuple(profiles.make_ladder(seed=0)[:3])
        lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                    NetworkModel())
        buckets = ShapeBuckets((1, 2, 4, 8))

        def problem(overridden):
            m, r = len(variants), 2
            acc = np.vstack([np.zeros((1, r)),
                             rng.uniform(0.2, 1.0, (m, r))])
            d_pre = np.vstack([np.zeros((1, r)),
                               rng.uniform(0.01, 0.1, (m, r))])
            d_inf = np.vstack([np.zeros((1, r)),
                               rng.uniform(0.05, 0.6, (m, r))])
            return pa.StreamProblem(
                acc, d_pre, d_inf, budget=1.2,
                variants=variants if overridden else None,
                latency_model=lat if overridden else None)

        rng_state = rng.bit_generator.state
        base = pa.solve_pod([problem(False) for _ in range(4)],
                            variants, lat, buckets=buckets)
        rng.bit_generator.state = rng_state
        over = pa.solve_pod([problem(True) for _ in range(4)],
                            variants, lat, buckets=buckets)
        assert base.counts == over.counts
        assert base.projected_tick == over.projected_tick
        for p, q in zip(base.plans, over.plans):
            assert (p is None) == (q is None)
            if p is not None:
                assert p.models == q.models
                assert p.value == q.value


# ---------------------------------------------------------------------------
# fleet-global SLO envelope
# ---------------------------------------------------------------------------


class TestSloEnvelope:
    def test_open_loop_begin_sets_solve_slo(self):
        server = build_pod(CLOSED_MIXED)
        assert server.solve_slo_s is None
        server.open_loop_begin(slo_s=2.0)
        assert server.solve_slo_s == 2.0

    def test_fleet_envelope_reaches_active_pods(self):
        spec = dataclasses.replace(OPEN_MIXED, pods=2)
        fleet = build_fleet(spec)
        fleet.run_open_loop(spec.traffic(), slo_s=spec.slo_s)
        assert fleet.active
        for pid in fleet.active:
            env = fleet.pods[pid].solve_slo_s
            assert env is not None
            # the fleet-global envelope is the SLO minus the worst
            # residual backlog — never looser than the SLO itself
            assert 0.0 <= env <= spec.slo_s

"""Serving stack: latency model, baselines, OmniSense loop, evaluation."""

import math

import numpy as np
import pytest

from repro.core import sroi as sroi_mod
from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import sph_nms_host
from repro.data.synthetic import make_video, noa_histogram
from repro.serving import baselines, profiles
from repro.serving.evaluation import sph_map
from repro.serving.network import NetworkModel, PassiveProfiler
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer


@pytest.fixture(scope="module")
def setup():
    video = make_video(n_frames=40, n_objects=40, seed=3)
    variants = profiles.make_ladder(seed=0)
    net = NetworkModel()
    lat = OmniSenseLatencyModel(profiles.paper_profile(), net)
    backend = OracleBackend(video)
    return video, variants, lat, backend


class TestLatencyModel:
    def test_delay_shapes_and_skip_row(self, setup):
        video, variants, lat, backend = setup
        srois = [sroi_mod.SRoI((0.0, 0.0), (1.0, 1.0)),
                 sroi_mod.SRoI((1.0, 0.2), (1.0, 1.0))]
        d_pre, d_inf = lat.delays(srois, variants)
        assert d_pre.shape == (6, 2) and d_inf.shape == (6, 2)
        assert (d_pre[0] == 0).all() and (d_inf[0] == 0).all()
        # bigger input sizes cost more at every stage
        assert (np.diff(d_pre[1:, 0]) >= 0).all()

    def test_device_variant_skips_network(self, setup):
        _, variants, lat, _ = setup
        tiny = variants[0]
        assert tiny.location == "device"
        # device inference = pure model time (no delivery term)
        assert np.isclose(lat._inf(tiny), tiny.infer_s)

    def test_passive_profiler_window(self):
        p = PassiveProfiler(omega=3, initial_s=9.9)
        assert p.estimate("m") == 9.9
        for d in (1.0, 2.0, 3.0, 4.0):
            p.observe("m", d)
        assert np.isclose(p.estimate("m"), 3.0)  # last 3 of 4

    def test_scale_estimate_preserves_rtt_floor(self):
        """Rescaling an estimate to a new payload size scales only the
        bandwidth term; the RTT floor is payload-invariant.  (The old
        code scaled the whole mean — a half-size payload halved the
        RTT too, and a zero-byte estimate went to 0 instead of RTT.)"""
        p = PassiveProfiler(omega=4, rtt_s=0.2)
        for _ in range(4):  # observed: 0.2 RTT + 0.4 bandwidth @ 1 MB
            p.observe("m", 0.6)
        assert np.isclose(p.scale_estimate("m", 1e6, 5e5), 0.2 + 0.2)
        assert np.isclose(p.scale_estimate("m", 1e6, 2e6), 0.2 + 0.8)
        assert np.isclose(p.scale_estimate("m", 1e6, 0.0), 0.2)
        # same-size rescale is exact regardless of the floor split
        assert np.isclose(p.scale_estimate("m", 1e6, 1e6), 0.6)
        # an RTT-free profiler keeps the pure-linear behaviour
        p0 = PassiveProfiler(omega=4)
        p0.observe("m", 0.6)
        assert np.isclose(p0.scale_estimate("m", 1e6, 5e5), 0.3)
        # the latency model's defaulted profiler inherits the link RTT
        from repro.serving import profiles as prof_mod
        from repro.serving.scheduler import OmniSenseLatencyModel
        net = NetworkModel(rtt_s=0.05)
        lat = OmniSenseLatencyModel(prof_mod.paper_profile(), net)
        assert lat.profiler.rtt_s == net.rtt_s


class TestSyntheticData:
    def test_noa_distribution_matches_paper_shape(self):
        video = make_video(n_frames=60, n_objects=200, seed=0)
        noas = noa_histogram(video, range(0, 60, 10))
        assert len(noas) > 100
        # paper Fig. 2: most objects are tiny; several decades of spread
        assert np.median(noas) < 1e-2
        assert np.log10(noas.max() / noas.min()) > 2.5

    def test_spatial_bias(self):
        video = make_video(n_frames=10, n_objects=300, seed=1)
        phis = np.array([o.phi for o in video.objects])
        # equatorial band holds the bulk (paper Fig. 4 / SR-3 empty sky)
        assert (np.abs(phis) < 0.6).mean() > 0.7

    def test_render_erp(self):
        video = make_video(n_frames=5, n_objects=10, seed=2)
        img = __import__("repro.data.synthetic", fromlist=["render_erp"]) \
            .render_erp(video, 0, 64, 128)
        assert img.shape == (64, 128, 3)
        assert np.isfinite(img).all() and img.max() > 0.2


class TestOmniSenseLoop:
    def test_end_to_end_frames(self, setup):
        video, variants, lat, backend = setup
        loop = OmniSenseLoop(variants, lat, backend, budget_s=2.0)
        results = []
        for f in range(8):
            backend.set_frame(f)
            results.append(loop.process_frame(None))
        # discovery must have fired at least once to seed the history
        assert any(r.discovered for r in results)
        # once seeded, SRoIs get predicted and plans respect the budget
        assert any(r.srois for r in results)
        for r in results:
            assert r.planned_latency <= 2.0 + 1e-9

    def test_budget_controls_model_choice(self, setup):
        video, variants, lat, backend = setup
        chosen = {}
        for budget in (0.5, 4.0):
            loop = OmniSenseLoop(variants, lat, backend, budget_s=budget)
            picks = []
            loop.on_plan = lambda plan, srois: picks.extend(
                m for m in plan.models if m > 0)
            for f in range(10):
                backend.set_frame(f)
                loop.process_frame(None)
            chosen[budget] = np.mean(picks) if picks else 0
        # looser budget -> more expensive variants on average
        assert chosen[4.0] >= chosen[0.5]


class TestBaselinesAndMetric:
    def test_perfect_predictions_score_one(self, setup):
        video, *_ = setup
        gts = [(f, d) for f in range(5) for d in video.visible_objects(f)]
        assert sph_map(gts, gts) > 0.99

    def test_erp_baseline_worse_than_oracle_regions(self, setup):
        video, variants, lat, backend = setup
        frames = range(0, 10)
        gts = [(f, d) for f in frames for d in video.visible_objects(f)]
        erp_preds, erp_t = baselines.run_erp_baseline(
            video, backend, lat, variants[3], frames)
        cm_preds, cm_t = baselines.run_cubemap_baseline(
            video, backend, lat, variants[3], frames)
        m_erp = sph_map(erp_preds, gts)
        m_cm = sph_map(cm_preds, gts)
        # CubeMap sees distortion-free faces -> beats raw ERP (paper)
        assert m_cm > m_erp
        assert erp_t > 0 and cm_t > erp_t  # 6 faces cost more than 1 frame


class TestNMSSwapRegression:
    """The batched-NMS refactor must not change end-to-end results."""

    @staticmethod
    def _fresh(seed):
        video = make_video(n_frames=16, n_objects=30, seed=seed)
        variants = profiles.make_ladder(seed=0)
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        backend = OracleBackend(video)
        return OmniSenseLoop(variants, lat, backend, budget_s=2.0), backend

    def test_process_frame_detection_feedback_unchanged(self):
        """Inline path (single-row sph_nms_batch) vs the pre-refactor
        per-frame ``sph_nms_host`` applied manually via defer_nms: the
        kept detections — and therefore the SRoI-prediction feedback —
        must be identical frame by frame on a seeded synthetic stream."""
        loop_a, backend_a = self._fresh(7)
        loop_b, backend_b = self._fresh(7)
        saw_detections = False
        for f in range(12):
            backend_a.set_frame(f)
            backend_b.set_frame(f)
            ra = loop_a.process_frame(None)
            rb = loop_b.process_frame(None, defer_nms=True)
            keep = None
            if rb.detections:
                boxes = np.stack([d.box for d in rb.detections])
                scores = np.array([d.score for d in rb.detections])
                keep = sph_nms_host(boxes, scores, loop_b.nms_threshold)
            loop_b.finalize_detections(rb, keep)
            assert len(ra.detections) == len(rb.detections), f
            for da, db in zip(ra.detections, rb.detections):
                np.testing.assert_array_equal(da.box, db.box)
                assert da.category == db.category
                assert da.score == db.score
            saw_detections = saw_detections or bool(ra.detections)
        assert saw_detections  # the stream must actually exercise NMS

    def test_pod_tick_batched_nms_matches_inline(self):
        """A PodServer tick (one batched dispatch for all streams) keeps
        exactly what per-stream inline processing would keep."""
        n_streams, n_frames = 3, 8
        inline, batched, backends_a, backends_b = [], [], [], []
        variants = profiles.make_ladder(seed=0)
        for s in range(n_streams):
            for loops, backends in ((inline, backends_a),
                                    (batched, backends_b)):
                video = make_video(n_frames=16, n_objects=30, seed=40 + s)
                lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                            NetworkModel())
                b = OracleBackend(video)
                backends.append(b)
                loops.append(OmniSenseLoop(variants, lat, b, budget_s=2.0))
        server = PodServer(batched, backends_b, max_batch=4)
        for f in range(n_frames):
            expect = []
            for loop, b in zip(inline, backends_a):
                b.set_frame(f)
                expect.append(loop.process_frame(None).detections)
            server.step(f)
            for s, loop in enumerate(batched):
                got = loop._history[-1]
                assert len(got) == len(expect[s]), (f, s)
                for da, db in zip(expect[s], got):
                    np.testing.assert_array_equal(da.box, db.box)


class TestPodServer:
    def test_multi_stream_batching(self, setup):
        video, variants, lat, _ = setup
        n_streams = 4
        loops, backends = [], []
        for s in range(n_streams):
            b = OracleBackend(make_video(n_frames=20, seed=10 + s))
            backends.append(b)
            loops.append(OmniSenseLoop(variants, lat, b, budget_s=2.0))
        server = PodServer(loops, backends, max_batch=4)
        stats = server.run(range(6))
        assert stats.frames == n_streams * 6
        assert stats.mean_e2e <= 2.0
        if stats.batch_sizes:
            assert max(stats.batch_sizes) <= 4

"""The trip-count-aware HLO analyzer vs XLA's exact unrolled costs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hloanalysis import analyze, parse_hlo


def _scan_fn(xs, w):
    def body(c, x):
        return jax.nn.relu(c @ w) + x, None
    c, _ = jax.lax.scan(body, xs[0], xs)
    return jnp.sum(c)


def _unrolled_fn(xs, w):
    c = xs[0]
    for i in range(xs.shape[0]):
        c = jax.nn.relu(c @ w) + xs[i]
    return jnp.sum(c)


N_STEPS = 6
XS = jax.ShapeDtypeStruct((N_STEPS, 64, 64), jnp.float32)
W = jax.ShapeDtypeStruct((64, 64), jnp.float32)


class TestFlops:
    def test_scan_matches_unrolled_cost_analysis(self):
        c_scan = jax.jit(_scan_fn).lower(XS, W).compile()
        c_unr = jax.jit(_unrolled_fn).lower(XS, W).compile()
        ca = c_unr.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax returns [dict]
            ca = ca[0]
        exact = ca["flops"]
        a_scan = analyze(c_scan.as_text())
        a_unr = analyze(c_unr.as_text())
        # dot flops dominate; elementwise excluded -> within a few %
        assert abs(a_scan["flops"] - exact) / exact < 0.05
        assert abs(a_unr["flops"] - a_scan["flops"]) / exact < 0.05

    def test_trip_count_scaling(self):
        xs2 = jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)
        xs8 = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
        f2 = analyze(jax.jit(_scan_fn).lower(xs2, W).compile().as_text())
        f8 = analyze(jax.jit(_scan_fn).lower(xs8, W).compile().as_text())
        assert np.isclose(f8["flops"] / f2["flops"], 4.0, rtol=0.05)

    def test_conv_flops(self):
        def f(x, k):
            return jax.lax.conv_general_dilated(
                x, k, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.ShapeDtypeStruct((2, 16, 16, 8), jnp.float32)
        k = jax.ShapeDtypeStruct((3, 3, 8, 32), jnp.float32)
        c = jax.jit(f).lower(x, k).compile()
        a = analyze(c.as_text())
        want = 2 * 2 * 16 * 16 * 32 * 3 * 3 * 8  # 2*out_numel*k_spatial*cin
        assert np.isclose(a["flops"], want, rtol=0.02)


class TestCollectives:
    def test_collective_bytes_scale_with_trips(self):
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >1 device (dry-run env only)")

    def test_parse_smoke(self):
        c = jax.jit(_scan_fn).lower(XS, W).compile()
        comps = parse_hlo(c.as_text())
        assert any(comp.is_entry for comp in comps.values())
        a = analyze(c.as_text())
        assert a["collective_bytes"] == 0  # single device: no collectives
        assert a["hbm_bytes"] > 0

"""Algorithm 1 (SRoI prediction) behaviour + invariants."""

import math

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import sroi

F = math.radians(60.0)


def det(t, p, dt, dp, cat=0):
    return sroi.Detection(np.array([t, p, dt, dp]), cat)


class TestPrediction:
    def test_empty_history(self):
        assert sroi.predict_srois([]) == []

    def test_nearby_objects_merge(self):
        dets = [det(0.0, 0.0, 0.2, 0.2, 1), det(0.1, 0.05, 0.2, 0.2, 2)]
        out = sroi.predict_srois(dets)
        assert len(out) == 1
        assert not out[0].special
        assert np.isclose(out[0].fov[0], F)
        assert np.isclose(out[0].alpha, 1.0)

    def test_distant_objects_split(self):
        dets = [det(0.0, 0.0, 0.2, 0.2), det(2.5, 0.0, 0.2, 0.2)]
        out = sroi.predict_srois(dets)
        assert len(out) == 2

    def test_large_object_goes_special(self):
        dets = [det(0.0, 0.0, 1.8, 1.5, 5)]
        out = sroi.predict_srois(dets, gamma=1.1)
        assert len(out) == 1
        s = out[0]
        assert s.special
        # area scaled by gamma: fov scaled by sqrt(gamma) per axis
        assert np.isclose(s.fov[0], 1.8 * math.sqrt(1.1), rtol=1e-6)
        assert np.isclose(s.alpha, 1.0)

    def test_seam_cluster_merges(self):
        dets = [det(math.pi - 0.05, 0.0, 0.1, 0.1),
                det(-math.pi + 0.05, 0.0, 0.1, 0.1)]
        out = sroi.predict_srois(dets)
        assert len(out) == 1  # cluster must not split on the ERP seam

    @given(st.integers(0, 1000), st.integers(1, 20))
    @settings(max_examples=40, deadline=None)
    def test_invariants(self, seed, n):
        rng = np.random.default_rng(seed)
        dets = [det(rng.uniform(-math.pi, math.pi), rng.uniform(-1.2, 1.2),
                    rng.uniform(0.05, 2.0), rng.uniform(0.05, 1.6),
                    int(rng.integers(0, 80))) for _ in range(n)]
        out = sroi.predict_srois(dets)
        # every object lands in exactly one SRoI
        assert sum(len(s.objects) for s in out) == n
        # alphas sum to 1
        assert np.isclose(sum(s.alpha for s in out), 1.0)
        for s in out:
            # ccv is a distribution over the SRoI's objects
            assert np.isclose(s.ccv.sum(), 1.0)
            if not s.special:
                # regular SRoIs are f x f
                assert np.isclose(s.fov[0], F) and np.isclose(s.fov[1], F)
                # member objects' centres lie within the merged extent
                for o in s.objects:
                    dlon = abs((o.box[0] - s.center[0] + math.pi)
                               % (2 * math.pi) - math.pi)
                    assert dlon <= F / 2 + 1e-9


class TestCCV:
    def test_size_levels(self):
        # tiny object -> small bucket; huge -> large bucket
        tiny = det(0, 0, 0.02, 0.02, 3)
        huge = det(0, 0, 1.5, 1.2, 3)
        ccv = sroi.compute_ccv([tiny, huge], 80, 0.0044, 0.0354)
        assert ccv[0 * 80 + 3] == 0.5  # small x cat 3
        assert ccv[2 * 80 + 3] == 0.5  # large x cat 3

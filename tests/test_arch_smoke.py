"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes and no NaNs (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import diffusion as diff_mod
from repro.models import transformer as lm_mod
from repro.models import vision as vis_mod
from repro.training import optimizer as opt_mod
from repro.training import steps as steps_mod

pytestmark = pytest.mark.slow  # compiles a train step per architecture

RNG = jax.random.PRNGKey(0)
OPT = opt_mod.adamw(lr=1e-3)


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", cfgbase.list_archs())
def test_smoke_train_step(arch_id):
    arch = cfgbase.get_arch(arch_id)
    cfg = arch.smoke
    if arch.family == "lm":
        params = lm_mod.init_params(RNG, cfg)
        step = steps_mod.lm_train_step(cfg, OPT)
        batch = {
            "tokens": jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size),
            "targets": jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size),
        }
        state = steps_mod.make_state(params, OPT)
        state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert _finite(state["params"])
    elif arch.family == "vision":
        init = {vis_mod.ViTConfig: vis_mod.vit_init,
                vis_mod.ConvNeXtConfig: vis_mod.convnext_init,
                vis_mod.ResNetConfig: vis_mod.resnet_init}[type(cfg)]
        params = init(RNG, cfg)
        step = steps_mod.vision_train_step(cfg, OPT)
        batch = {
            "images": jax.random.normal(RNG, (2, cfg.img_res, cfg.img_res, 3)),
            "labels": jax.random.randint(RNG, (2,), 0, cfg.n_classes),
        }
        state = steps_mod.make_state(params, OPT)
        state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert 0.0 <= float(metrics["accuracy"]) <= 1.0
    else:  # diffusion
        is_flux = isinstance(cfg, diff_mod.MMDiTConfig)
        init = diff_mod.mmdit_init if is_flux else diff_mod.unet_init
        params = init(RNG, cfg)
        step = steps_mod.diffusion_train_step(cfg, OPT)
        r = cfg.latent_res
        batch = {"latents": jax.random.normal(RNG, (2, r, r, cfg.latent_ch)),
                 "seed": jnp.asarray(0, jnp.int32)}
        if is_flux:
            batch["ctx"] = jax.random.normal(RNG, (2, cfg.n_ctx_tokens, cfg.d_ctx))
            batch["pooled"] = jax.random.normal(RNG, (2, cfg.d_pooled))
        else:
            batch["ctx"] = jax.random.normal(RNG, (2, cfg.n_ctx_tokens, cfg.ctx_dim))
            batch["add_emb"] = jax.random.normal(RNG, (2, cfg.d_add))
        state = steps_mod.make_state(params, OPT)
        state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert _finite(state["params"])


@pytest.mark.parametrize("arch_id", ["granite_34b", "smollm_135m",
                                     "mixtral_8x22b", "qwen3_moe_235b_a22b"])
def test_smoke_serve_path(arch_id):
    """Prefill + one decode step on the reduced LM config."""
    arch = cfgbase.get_arch(arch_id)
    cfg = arch.smoke
    params = lm_mod.init_params(RNG, cfg)
    toks = jax.random.randint(RNG, (2, 12), 0, cfg.vocab_size)
    logits, cache = lm_mod.prefill(params, toks, cfg, max_len=24)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = lm_mod.decode_step(params, nxt, cache, cfg)
    assert logits2.shape == (2, cfg.vocab_size)
    assert int(cache2.length) == 13


def test_registry_covers_all_cells():
    cells = list(__import__("repro.launch.cells", fromlist=["iter_cells"])
                 .iter_cells())
    assert len(cells) == 40  # 10 archs x 4 shapes
    skipped = [c for c in cells if c[2] is not None]
    # exactly the three pure-full-attention LMs skip long_500k
    assert sorted(c[0] for c in skipped) == [
        "granite_34b", "qwen3_moe_235b_a22b", "smollm_135m"]
    assert all(c[1] == "long_500k" for c in skipped)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks per arch)."""
    a = cfgbase.get_arch("granite_34b").config
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab_size) == (88, 6144, 48, 1, 24576, 49152)
    m = cfgbase.get_arch("mixtral_8x22b").config
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.n_experts,
            m.moe_top_k, m.d_ff_expert, m.vocab_size) == (
        56, 6144, 48, 8, 8, 2, 16384, 32768)
    assert m.window is not None  # SWA per assignment
    q = cfgbase.get_arch("qwen3_moe_235b_a22b").config
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.n_experts,
            q.moe_top_k, q.d_ff_expert, q.vocab_size) == (
        94, 4096, 64, 4, 128, 8, 1536, 151936)
    s = cfgbase.get_arch("smollm_135m").config
    assert (s.n_layers, s.d_model, s.n_heads, s.n_kv_heads, s.d_ff) == (
        30, 576, 9, 3, 1536)
    f = cfgbase.get_arch("flux_dev").config
    assert (f.latent_res, f.n_double_blocks, f.n_single_blocks, f.d_model,
            f.n_heads) == (128, 19, 38, 3072, 24)
    u = cfgbase.get_arch("unet_sdxl").config
    assert (u.ch, tuple(u.ch_mult), u.n_res_blocks,
            tuple(u.transformer_depth), u.ctx_dim) == (
        320, (1, 2, 4), 2, (1, 2, 10), 2048)
    c = cfgbase.get_arch("convnext_b").config
    assert (tuple(c.depths), tuple(c.dims)) == ((3, 3, 27, 3),
                                                (128, 256, 512, 1024))
    r152 = cfgbase.get_arch("resnet_152").config
    assert tuple(r152.depths) == (3, 8, 36, 3)
    r50 = cfgbase.get_arch("resnet_50").config
    assert tuple(r50.depths) == (3, 4, 6, 3)
    v = cfgbase.get_arch("vit_b16").config
    assert (v.patch, v.n_layers, v.d_model, v.n_heads, v.d_ff) == (
        16, 12, 768, 12, 3072)

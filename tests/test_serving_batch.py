"""Variant-batched inference in the pod serving loop.

Pins the PR-2 serving refactor:

  * shape buckets bound the dispatch shape space (pad/split/resolution);
  * the batched latency path (per-batch fixed + per-item marginal)
    reduces to the per-request term at b=1 and preserves the
    scheduler's utility ordering (pinned allocator plans);
  * a PodServer tick equals the inline per-request path detection-for-
    detection on the oracle backend, and issues exactly one batched
    forward per distinct variant;
  * the Jax backend's bucketed-padded batched forward matches its
    per-request path and compiles at most ``len(buckets)`` programs per
    variant under mixed-size ticks;
  * ``decode``'s validity mask silences padded batch rows;
  * the CubeMap baseline through the queue machinery is unchanged.
"""

import dataclasses
import math

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import sroi as sroi_mod
from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import pad_detection_rows, sph_nms_batch
from repro.data.synthetic import make_video
from repro.models import detector as det_mod
from repro.serving import baselines, profiles
from repro.serving.batching import (DEFAULT_BATCH_BUCKETS, ShapeBuckets,
                                    VariantQueues)
from repro.serving.network import NetworkModel
from repro.serving.scheduler import (JaxDetectorBackend, OmniSenseLatencyModel,
                                     OracleBackend)
from repro.serving.server import PodServer


class TestShapeBuckets:
    def test_pad_batch_smallest_bucket(self):
        b = ShapeBuckets((1, 2, 4, 8))
        assert [b.pad_batch(i) for i in range(1, 9)] == [1, 2, 4, 4, 8, 8, 8, 8]
        with pytest.raises(ValueError):
            b.pad_batch(9)
        with pytest.raises(ValueError):
            b.pad_batch(0)

    def test_split_chunks_to_buckets(self):
        b = ShapeBuckets((1, 2, 4))
        assert b.split(11) == [4, 4, 3]
        assert b.split(4) == [4]
        assert b.split(1) == [1]
        assert b.split(0) == []

    def test_resolution_bucket_membership(self):
        b = ShapeBuckets((1, 2), resolutions=(64, 96))
        assert b.bucket_resolution(64) == 64
        with pytest.raises(ValueError):
            b.bucket_resolution(80)
        assert ShapeBuckets((1,)).bucket_resolution(80) == 80  # unrestricted

    def test_for_max_batch_tops_out_exactly(self):
        assert ShapeBuckets.for_max_batch(8).batch_sizes == (1, 2, 4, 8)
        assert ShapeBuckets.for_max_batch(4).batch_sizes == (1, 2, 4)
        assert ShapeBuckets.for_max_batch(6).batch_sizes == (1, 2, 4, 6)
        assert ShapeBuckets.for_max_batch(1).batch_sizes == (1,)

    def test_invalid_buckets_rejected(self):
        with pytest.raises(ValueError):
            ShapeBuckets((2, 1))
        with pytest.raises(ValueError):
            ShapeBuckets(())
        with pytest.raises(ValueError):
            ShapeBuckets((0, 2))

    @given(st.integers(1, 500), st.integers(0, 200))
    @settings(max_examples=50, deadline=None)
    def test_split_pad_invariants_property(self, seed, count):
        self._check_split_pad(seed, count)

    def test_split_pad_invariants_fixed(self):
        for seed, count in ((0, 0), (1, 1), (2, 7), (3, 64), (4, 133)):
            self._check_split_pad(seed, count)

    @staticmethod
    def _check_split_pad(seed, count):
        """Chunks conserve the request count, never exceed the top
        bucket, and every chunk pads to a member bucket >= its size."""
        rng = np.random.default_rng(seed)
        sizes = tuple(sorted(rng.choice(
            np.arange(1, 33), size=int(rng.integers(1, 5)), replace=False)))
        b = ShapeBuckets(tuple(int(s) for s in sizes))
        chunks = b.split(count)
        assert sum(chunks) == count
        assert all(0 < c <= b.max_batch for c in chunks)
        for c in chunks:
            padded = b.pad_batch(c)
            assert padded in b.batch_sizes and padded >= c


class TestBatchedLatencyModel:
    def _lat(self):
        return OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())

    def test_b1_reduces_to_per_request(self):
        lat = self._lat()
        for v in profiles.make_ladder(seed=0):
            assert lat.batched_inference_delay(v, 1) == lat._inf(v)

    def test_sublinear_and_monotone(self):
        lat = self._lat()
        v = profiles.make_ladder(seed=0)[3]
        costs = [lat.batched_inference_delay(v, b) for b in (1, 2, 4, 8)]
        assert all(a < b for a, b in zip(costs, costs[1:]))  # more work
        # ... but each batch of b costs less than b separate forwards
        for b, c in zip((2, 4, 8), costs[1:]):
            assert c < b * costs[0]
        amort = [lat.amortized_inference_delay(v, b) for b in (1, 2, 4, 8)]
        assert all(a > b for a, b in zip(amort, amort[1:]))

    def test_variant_cost_ordering_preserved(self):
        """Batching rescales every variant by the same curve, so the
        allocator's cost ordering across variants cannot flip."""
        lat = self._lat()
        variants = profiles.make_ladder(seed=0)
        for b in (1, 2, 8):
            batched = [lat.batched_inference_delay(v, b) for v in variants]
            single = [lat._inf(v) for v in variants]
            assert np.argsort(batched).tolist() == np.argsort(single).tolist()

    def test_allocator_plans_pinned(self):
        """Regression pin: the per-stream allocator (which prices
        requests individually) must produce the same plans before and
        after the batched-cost path was added."""
        video = make_video(n_frames=16, n_objects=30, seed=7)
        variants = profiles.make_ladder(seed=0)
        lat = self._lat()
        backend = OracleBackend(video)
        loop = OmniSenseLoop(variants, lat, backend, budget_s=2.0)
        plans = []
        for f in range(8):
            backend.set_frame(f)
            r = loop.process_frame(None)
            plans.append(None if r.plan is None else r.plan.models)
        assert plans == [None, (5, 3, 3), (5, 3, 3), (5, 4), (5, 4),
                         (5, 4), (5, 3, 3), (5, 3, 3)]


def _oracle_pod(n_streams, seed0=40, budget=2.0, max_batch=4):
    variants = profiles.make_ladder(seed=0)
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=16, n_objects=30, seed=seed0 + s)
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        b = OracleBackend(video)
        backends.append(b)
        loops.append(OmniSenseLoop(variants, lat, b, budget_s=budget))
    return loops, backends


class TestPodServerBatchedTick:
    def test_batched_tick_matches_per_request_inline(self):
        """The tentpole equivalence: a PodServer tick — request
        emission, variant-queue drain into batched forwards, scatter,
        batched NMS — keeps exactly the detections the inline
        per-request path produces, stream by stream, frame by frame."""
        n_streams, n_frames = 4, 8
        inline, backends_a = _oracle_pod(n_streams)
        batched, backends_b = _oracle_pod(n_streams)
        server = PodServer(batched, backends_b, max_batch=4)
        saw = 0
        for f in range(n_frames):
            expect = []
            for loop, b in zip(inline, backends_a):
                b.set_frame(f)
                expect.append(loop.process_frame(None).detections)
            server.step(f)
            for s, loop in enumerate(batched):
                got = loop._history[-1]
                assert len(got) == len(expect[s]), (f, s)
                for da, db in zip(expect[s], got):
                    np.testing.assert_array_equal(da.box, db.box)
                    assert da.category == db.category
                    assert da.score == db.score
                saw += len(got)
        assert saw > 0

    def test_one_dispatch_per_variant_per_tick(self):
        """S streams choosing V distinct variants => exactly V batched
        forwards in the tick (queues fit one bucket each here)."""
        n_frames = 6
        inline, backends_a = _oracle_pod(3, seed0=60, max_batch=8)
        batched, backends_b = _oracle_pod(3, seed0=60, max_batch=8)
        server = PodServer(batched, backends_b, max_batch=8)
        for f in range(n_frames):
            expect_variants = set()
            for loop, b in zip(inline, backends_a):
                b.set_frame(f)
                res = loop.process_frame(None)
                if res.plan is not None:
                    expect_variants |= {m for m in res.plan.models if m > 0}
            before = server.stats.dispatches
            server.step(f)
            assert server.stats.dispatches - before == len(expect_variants), f

    def test_queue_machinery_respects_max_batch(self):
        loops, backends = _oracle_pod(6, seed0=80, max_batch=2)
        server = PodServer(loops, backends, max_batch=2)
        stats = server.run(range(6))
        assert stats.batch_sizes and max(stats.batch_sizes) <= 2
        assert stats.dispatches == len(stats.batch_sizes)

    def test_batched_cost_charged_not_per_request_sums(self):
        loops, backends = _oracle_pod(6, seed0=90, max_batch=8)
        server = PodServer(loops, backends, max_batch=8)
        stats = server.run(range(8))
        assert stats.dispatches > 0
        # some tick batched >1 requests, so the pod pays strictly less
        # than the per-request sum, but never less than amortization-free
        assert stats.sum_batched_inf_s < stats.sum_per_request_inf_s
        assert stats.batching_gain > 1.0
        mb = max(stats.batch_sizes)
        assert stats.batching_gain <= mb / (1 + (mb - 1) * 0.15) + 1e-9

    def test_mismatched_buckets_rejected(self):
        loops, backends = _oracle_pod(2)
        with pytest.raises(ValueError):
            PodServer(loops, backends, max_batch=8,
                      buckets=ShapeBuckets((1, 2, 4)))

    def test_backend_buckets_smaller_than_server_rejected(self):
        """A backend whose bucket ladder tops out below the server's
        would silently split drained chunks, so the priced tick
        schedule would diverge from the executed one."""
        loops, backends = _oracle_pod(2)
        for b in backends:
            b.buckets = ShapeBuckets((1, 2, 4))  # tops out below 8
        with pytest.raises(ValueError):
            PodServer(loops, backends, max_batch=8)

    def test_marginal_batch_cost_override_is_honored(self):
        """An explicit marginal_batch_cost must override the latency
        model's curve in every priced dispatch."""
        stats = {}
        for marginal in (None, 0.0):
            loops, backends = _oracle_pod(6, seed0=90, max_batch=8)
            server = PodServer(loops, backends, max_batch=8,
                               marginal_batch_cost=marginal)
            stats[marginal] = server.run(range(4))
        # identical schedules (same seeds) and per-request sums, but
        # marginal=0 prices every dispatch at the single-forward cost —
        # strictly cheaper than the model's 0.15 curve once any b > 1
        assert stats[0.0].batch_sizes == stats[None].batch_sizes
        assert max(stats[0.0].batch_sizes) > 1
        assert stats[0.0].sum_per_request_inf_s == pytest.approx(
            stats[None].sum_per_request_inf_s)
        assert stats[0.0].sum_batched_inf_s < stats[None].sum_batched_inf_s
        assert stats[0.0].batching_gain > stats[None].batching_gain


# ---------------------------------------------------------------------------
# Real Jax detector path: bucketed-padded batched forward
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def jax_backend():
    cfg = dataclasses.replace(det_mod.PAPER_LADDER[0], input_size=64,
                              n_classes=8)
    params = det_mod.init_params(jax.random.PRNGKey(0), cfg)
    return JaxDetectorBackend(
        [cfg], [params], conf=0.01, use_kernel=False, max_det=4,
        buckets=ShapeBuckets((1, 2, 4), resolutions=(64,)))


def _regions(rng, n):
    fov = (math.radians(60), math.radians(60))
    return [sroi_mod.SRoI(center=(float(rng.uniform(-2.5, 2.5)),
                                  float(rng.uniform(-0.9, 0.9))), fov=fov)
            for _ in range(n)]


class TestJaxBatchedBackend:
    def test_batched_matches_per_request(self, jax_backend):
        """Acceptance: batched-padded inference produces the same
        detections as the per-request path on the Jax backend (crop,
        forward, decode, back-project all shared; only the batch shape
        differs, so results agree to float tolerance)."""
        rng = np.random.default_rng(0)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        variant = profiles.make_ladder(seed=0)[0]
        regions = _regions(rng, 3)
        per_request = [jax_backend.infer_sroi(frame, r, variant)
                       for r in regions]
        batched = jax_backend.infer_srois_batched(
            [(frame, r) for r in regions], variant)  # one chunk, padded to 4
        assert sum(len(d) for d in per_request) > 0
        assert len(batched) == len(per_request)
        for dets_a, dets_b in zip(per_request, batched):
            assert len(dets_a) == len(dets_b)
            for da, db in zip(dets_a, dets_b):
                assert da.category == db.category
                np.testing.assert_allclose(da.box, db.box,
                                           rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(da.score, db.score,
                                           rtol=1e-4, atol=1e-5)

    def test_mixed_shapes_compile_at_most_len_buckets(self, jax_backend):
        """A tick of mixed-size request groups triggers at most
        ``len(buckets)`` distinct jit compilations per variant — the
        shape-bucketing guarantee (trace_count increments only when
        jax.jit actually retraces)."""
        rng = np.random.default_rng(1)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        variant = profiles.make_ladder(seed=0)[0]
        start = jax_backend.trace_count
        for count in (1, 2, 3, 1, 2):  # mixed-shape "ticks"
            jax_backend.infer_srois_batched(
                [(frame, r) for r in _regions(rng, count)], variant)
        n_buckets = len(jax_backend.buckets.batch_sizes)
        assert jax_backend.trace_count - start <= n_buckets
        assert len(jax_backend._jit_cache) <= n_buckets * len(jax_backend.cfgs)
        for idx, b_pad in jax_backend._jit_cache:
            assert b_pad in jax_backend.buckets.batch_sizes

    def test_decode_valid_mask_silences_padded_rows(self):
        cfg = dataclasses.replace(det_mod.PAPER_LADDER[0], input_size=64,
                                  n_classes=8)
        params = det_mod.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(2)
        imgs = rng.random((2, 64, 64, 3)).astype(np.float32)
        outs = det_mod.apply(params, imgs, cfg)
        valid = np.array([True, False])
        boxes, scores, classes = det_mod.decode(outs, cfg, 0.01, max_det=8,
                                                valid=valid)
        assert (np.asarray(scores)[1] == 0).all()  # padded row silenced
        b_ref, s_ref, c_ref = det_mod.decode(outs, cfg, 0.01, max_det=8)
        for r in (0,):  # valid rows decode exactly as without a mask
            np.testing.assert_array_equal(np.asarray(scores)[r],
                                          np.asarray(s_ref)[r])
            np.testing.assert_array_equal(np.asarray(boxes)[r],
                                          np.asarray(b_ref)[r])


@pytest.mark.slow
class TestPodServerJaxBackend:
    def test_pod_tick_on_real_detector_matches_inline(self):
        """End-to-end pod tick on the REAL detector path: streams share
        one JaxDetectorBackend, frames come from ``frame_source``, and
        the batched tick's post-NMS histories match per-stream inline
        processing to float tolerance."""
        rng = np.random.default_rng(5)
        n_streams, n_frames = 3, 2
        cfgs = [dataclasses.replace(det_mod.PAPER_LADDER[i], input_size=64,
                                    n_classes=8) for i in range(2)]
        params = [det_mod.init_params(jax.random.PRNGKey(i), c)
                  for i, c in enumerate(cfgs)]
        variants = profiles.make_ladder(n_categories=8, seed=0)[:2]
        frames = {(s, f): rng.random((64, 128, 3)).astype(np.float32)
                  for s in range(n_streams) for f in range(n_frames)}
        seeds = [[sroi_mod.Detection(
                      box=np.array([rng.uniform(-2, 2), rng.uniform(-0.8, 0.8),
                                    0.5, 0.5]), category=int(rng.integers(8)),
                      score=0.9) for _ in range(2)]
                 for _ in range(n_streams)]

        def build():
            backend = JaxDetectorBackend(
                cfgs, params, conf=0.01, use_kernel=False, max_det=4,
                buckets=ShapeBuckets((1, 2, 4, 8), resolutions=(64,)))
            lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                        NetworkModel())
            loops = []
            for s in range(n_streams):
                loop = OmniSenseLoop(variants, lat, backend, budget_s=4.0,
                                     n_categories=8, explore_every=0)
                loop.seed_history(list(seeds[s]))
                loops.append(loop)
            return loops, backend

        inline_loops, _ = build()
        pod_loops, backend = build()
        server = PodServer(pod_loops, [backend] * n_streams, max_batch=8,
                           frame_source=lambda s, f: frames[(s, f)])
        saw = 0
        for f in range(n_frames):
            expect = []
            for s, loop in enumerate(inline_loops):
                expect.append(loop.process_frame(frames[(s, f)]).detections)
            server.step(f)
            for s, loop in enumerate(pod_loops):
                got = loop._history[-1]
                assert len(got) == len(expect[s]), (f, s)
                for da, db in zip(expect[s], got):
                    assert da.category == db.category
                    np.testing.assert_allclose(da.box, db.box,
                                               rtol=1e-4, atol=1e-4)
                saw += len(got)
        assert saw > 0  # the real detector must actually emit detections


class TestCubeMapThroughQueues:
    def test_results_match_per_request_path(self):
        """CubeMap routed through the variant-queue machinery must keep
        the exact predictions and calibrated E2E of the per-face
        implementation it replaced."""
        video = make_video(n_frames=8, n_objects=30, seed=3)
        variants = profiles.make_ladder(seed=0)
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        backend = OracleBackend(video)
        frames = range(0, 6)
        preds, e2e = baselines.run_cubemap_baseline(
            video, backend, lat, variants[3], frames)

        # the pre-refactor implementation, inlined
        lat_ref = OmniSenseLatencyModel(profiles.paper_profile(),
                                        NetworkModel())
        backend_ref = OracleBackend(make_video(n_frames=8, n_objects=30,
                                               seed=3))
        fov = (math.pi / 2, math.pi / 2)
        per_frame = []
        for f in frames:
            backend_ref.set_frame(f)
            dets = []
            for ct, cp in baselines.CUBE_CENTERS:
                region = sroi_mod.SRoI(center=(ct, cp), fov=fov)
                dets.extend(backend_ref.infer_sroi(None, region, variants[3]))
            per_frame.append((f, dets))
        expect = []
        rows = [(f, dets) for f, dets in per_frame if dets]
        boxes, scores, mask = pad_detection_rows([d for _, d in rows])
        keep = sph_nms_batch(boxes, scores, mask, iou_threshold=0.6)
        for r, (f, dets) in enumerate(rows):
            expect.extend((f, d) for d, k in zip(dets, keep[r]) if k)

        assert len(preds) == len(expect) and len(preds) > 0
        for (fa, da), (fb, db) in zip(preds, expect):
            assert fa == fb and da.category == db.category
            np.testing.assert_array_equal(da.box, db.box)

    def test_face_batching_cheaper_than_pipelined(self):
        video = make_video(n_frames=4, n_objects=20, seed=4)
        variants = profiles.make_ladder(seed=0)
        frames = range(0, 3)
        e2es = {}
        for fb in (1, 6):
            lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                        NetworkModel())
            backend = OracleBackend(make_video(n_frames=4, n_objects=20,
                                               seed=4))
            preds, e2e = baselines.run_cubemap_baseline(
                video, backend, lat, variants[3], frames, face_batch=fb)
            e2es[fb] = e2e
        assert e2es[6] < e2es[1]


class TestNmsShapeBuckets:
    """PR-3 satellite (ROADMAP open item): per-tick batched-NMS rows
    pad to the ShapeBuckets N-ladder so the device path's (B, N)
    compile shapes are bounded, pinned by the sphere-level trace
    counter exactly like ``infer_srois_batched``'s."""

    def test_pad_nms_rows_snaps_to_ladder(self):
        b = ShapeBuckets((1, 2), nms_sizes=(8, 16, 32))
        assert [b.pad_nms_rows(n) for n in (0, 1, 8, 9, 16, 30)] == \
            [8, 8, 8, 16, 16, 32]
        # beyond the top rung: top-rung multiples, never an error
        assert b.pad_nms_rows(33) == 64 and b.pad_nms_rows(65) == 96

    def test_invalid_nms_buckets_rejected(self):
        with pytest.raises(ValueError):
            ShapeBuckets((1, 2), nms_sizes=(16, 8))
        with pytest.raises(ValueError):
            ShapeBuckets((1, 2), nms_sizes=())

    def _rows(self, rng, n_rows, max_det=12):
        rows = []
        for _ in range(n_rows):
            k = int(rng.integers(0, max_det))
            rows.append([sroi_mod.Detection(
                box=np.array([rng.uniform(-2, 2), rng.uniform(-0.8, 0.8),
                              rng.uniform(0.2, 0.6), rng.uniform(0.2, 0.6)]),
                category=0, score=float(rng.uniform(0.1, 1.0)))
                for _ in range(k)])
        return rows

    def test_bucketed_padding_keeps_identical_masks(self):
        """Masked padding (N to the ladder, B to the stream count) can
        never change which real detections survive."""
        rng = np.random.default_rng(0)
        buckets = ShapeBuckets((1, 2, 4), nms_sizes=(8, 16, 32))
        for trial in range(5):
            rows = self._rows(rng, n_rows=int(rng.integers(1, 6)))
            boxes_a, scores_a, mask_a = pad_detection_rows(rows)
            keep_a = sph_nms_batch(boxes_a, scores_a, mask_a,
                                   iou_threshold=0.6)
            boxes_b, scores_b, mask_b = pad_detection_rows(
                rows, pad_n=buckets.pad_nms_rows, total_rows=8)
            assert boxes_b.shape[0] == 8
            assert boxes_b.shape[1] in (8, 16, 32)
            keep_b = sph_nms_batch(boxes_b, scores_b, mask_b,
                                   iou_threshold=0.6)
            for r, dets in enumerate(rows):
                np.testing.assert_array_equal(keep_a[r, :len(dets)],
                                              keep_b[r, :len(dets)])
            assert not keep_b[len(rows):].any()  # padded rows keep nothing

    def test_device_path_traces_bounded_by_ladder(self):
        """Trace-counter pin: ladder-padded ticks retrace the jitted
        device NMS once per rung, not once per detection count."""
        from repro.core.sphere import nms_device_trace_count

        rng = np.random.default_rng(1)
        buckets = ShapeBuckets((1, 2, 4), nms_sizes=(8, 16))
        n_streams = 4
        start = nms_device_trace_count()
        for tick in range(6):
            rows = self._rows(rng, n_rows=int(rng.integers(1, n_streams + 1)))
            boxes, scores, mask = pad_detection_rows(
                rows, pad_n=buckets.pad_nms_rows, total_rows=n_streams)
            sph_nms_batch(boxes, scores, mask, iou_threshold=0.6,
                          backend="jit")
        assert nms_device_trace_count() - start <= len(buckets.nms_sizes)

    def test_pod_server_suppression_unchanged_by_bucketing(self):
        """The served histories with bucketed NMS padding equal the
        unpadded per-stream suppression (the pre-PR-3 behaviour)."""
        inline, backends_a = _oracle_pod(3, seed0=70)
        batched, backends_b = _oracle_pod(3, seed0=70)
        server = PodServer(batched, backends_b, max_batch=4)
        for f in range(6):
            for loop, b in zip(inline, backends_a):
                b.set_frame(f)
                loop.process_frame(None)
            server.step(f)
        for la, lb in zip(inline, batched):
            assert len(la._history[-1]) == len(lb._history[-1])
            for a, b in zip(la._history[-1], lb._history[-1]):
                np.testing.assert_array_equal(a.box, b.box)


class TestVariantQueuesUnit:
    class _CountingBackend:
        def __init__(self):
            self.calls = []

        def infer_srois_batched(self, items, variant):
            self.calls.append((variant.name, len(items)))
            return [[] for _ in items]

    def test_drain_order_and_chunking(self):
        from repro.core.omnisense import InferenceRequest
        from repro.serving.batching import QueuedRequest

        variants = profiles.make_ladder(seed=0)
        backend = self._CountingBackend()
        q = VariantQueues(ShapeBuckets((1, 2)))
        fov = (1.0, 1.0)
        for slot, v in enumerate([variants[1]] * 3 + [variants[0]]):
            q.put(QueuedRequest(
                request=InferenceRequest(
                    region=sroi_mod.SRoI(center=(0.0, 0.0), fov=fov),
                    variant=v, slot=slot, special=False),
                owner=None, backend=backend))
        results, dispatches = q.drain()
        assert len(results) == 4 and len(q) == 0
        # sorted variant-name drain order; chunks of <= max bucket
        assert backend.calls == [(variants[1].name, 2), (variants[1].name, 1),
                                 (variants[0].name, 1)]
        assert [(d["variant"], d["b"], d["padded"]) for d in dispatches] == [
            (variants[1].name, 2, 2), (variants[1].name, 1, 1),
            (variants[0].name, 1, 1)]

    def test_default_buckets_exported(self):
        assert DEFAULT_BATCH_BUCKETS == (1, 2, 4, 8)

    def test_real_backend_groups_priced_individually(self):
        """A same-variant chunk spanning DISTINCT real backends executes
        one forward per backend group — pricing must follow the group
        sizes, never the chunk, or stats would report batching that
        never ran.  Per-stream oracle instances (``semantic_batch``)
        keep chunk-level pricing: they simulate one shared accelerator."""
        from repro.core.omnisense import InferenceRequest
        from repro.serving.batching import QueuedRequest

        class _RealBackend:  # no semantic_batch attribute
            def infer_srois_batched(self, items, variant):
                return [[] for _ in items]

        variants = profiles.make_ladder(seed=0)
        v = variants[1]
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        b1, b2 = _RealBackend(), _RealBackend()
        q = VariantQueues(ShapeBuckets((1, 2, 4)))
        for slot, be in enumerate([b1, b1, b1, b2]):
            q.put(QueuedRequest(
                request=InferenceRequest(
                    region=sroi_mod.SRoI(center=(0.0, 0.0), fov=(1.0, 1.0)),
                    variant=v, slot=slot, special=False),
                owner=None, backend=be, latency_model=lat))
        _, dispatches = q.drain()
        assert len(dispatches) == 1
        d = dispatches[0]
        assert d["semantic"] is False
        assert sorted(d["group_sizes"]) == [1, 3] and d["forwards"] == 2

        loops, backends = _oracle_pod(1)
        server = PodServer(loops, backends)
        batched, per_req = server._dispatch_cost(d)
        assert batched == pytest.approx(lat.batched_inference_delay(v, 3)
                                        + lat.batched_inference_delay(v, 1))
        assert per_req == pytest.approx(4 * lat._inf(v))

        # oracle chunks (semantic simulation) stay chunk-priced
        o_loops, o_backends = _oracle_pod(2)
        q2 = VariantQueues(ShapeBuckets((1, 2, 4)))
        for slot, be in enumerate(o_backends):
            q2.put(QueuedRequest(
                request=InferenceRequest(
                    region=sroi_mod.SRoI(center=(0.0, 0.0), fov=(1.0, 1.0)),
                    variant=v, slot=slot, special=False),
                owner=None, backend=be, latency_model=lat))
        _, o_dispatches = q2.drain()
        assert o_dispatches[0]["semantic"] is True
        o_batched, _ = server._dispatch_cost(o_dispatches[0])
        assert o_batched == pytest.approx(lat.batched_inference_delay(v, 2))

"""Spherical geometry: unit + property tests (hypothesis)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import sphere

ANG = st.floats(-math.pi, math.pi, allow_nan=False)
LAT = st.floats(-1.45, 1.45, allow_nan=False)
FOV = st.floats(0.05, 1.5, allow_nan=False)


def box(t, p, dt, dp):
    return jnp.array([t, p, dt, dp], jnp.float32)


class TestArea:
    def test_formula(self):
        b = box(0.3, -0.2, 0.5, 0.8)
        assert np.isclose(float(sphere.sph_area(b)),
                          2 * 0.5 * math.sin(0.4), atol=1e-6)

    @given(ANG, LAT, FOV, FOV)
    @settings(max_examples=50, deadline=None)
    def test_rotation_invariant_and_positive(self, t, p, dt, dp):
        a1 = float(sphere.sph_area(box(t, p, dt, dp)))
        a2 = float(sphere.sph_area(box(0.0, 0.0, dt, dp)))
        assert a1 > 0
        assert np.isclose(a1, a2, rtol=1e-5)

    def test_full_sphere_limit(self):
        # dtheta=2pi, dphi=pi covers the sphere: area = 4pi
        a = float(sphere.sph_area(box(0, 0, 2 * math.pi, math.pi)))
        assert np.isclose(a, 4 * math.pi, rtol=1e-6)


class TestIoU:
    @given(ANG, LAT, FOV, FOV)
    @settings(max_examples=50, deadline=None)
    def test_self_iou_is_one(self, t, p, dt, dp):
        b = box(t, p, dt, dp)
        assert np.isclose(float(sphere.sph_iou(b, b)), 1.0, atol=1e-4)

    @given(ANG, LAT, FOV, FOV, ANG, LAT, FOV, FOV)
    @settings(max_examples=50, deadline=None)
    def test_bounds_and_symmetry(self, t1, p1, dt1, dp1, t2, p2, dt2, dp2):
        a, b = box(t1, p1, dt1, dp1), box(t2, p2, dt2, dp2)
        i1 = float(sphere.sph_iou(a, b))
        i2 = float(sphere.sph_iou(b, a))
        assert -1e-6 <= i1 <= 1.0 + 1e-6
        assert np.isclose(i1, i2, atol=2e-3)

    def test_disjoint(self):
        assert float(sphere.sph_iou(box(0, 0, 0.4, 0.4),
                                    box(2.0, 0, 0.4, 0.4))) == 0.0

    def test_seam_wrap(self):
        # boxes straddling the +-pi seam must still overlap
        a = box(math.pi - 0.05, 0.0, 0.3, 0.3)
        b = box(-math.pi + 0.05, 0.0, 0.3, 0.3)
        assert float(sphere.sph_iou(a, b)) > 0.3

    def test_small_box_matches_planar(self):
        # tiny equatorial boxes behave like planar IoU
        a = box(0.0, 0.0, 0.02, 0.02)
        b = box(0.01, 0.0, 0.02, 0.02)
        planar = (0.01 * 0.02) / (2 * 0.02 * 0.02 - 0.01 * 0.02)
        assert np.isclose(float(sphere.sph_iou(a, b)), planar, rtol=1e-2)


class TestNMS:
    def test_host_lax_and_folded_agree(self):
        rng = np.random.default_rng(0)
        boxes = np.stack([
            rng.uniform(-math.pi, math.pi, 40),
            rng.uniform(-1.2, 1.2, 40),
            rng.uniform(0.1, 0.8, 40),
            rng.uniform(0.1, 0.8, 40)], axis=-1).astype(np.float32)
        scores = rng.uniform(0, 1, 40).astype(np.float32)
        k1 = sphere.sph_nms_host(boxes, scores)
        k2 = np.asarray(sphere.sph_nms_lax(jnp.asarray(boxes),
                                           jnp.asarray(scores)))
        k3 = sphere.sph_nms(boxes, scores)  # B=1 fold of sph_nms_batch
        assert (k1 == k2).all()
        assert (k1 == k3).all()

    def test_suppresses_duplicates(self):
        b = np.array([[0, 0, 0.5, 0.5], [0.01, 0.0, 0.5, 0.5]], np.float32)
        keep = sphere.sph_nms_host(b, np.array([0.9, 0.8]))
        assert keep.tolist() == [True, False]

    @given(st.integers(1, 30))
    @settings(max_examples=20, deadline=None)
    def test_survivors_mutually_nonoverlapping(self, n):
        rng = np.random.default_rng(n)
        boxes = np.stack([
            rng.uniform(-math.pi, math.pi, n),
            rng.uniform(-1.2, 1.2, n),
            rng.uniform(0.1, 0.9, n),
            rng.uniform(0.1, 0.9, n)], axis=-1).astype(np.float32)
        scores = rng.uniform(0, 1, n).astype(np.float32)
        keep = sphere.sph_nms_host(boxes, scores, 0.6)
        surv = boxes[keep]
        if len(surv) > 1:
            iou = np.array(sphere.sph_iou_matrix(
                jnp.asarray(surv), jnp.asarray(surv)))
            np.fill_diagonal(iou, 0)
            assert iou.max() <= 0.6 + 1e-5


class TestBackProjection:
    def test_pi_box_roundtrip(self):
        # a PI-centred detection back-projects to a SphBB at the centre
        rect = jnp.array([96.0, 96.0, 160.0, 160.0])  # centred in 256x256
        bb = sphere.pi_box_to_sphbb(
            rect, jnp.asarray(0.7), jnp.asarray(-0.3),
            (math.radians(60), math.radians(60)), (256, 256))
        bb = np.asarray(bb)
        assert np.isclose(bb[0], 0.7, atol=1e-3)
        assert np.isclose(bb[1], -0.3, atol=1e-3)
        assert 0.05 < bb[2] < 0.5 and 0.05 < bb[3] < 0.5

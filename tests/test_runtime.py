"""Event-driven serving runtime (PR 5).

Pins the runtime/policy refactor of the pod serving plane:

  * ``GroupClock.free_at`` is monotone per group and no dispatch ever
    launches before the tick that emitted its inputs (causality on the
    event clock), property-tested with fixed-seed twins;
  * ``SyncTickPolicy`` reproduces the pre-refactor ``PodServer.step``
    BIT-IDENTICALLY on a seeded 8-stream corpus — detections, stats
    and jit/NMS trace counts all equal a hand-rolled reference of the
    old tick loop — and its per-tick timelines price exactly
    ``OmniSenseLatencyModel.tick_inference_delay``;
  * ``DeadlineOrderPolicy`` orders dispatches by (deadline, cost per
    request served) without perturbing results, cutting mean
    event-clock E2E at identical tick cost;
  * ``AsyncDrainPolicy`` carries residual sub-bucket chunks (bounded
    staleness, conservation of frames) and strictly undercuts the sync
    barrier's mean tick at 8 streams / 2 variants — the test-scale
    twin of the ``serving_bench --policy`` nightly gate;
  * the old ``PodServer(pod_allocate=...)`` boolean is GONE (the PR 5
    shim was removed on schedule): the keyword raises ``TypeError``
    and the boolean lives on the policy object only;
  * ``solve_pod`` exports its per-group ``projected_load`` and the
    policies consume it instead of recomputing the curve.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import sroi as sroi_mod
from repro.core.omnisense import InferenceRequest, OmniSenseLoop
from repro.core.sphere import (nms_auto_backend, nms_device_trace_count,
                               pad_detection_rows, sph_nms_batch)
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.batching import QueuedRequest, ShapeBuckets, VariantQueues
from repro.serving.network import NetworkModel
from repro.serving.runtime import (AsyncDrainPolicy, DeadlineOrderPolicy,
                                   DispatchEvent, GroupClock, SyncTickPolicy,
                                   TickTimeline, make_policy)
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer

# ---------------------------------------------------------------------------
# event clock
# ---------------------------------------------------------------------------


def _clock_trace(seed: int):
    """Random dispatch/advance trace; returns per-group free_at
    observations in operation order plus the (launch, emit-tick-start)
    pairs of every dispatch."""
    rng = np.random.default_rng(seed)
    clock = GroupClock()
    observed: dict[int, list[float]] = {}
    launches = []
    for _ in range(int(rng.integers(1, 40))):
        if rng.random() < 0.3:  # close the tick like a policy would
            clock.advance(clock.now + float(rng.uniform(0.0, 1.0)))
        g = int(rng.integers(0, 4))
        start = clock.now
        launch, complete = clock.dispatch(g, float(rng.uniform(0.0, 2.0)))
        launches.append((launch, start))
        assert complete == clock.free_at(g)
        observed.setdefault(g, []).append(clock.free_at(g))
    return observed, launches


class TestGroupClock:
    @given(st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_free_at_monotone_property(self, seed):
        self._check_monotone(seed)

    def test_free_at_monotone_fixed(self):
        for seed in (0, 1, 2, 7, 1234):
            self._check_monotone(seed)

    @staticmethod
    def _check_monotone(seed):
        observed, launches = _clock_trace(seed)
        for g, series in observed.items():
            assert all(a <= b for a, b in zip(series, series[1:])), g
        # causality: a dispatch can never launch before the tick that
        # admitted it started
        for launch, start in launches:
            assert launch >= start

    def test_unseen_group_free_at_start(self):
        clock = GroupClock(start=3.0)
        assert clock.free_at(42) == 3.0
        assert not clock.busy(42)
        assert clock.next_free() is None
        assert clock.horizon() == 3.0

    def test_dispatch_serialises_within_group(self):
        clock = GroupClock()
        l1, c1 = clock.dispatch(0, 1.0)
        l2, c2 = clock.dispatch(0, 0.5)
        assert (l1, c1) == (0.0, 1.0)
        assert (l2, c2) == (1.0, 1.5)  # waits for the group, not the tick
        l3, c3 = clock.dispatch(1, 0.25)
        assert (l3, c3) == (0.0, 0.25)  # other groups run concurrently
        assert clock.next_free() == 0.25
        assert clock.horizon() == 1.5

    def test_advance_never_rewinds(self):
        clock = GroupClock()
        clock.advance(2.0)
        clock.advance(1.0)
        assert clock.now == 2.0

    def test_negative_cost_rejected(self):
        with pytest.raises(ValueError):
            GroupClock().dispatch(0, -1.0)


class TestTickTimeline:
    def _event(self, g, cost, launch, tick=0):
        return DispatchEvent(variant="v", b=1, padded=1, group=g,
                             n_devices=1, cost_s=cost, launch_s=launch,
                             complete_s=launch + cost, emitted_s=0.0,
                             tick=tick)

    def test_barrier_equals_tick_inference_delay(self):
        """The no-carry timeline charge IS the old device-aware tick
        model, on the exact same accumulation."""
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        rng = np.random.default_rng(0)
        for _ in range(20):
            tl = TickTimeline(0, start=float(rng.uniform(0, 5)))
            group_costs: dict[int, float] = {}
            t = {}
            for _ in range(int(rng.integers(0, 12))):
                g = int(rng.integers(0, 3))
                c = float(rng.uniform(0.0, 1.0))
                launch = tl.start + t.get(g, 0.0)
                t[g] = t.get(g, 0.0) + c
                tl.record(self._event(g, c, launch))
                group_costs[g] = group_costs.get(g, 0.0) + c
            assert tl.barrier_delay(lat.tick_inference_delay) == \
                lat.tick_inference_delay(group_costs.values())
            assert tl.barrier_delay() == \
                max(group_costs.values(), default=0.0)

    def test_overlap_generalises_barrier(self):
        """tick_overlap_delay with zero carry-in == tick_inference_delay;
        carry-in pushes exactly the busy group's completion out."""
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        costs = {0: 1.0, 1: 0.4}
        assert lat.tick_overlap_delay(costs) == \
            lat.tick_inference_delay(costs.values())
        assert lat.tick_overlap_delay(costs, carry_in={1: 0.9}) == 1.3
        assert lat.tick_overlap_delay(costs, carry_in={0: 0.1}) == 1.1
        assert lat.tick_overlap_delay({}) == 0.0

    def test_overlap_delay_tracks_event_horizon(self):
        tl = TickTimeline(0, start=1.0)
        assert tl.overlap_delay() == 0.0
        tl.record(self._event(0, 0.5, launch=1.0))
        tl.record(self._event(1, 0.25, launch=2.0))  # carried-in group
        assert tl.overlap_delay() == pytest.approx(1.25)
        assert tl.horizon() == pytest.approx(2.25)


# ---------------------------------------------------------------------------
# policy construction / the PodServer API
# ---------------------------------------------------------------------------


def _oracle_pod(n_streams, frames=8, seed0=100, budget=1.8, policy=None,
                variants=None, devices=0, budget_fn=None):
    variants = variants or profiles.make_ladder()[3:5]
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=frames + 8, n_objects=30 + 5 * (s % 4),
                           seed=seed0 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        b = budget_fn(s) if budget_fn is not None else budget
        loops.append(OmniSenseLoop(variants, lat, backend, budget_s=b,
                                   explore_costs=costs))
    placement = None
    if devices:
        from repro.serving.placement import VariantPlacement

        placement = VariantPlacement.virtual(variants, devices,
                                             cost_fn=lat._inf)
    return PodServer(loops, backends, max_batch=8, placement=placement,
                     policy=policy)


class TestPolicyAPI:
    def test_make_policy_names(self):
        assert isinstance(make_policy("sync"), SyncTickPolicy)
        assert isinstance(make_policy("deadline"), DeadlineOrderPolicy)
        assert isinstance(make_policy("async"), AsyncDrainPolicy)
        assert make_policy("sync", pod_allocate=True).pod_allocate

    def test_make_policy_instance_passthrough(self):
        p = AsyncDrainPolicy(max_carry=2)
        assert make_policy(p) is p

    def test_make_policy_unknown_rejected(self):
        with pytest.raises(ValueError):
            make_policy("fifo")

    def test_default_policy_is_sync(self):
        server = _oracle_pod(2)
        assert isinstance(server.policy, SyncTickPolicy)
        assert server.stats.policy == "sync"
        assert server.pod_allocate is False

    def test_pod_allocate_shim_removed(self):
        """The PR 5 ``pod_allocate=`` DeprecationWarning shim was
        scheduled for removal at ~PR 7; pin that it's gone — the
        keyword now fails like any unknown argument instead of
        warning-and-mapping."""
        variants = profiles.make_ladder()[3:5]
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        loops, backends = [], []
        for s in range(2):
            backend = OracleBackend(make_video(n_frames=8, n_objects=20,
                                               seed=s))
            backends.append(backend)
            loops.append(OmniSenseLoop(variants, lat, backend, budget_s=1.8))
        with pytest.raises(TypeError, match="pod_allocate"):
            PodServer(loops, backends, pod_allocate=True)
        # the replacement spelling: the boolean lives on the policy
        server = PodServer(loops, backends,
                           policy=SyncTickPolicy(pod_allocate=True))
        assert server.pod_allocate is True

    def test_policy_name_accepted_by_server(self):
        server = _oracle_pod(2, policy="async")
        assert isinstance(server.policy, AsyncDrainPolicy)
        assert server.stats.policy == "async"


# ---------------------------------------------------------------------------
# sync equivalence: the runtime reproduces the pre-refactor tick loop
# ---------------------------------------------------------------------------


def _reference_tick_loop(n_streams, frames, seed0=100, budget=1.8,
                         variants=None, devices=0, max_batch=8):
    """The PRE-RUNTIME ``PodServer.step``, hand-rolled from its public
    pieces: full sorted-variant drain, scatter, per-tick batched NMS,
    barrier tick charge.  The seeded corpus oracle for the
    ``SyncTickPolicy`` bit-identity acceptance test."""
    variants = variants or profiles.make_ladder()[3:5]
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=frames + 8, n_objects=30 + 5 * (s % 4),
                           seed=seed0 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        loops.append(OmniSenseLoop(variants, lat, backend, budget_s=budget,
                                   explore_costs=costs))
    placement = None
    if devices:
        from repro.serving.placement import VariantPlacement

        placement = VariantPlacement.virtual(variants, devices,
                                             cost_fn=lat._inf)
    buckets = ShapeBuckets.for_max_batch(max_batch)
    queues = VariantQueues(buckets)
    stats = dict(frames=0, detections=0, batch_sizes=[], dispatches=0,
                 sum_batched=0.0, sum_per_request=0.0, sum_tick=0.0,
                 sum_e2e=0.0, sum_plan_value=0.0)
    histories = []
    for f in range(frames):
        pendings = []
        for loop, backend in zip(loops, backends):
            backend.set_frame(f)
            pending = loop.begin_frame(None)
            pendings.append((loop, pending))
            if pending.plan is not None:
                stats["sum_plan_value"] += pending.plan.value
            for req in pending.requests:
                queues.put(QueuedRequest(request=req, owner=pending,
                                         backend=backend,
                                         latency_model=loop.latency_model))
        if placement is not None:
            counts = {}
            for _, pending in pendings:
                for req in pending.requests:
                    counts[req.variant.name] = counts.get(req.variant.name,
                                                          0) + 1
            placement.observe(counts)
            placement.maybe_rebalance()
        results, dispatches = queues.drain(placement)
        scatter = {}
        for item, dets in results:
            scatter.setdefault(id(item.owner), {})[item.request.slot] = dets
        group_costs = {}
        for d in dispatches:
            stats["dispatches"] += 1
            stats["batch_sizes"].append(d["b"])
            variant = d["items"][0].request.variant
            group = d.get("group")
            n_dev = group.n_devices if group is not None else 1
            if d["semantic"]:
                batched = lat.sharded_inference_delay(variant, d["b"], n_dev)
            else:
                batched = sum(lat.sharded_inference_delay(variant, g, n_dev)
                              for g in d["group_sizes"])
            stats["sum_batched"] += batched
            stats["sum_per_request"] += lat.batched_inference_delay(
                variant, 1) * d["b"]
            gidx = group.index if group is not None else 0
            group_costs[gidx] = group_costs.get(gidx, 0.0) + batched
        stats["sum_tick"] += lat.tick_inference_delay(group_costs.values())
        plans = []
        for loop, pending in pendings:
            slots = scatter.get(id(pending), {})
            request_detections = [slots.get(i, [])
                                  for i in range(len(pending.requests))]
            plans.append((loop, loop.finish_frame(pending, request_detections,
                                                  defer_nms=True)))
        rows = [(loop, res) for loop, res in plans if res.detections]
        keeps = {}
        if rows:
            row_dets = [res.detections for _, res in rows]
            n_pad = buckets.pad_nms_rows(max(len(d) for d in row_dets))
            if nms_auto_backend(len(plans), n_pad) == "device":
                boxes, scores, mask = pad_detection_rows(
                    row_dets, pad_n=buckets.pad_nms_rows,
                    total_rows=len(plans))
            else:
                boxes, scores, mask = pad_detection_rows(row_dets)
            keep = sph_nms_batch(boxes, scores, mask, iou_threshold=0.6)
            for r, (_, res) in enumerate(rows):
                keeps[id(res)] = keep[r, : len(res.detections)]
        for loop, res in plans:
            loop.finalize_detections(res, keeps.get(id(res)))
            stats["frames"] += 1
            stats["detections"] += len(res.detections)
            stats["sum_e2e"] += res.planned_latency
        histories.append([list(loop._history[-1]) for loop in loops])
    return stats, histories


class TestSyncEquivalence:
    @pytest.mark.parametrize("devices", [0, 8])
    def test_sync_policy_bit_identical_on_seeded_corpus(self, devices):
        """The acceptance pin: PodServer(policy=sync) on the seeded
        8-stream corpus equals the pre-refactor tick loop — stats,
        detections and NMS trace counts all bit-equal."""
        n_streams, frames = 8, 8
        nms_traces = nms_device_trace_count()
        ref, ref_hist = _reference_tick_loop(n_streams, frames,
                                             devices=devices)
        server = _oracle_pod(n_streams, frames=frames, devices=devices,
                             policy="sync")
        got_hist = []
        for f in range(frames):
            server.step(f)
            got_hist.append([list(loop._history[-1])
                             for loop in server.loops])
        server.flush()  # must be a no-op under sync
        st = server.stats
        assert st.frames == ref["frames"] == n_streams * frames
        assert st.total_detections == ref["detections"]
        assert st.batch_sizes == ref["batch_sizes"]
        assert st.dispatches == ref["dispatches"]
        assert st.sum_batched_inf_s == ref["sum_batched"]
        assert st.sum_per_request_inf_s == ref["sum_per_request"]
        assert st.sum_tick_inf_s == ref["sum_tick"]
        assert st.sum_e2e == ref["sum_e2e"]
        assert st.sum_plan_value == ref["sum_plan_value"]
        assert st.carried_requests == 0
        for fa, fb in zip(ref_hist, got_hist):
            for da, db in zip(fa, fb):
                assert len(da) == len(db)
                for a, b in zip(da, db):
                    np.testing.assert_array_equal(a.box, b.box)
                    assert a.category == b.category
                    assert a.score == b.score
        # the host-path NMS must not have compiled anything new
        assert nms_device_trace_count() == nms_traces

    def test_sync_timelines_price_tick_inference_delay_exactly(self):
        """Per tick, the timeline's barrier charge equals the latency
        model's tick_inference_delay on the recorded group sums, and
        the charges sum to the serve stats; no sync dispatch overlaps
        a tick boundary."""
        server = _oracle_pod(6, frames=6, devices=8, policy="sync")
        lat = server.loops[0].latency_model
        server.run(range(6))
        total = 0.0
        for tl in server.timelines:
            charge = tl.barrier_delay(lat.tick_inference_delay)
            assert charge == lat.tick_inference_delay(tl.group_costs.values())
            total += charge
            for e in tl.events:
                assert e.launch_s >= tl.start  # no pre-tick launches
                assert e.carried == 0
        assert total == server.stats.sum_tick_inf_s

    def test_sync_pod_allocate_stats_unchanged(self):
        """The pod-allocation path is stable across server builds:
        two identically seeded coupled pods (policy-object spelling —
        the only spelling since the shim removal) agree on every
        deterministic stat."""
        a = _oracle_pod(4, frames=4, devices=8,
                        policy=SyncTickPolicy(pod_allocate=True))
        sa = a.run(range(4))
        variants = profiles.make_ladder()[3:5]
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        costs = [lat._pre(v) + lat._inf(v) for v in variants]
        loops, backends = [], []
        for s in range(4):
            backend = OracleBackend(make_video(n_frames=12,
                                               n_objects=30 + 5 * (s % 4),
                                               seed=100 + s))
            backends.append(backend)
            loops.append(OmniSenseLoop(variants, lat, backend, budget_s=1.8,
                                       explore_costs=costs))
        from repro.serving.placement import VariantPlacement

        placement = VariantPlacement.virtual(variants, 8, cost_fn=lat._inf)
        b = PodServer(loops, backends, max_batch=8, placement=placement,
                      policy=SyncTickPolicy(pod_allocate=True))
        sb = b.run(range(4))
        assert sa.pod_ticks == sb.pod_ticks
        assert sa.pod_rounds == sb.pod_rounds
        assert sa.sum_plan_value == sb.sum_plan_value
        assert sa.sum_tick_inf_s == sb.sum_tick_inf_s
        assert sa.total_detections == sb.total_detections


# ---------------------------------------------------------------------------
# causality: no dispatch before its inputs exist
# ---------------------------------------------------------------------------


class TestCausality:
    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_launch_after_emission_property(self, seed):
        self._check_causality(seed)

    def test_launch_after_emission_fixed(self):
        for seed in (0, 3, 11):
            self._check_causality(seed)

    @staticmethod
    def _check_causality(seed):
        rng = np.random.default_rng(seed)
        policy = ["sync", "deadline", "async"][seed % 3]
        frames = int(rng.integers(2, 6))
        server = _oracle_pod(int(rng.integers(2, 7)), frames=frames,
                             seed0=int(rng.integers(0, 1000)),
                             devices=int(rng.choice([0, 8])),
                             policy=policy)
        server.run(range(frames))
        for tl in server.timelines:
            for e in tl.events:
                # inputs exist before the dispatch launches, and the
                # launch respects the group serialisation
                assert e.launch_s >= e.emitted_s
                assert e.complete_s == e.launch_s + e.cost_s
        assert not len(server.queues) and not server._inflight


# ---------------------------------------------------------------------------
# deadline ordering
# ---------------------------------------------------------------------------


def _queued(variant, deadline, slot=0, age=0, emitted=0.0):
    return QueuedRequest(
        request=InferenceRequest(
            region=sroi_mod.SRoI(center=(0.0, 0.0), fov=(1.0, 1.0)),
            variant=variant, slot=slot, special=False),
        owner=None, backend=None, deadline=deadline, age=age,
        emitted_s=emitted)


class TestDeadlineOrder:
    def test_tightest_deadline_first_then_weighted_sjf(self):
        variants = profiles.make_ladder(seed=0)
        tiny, csp = variants[0], variants[2]
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        for i in range(3):
            q.put(_queued(csp, 1.8, slot=i))
        for i in range(2):
            q.put(_queued(tiny, 0.5, slot=3 + i))
        ops = DeadlineOrderPolicy().plan_drain(
            q, q.buckets, None, GroupClock(),
            chunk_cost=lambda name, b: (0.5 if "csp" in name else 0.05) * b)
        assert [(o.variant, o.take) for o in ops] == [
            (tiny.name, 2), (csp.name, 3)]
        # equal deadlines: cost PER REQUEST decides (a cheap b=1 chunk
        # must not jump a b=8 batch serving eight frames)
        q2 = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        for i in range(8):
            q2.put(_queued(csp, 1.0, slot=i))
        q2.put(_queued(tiny, 1.0, slot=8))
        ops = DeadlineOrderPolicy().plan_drain(
            q2, q2.buckets, None, GroupClock(),
            chunk_cost=lambda name, b:
                (0.1 * (1 + (b - 1) * 0.15)) if "csp" in name else 0.09)
        # csp batch of 8: 0.205/8 = 0.026 per request < tiny's 0.09
        assert [(o.variant, o.take) for o in ops] == [
            (csp.name, 8), (tiny.name, 1)]

    def test_same_variant_chunks_stay_fifo(self):
        """A variant's own chunks never reorder (FIFO pops would hand
        the sorted keys the wrong items)."""
        variants = profiles.make_ladder(seed=0)
        csp = variants[2]
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        for i in range(8):  # first chunk: loose deadlines
            q.put(_queued(csp, 2.0, slot=i))
        q.put(_queued(csp, 0.1, slot=8))  # residual chunk: tight
        ops = DeadlineOrderPolicy().plan_drain(
            q, q.buckets, None, GroupClock(),
            chunk_cost=lambda name, b: 0.1 * b)
        assert [(o.variant, o.take) for o in ops] == [
            (csp.name, 8), (csp.name, 1)]

    def test_blocking_chunk_inherits_blocked_deadline(self):
        """EDF with precedence: a loose chunk standing (FIFO) in front
        of a tight chunk of the same variant must sort with the TIGHT
        key — a re-slotting scheme that lets the loose chunk squat on
        the tight chunk's won position would run a deadline-2.0 chunk
        before another variant's deadline-1.6 one."""
        variants = profiles.make_ladder(seed=0)
        v, w = variants[2], variants[3]
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        for i in range(8):
            q.put(_queued(v, 2.0, slot=i))      # v chunk 1: loose
        q.put(_queued(v, 1.2, slot=8))          # v chunk 2: tight
        q.put(_queued(w, 1.6, slot=9))          # w: in between
        ops = DeadlineOrderPolicy().plan_drain(
            q, q.buckets, None, GroupClock(),
            chunk_cost=lambda name, b: 0.1 * b)
        # v's whole FIFO chain inherits the 1.2 deadline it blocks, so
        # BOTH v chunks precede w — never v(2.0), w(1.6), v(1.2)
        assert [(o.variant, o.take) for o in ops] == [
            (v.name, 8), (v.name, 1), (w.name, 1)]

    def test_absolute_due_time_under_staggered_arrivals(self):
        """EDF orders by ABSOLUTE due time (emitted_s + budget), not
        the bare relative budget.  Stream A's request (emitted 0.0,
        budget 1.0) is due at 1.0; stream B's (emitted 0.9, budget
        0.5) is due at 1.4 — A must dispatch first even though B's
        relative budget is tighter.  The old relative-budget key
        sorted B (0.5 < 1.0) first; harmless while every emission
        shared a tick boundary (emitted_s identical), wrong the
        moment arrivals stagger."""
        variants = profiles.make_ladder(seed=0)
        a, b = variants[2], variants[3]
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        q.put(_queued(a, 1.0, slot=0, emitted=0.0))   # due 1.0
        q.put(_queued(b, 0.5, slot=1, emitted=0.9))   # due 1.4
        ops = DeadlineOrderPolicy().plan_drain(
            q, q.buckets, None, GroupClock(),
            chunk_cost=lambda name, n: 0.1 * n)
        assert [o.variant for o in ops] == [a.name, b.name]
        # same budgets, staggered emissions: earlier emission is due
        # earlier (the relative key was blind to this — a pure tie)
        q2 = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        q2.put(_queued(b, 1.0, slot=0, emitted=0.7))  # due 1.7
        q2.put(_queued(a, 1.0, slot=1, emitted=0.2))  # due 1.2
        ops = DeadlineOrderPolicy().plan_drain(
            q2, q2.buckets, None, GroupClock(),
            chunk_cost=lambda name, n: 0.1 * n)
        assert [o.variant for o in ops] == [a.name, b.name]

    def test_carried_request_gains_urgency(self):
        """A request carried across ticks keeps its original emission
        time, so under the absolute key it eventually precedes every
        fresher request — even one with a tighter relative budget.
        (Old key: the carried 1.5-budget request lost to the fresh
        0.5-budget one forever, no matter how long it waited.)"""
        variants = profiles.make_ladder(seed=0)
        a, b = variants[2], variants[3]
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        q.put(_queued(a, 1.5, slot=0, emitted=0.0, age=2))  # due 1.5
        q.put(_queued(b, 0.5, slot=1, emitted=2.0))         # due 2.5
        ops = DeadlineOrderPolicy().plan_drain(
            q, q.buckets, None, GroupClock(),
            chunk_cost=lambda name, n: 0.1 * n)
        assert [o.variant for o in ops] == [a.name, b.name]

    def test_deadline_run_same_results_lower_event_e2e(self):
        """On the cheap-sorts-last ladder the deadline order keeps the
        exact detections and tick cost of sync but completes frames
        earlier on the event clock."""
        ladder = profiles.make_ladder()
        variants = [ladder[0], ladder[4]]  # tiny sorts AFTER p6

        def budget_fn(s):
            return 1.2 + 0.4 * (s % 3)

        runs = {}
        for policy in ("sync", "deadline"):
            server = _oracle_pod(8, frames=8, policy=policy,
                                 variants=variants, budget_fn=budget_fn)
            runs[policy] = server.run(range(8))
        sync, dl = runs["sync"], runs["deadline"]
        assert dl.total_detections == sync.total_detections
        assert dl.sum_tick_inf_s == sync.sum_tick_inf_s
        assert sorted(dl.batch_sizes) == sorted(sync.batch_sizes)
        assert float(np.mean(dl.event_e2e)) < float(np.mean(sync.event_e2e))


# ---------------------------------------------------------------------------
# async drain: carry-over + overlap pricing
# ---------------------------------------------------------------------------


class TestAsyncDrain:
    def test_residual_withheld_only_when_busy_or_critical(self):
        variants = profiles.make_ladder(seed=0)
        tiny, csp = variants[0], variants[2]
        buckets = ShapeBuckets((1, 2, 4, 8))

        # loose deadlines: this test exercises the busy/critical-path
        # carry mechanics alone (the synthetic chunk costs dwarf a real
        # budget, and deadline-aware carry would rightly refuse); the
        # deadline interplay is pinned by
        # test_deadline_aware_carry_staggered below
        def fill(q):
            for i in range(9):  # csp: chunks [8, 1] — 1 is residual
                q.put(_queued(csp, 50.0, slot=i))
            for i in range(2):  # tiny: single sub-bucket chunk [2]
                q.put(_queued(tiny, 50.0, slot=9 + i))

        cost = {csp.name: 0.5, tiny.name: 0.01}

        def chunk_cost(name, b):
            return cost[name] * b

        # single implicit group: it is trivially the critical path, so
        # both residuals carry
        q = VariantQueues(buckets)
        fill(q)
        ops = AsyncDrainPolicy().plan_drain(q, buckets, None, GroupClock(),
                                            chunk_cost=chunk_cost)
        assert [(o.variant, o.take) for o in ops] == [(csp.name, 8)]

        # distinct groups: only the critical (expensive) group's
        # residual carries; the idle cheap group dispatches in full
        class _Group:
            def __init__(self, index):
                self.index = index
                self.n_devices = 1

        class _Placement:
            def group_for(self, name):
                return _Group(0 if "csp" in name else 1)

        q = VariantQueues(buckets)
        fill(q)
        ops = AsyncDrainPolicy().plan_drain(q, buckets, _Placement(),
                                            GroupClock(),
                                            chunk_cost=chunk_cost)
        assert [(o.variant, o.take) for o in ops] == [
            (csp.name, 8), (tiny.name, 2)]

        # a busy group carries its residual regardless of load — and a
        # heavy enough carry-in shifts the critical path, so the other
        # group's residual now dispatches in full
        q = VariantQueues(buckets)
        fill(q)
        clock = GroupClock()
        clock.dispatch(1, 5.0)  # tiny's group still busy, now critical
        ops = AsyncDrainPolicy().plan_drain(q, buckets, _Placement(), clock,
                                            chunk_cost=chunk_cost)
        assert [(o.variant, o.take) for o in ops] == [
            (csp.name, 8), (csp.name, 1)]

    def test_deadline_aware_carry_staggered(self):
        """A residual chunk carries only while the merged batch still
        meets the TIGHTEST withheld member's absolute due time; with
        staggered deadlines the tightest member governs, and deadlines
        outside the withheld residual have no vote."""
        variants = profiles.make_ladder(seed=0)
        csp = variants[2]
        buckets = ShapeBuckets((1, 2, 4, 8))

        def drain(deadlines):
            q = VariantQueues(buckets)
            for i, d in enumerate(deadlines):
                q.put(_queued(csp, d, slot=i))
            return AsyncDrainPolicy().plan_drain(
                q, buckets, None, GroupClock(),
                chunk_cost=lambda name, b: 0.2 * b)

        # 9 requests -> chunks [8, 1]; the single group is trivially
        # critical, and the carried residual's projected completion is
        # expected load (1.8s) + its own merged forward (0.2s) = 2.0s
        ops = drain([2.5] * 9)
        assert [(o.variant, o.take) for o in ops] == [(csp.name, 8)]
        # stagger the residual member tighter: 2.0s > 1.9s due, so the
        # chunk dispatches NOW instead of carrying past its deadline
        ops = drain([2.5] * 8 + [1.9])
        assert [(o.variant, o.take) for o in ops] == [
            (csp.name, 8), (csp.name, 1)]
        # a tight deadline OUTSIDE the withheld residual has no vote
        # (that request dispatches this tick anyway)
        ops = drain([1.9] + [2.5] * 8)
        assert [(o.variant, o.take) for o in ops] == [(csp.name, 8)]
        # deadline-free requests are always carry-eligible
        ops = drain([None] * 9)
        assert [(o.variant, o.take) for o in ops] == [(csp.name, 8)]

    def test_carry_age_bound_forces_dispatch(self):
        """A request carried once (age >= max_carry) pins its chunk
        into the next drain — no starvation."""
        variants = profiles.make_ladder(seed=0)
        csp = variants[2]
        buckets = ShapeBuckets((1, 2, 4, 8))
        q = VariantQueues(buckets)
        q.put(_queued(csp, 1.8, slot=0, age=1))
        ops = AsyncDrainPolicy().plan_drain(q, buckets, None, GroupClock(),
                                            chunk_cost=lambda n, b: 0.1)
        assert [(o.variant, o.take) for o in ops] == [(csp.name, 1)]

    def test_carried_requests_replay_their_emission_frame(self):
        """A ``set_frame`` (simulation) backend must sample the ground
        truth of the frame that EMITTED each request, not whatever
        frame the tick advanced to — carried requests would otherwise
        observe the future (the real pixel backend is immune: the
        pixels travel inside the request)."""
        variants = profiles.make_ladder(seed=0)
        csp = variants[2]

        class _FrameRecorder:
            def __init__(self):
                self.frame = None
                self.calls = []

            def set_frame(self, f):
                self.frame = f

            def infer_srois_batched(self, items, variant):
                self.calls.append((self.frame, len(items)))
                return [[] for _ in items]

        backend = _FrameRecorder()
        backend.set_frame(7)  # the tick has advanced past emission
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        for i in range(3):  # carried from frame 5
            item = _queued(csp, 1.8, slot=i, age=1)
            item.backend, item.frame_idx = backend, 5
            q.put(item)
        for i in range(2):  # this tick's emission, frame 7
            item = _queued(csp, 1.8, slot=3 + i)
            item.backend, item.frame_idx = backend, 7
            q.put(item)
        results, dispatches = q.drain_ops([(csp.name, 5)])
        assert len(results) == 5
        assert len(dispatches) == 1  # still ONE dispatch in the schedule
        # ...executed as two replays, each at its emission frame
        assert backend.calls == [(5, 3), (7, 2)]

    def test_flush_closed_form_matches_event_charge(self):
        """The flush charge is the latency model's tick_overlap_delay
        closed form (carry-in + serialised drain, max over groups) —
        it must agree with the event clock it generalises."""
        server = _oracle_pod(8, frames=6, devices=8, policy="async")
        lat = server.loops[0].latency_model
        for f in range(6):
            server.step(f)
        n_ticks = len(server.timelines)
        before = server.stats.sum_tick_inf_s
        start = server.clock.now
        server.flush()
        for tl in server.timelines[n_ticks:]:
            np.testing.assert_allclose(
                lat.tick_overlap_delay(tl.group_costs, tl.carry_in),
                max((e.complete_s for e in tl.events), default=tl.start)
                - tl.start, rtol=1e-12)
        # the flush billed the whole remaining horizon
        assert server.stats.sum_tick_inf_s - before == pytest.approx(
            server.clock.horizon() - start)

    def test_drain_ops_ages_leftovers(self):
        variants = profiles.make_ladder(seed=0)
        csp = variants[2]
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        backend = OracleBackend(make_video(n_frames=4, n_objects=5, seed=0))
        for i in range(3):
            item = _queued(csp, 1.8, slot=i)
            item.backend = backend
            q.put(item)
        q.drain_ops([(csp.name, 2)])
        assert [it.age for it in q.peek(csp.name)] == [1]
        with pytest.raises(ValueError):
            q.drain_ops([(csp.name, 5)])  # more than queued
        with pytest.raises(ValueError):
            q.drain_ops([(csp.name, 0)])

    def test_async_conserves_frames_and_settles(self):
        server = _oracle_pod(8, frames=8, devices=8, policy="async")
        stats = server.run(range(8))
        assert stats.frames == 64  # every emitted frame finishes
        assert stats.total_detections > 0
        assert not len(server.queues) and not server._inflight
        assert stats.carried_requests > 0  # the policy actually carried
        # carried dispatches really overlapped: some launch strictly
        # inside a tick (after its start) or before the barrier would
        # have allowed
        carried_events = [e for tl in server.timelines for e in tl.events
                          if e.carried]
        assert carried_events

    def test_async_strictly_undercuts_sync_mean_tick(self):
        """The nightly gate's test-scale twin: at 8 streams / 2
        variants the async policy's mean event-clock tick is strictly
        below the sync barrier's."""
        sync = _oracle_pod(8, frames=8, devices=8, policy="sync")
        asy = _oracle_pod(8, frames=8, devices=8, policy="async")
        ss, sa = sync.run(range(8)), asy.run(range(8))
        assert sa.mean_tick < ss.mean_tick
        # fewer dispatch fixed costs: carried residuals merged
        assert sa.dispatches < ss.dispatches
        assert sa.frames == ss.frames

    def test_async_max_carry_validation(self):
        with pytest.raises(ValueError):
            AsyncDrainPolicy(max_carry=0)


# ---------------------------------------------------------------------------
# shared projected load (solve_pod export)
# ---------------------------------------------------------------------------


class TestProjectedLoadShared:
    def test_solve_pod_exports_group_load(self):
        from repro.serving import pod_allocation

        variants = profiles.make_ladder()[3:5]
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        rng = np.random.default_rng(0)
        problems = []
        for _ in range(3):
            r = 2
            acc = np.vstack([np.zeros(r),
                             rng.uniform(0.2, 0.9, (len(variants), r))])
            d_pre = np.vstack([np.zeros(r),
                               rng.uniform(0.01, 0.1, (len(variants), r))])
            d_inf = np.vstack([np.zeros(r),
                               rng.uniform(0.1, 0.6, (len(variants), r))])
            problems.append(pod_allocation.StreamProblem(acc, d_pre, d_inf,
                                                         budget=1.5))
        sol = pod_allocation.solve_pod(problems, variants, lat)
        assert sol.projected_load  # exported
        assert sol.projected_tick == max(sol.projected_load.values())
        load = pod_allocation.projected_group_load(
            sol.counts, variants, lat, ShapeBuckets())
        assert load == sol.projected_load

    def test_policy_consumes_exported_load_plus_carried(self):
        """With a projection supplied, the async policy uses it for
        this tick's emissions instead of recomputing — and adds ONLY
        the carried (age > 0) queue items the projection cannot know
        about, on the same chunk curve."""
        policy = AsyncDrainPolicy()
        variants = profiles.make_ladder(seed=0)
        csp = variants[2]
        empty = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        load = policy._group_load(empty, empty.buckets, None,
                                  lambda n, b: 0.1 * b, {0: 1.25, 1: 0.5})
        assert load == {0: 1.25, 1: 0.5}  # nothing carried: verbatim
        q = VariantQueues(ShapeBuckets((1, 2, 4, 8)))
        q.put(_queued(csp, 1.8, slot=0, age=1))   # carried residual
        q.put(_queued(csp, 1.8, slot=1, age=0))   # this tick's emission
        load = policy._group_load(q, q.buckets, None,
                                  lambda n, b: 0.1 * b, {0: 1.25})
        assert load == {0: pytest.approx(1.25 + 0.1)}  # + carried only
        # no projection: the WHOLE queue is priced from the curve
        load = policy._group_load(q, q.buckets, None,
                                  lambda n, b: 0.1 * b, None)
        assert load == {0: pytest.approx(0.2)}

    def test_pod_allocate_feeds_projection_to_drain(self):
        server = _oracle_pod(4, frames=3, devices=8,
                             policy=AsyncDrainPolicy(pod_allocate=True))
        seen = []
        orig = server.policy.plan_drain

        def spy(*args, **kwargs):
            seen.append(kwargs.get("projected_load"))
            return orig(*args, **kwargs)

        server.policy.plan_drain = spy
        server.run(range(3))
        assert seen and all(pl is not None for pl in seen)


# ---------------------------------------------------------------------------
# pod-level tick-charge hooks: resolved once, conflicts are errors
# ---------------------------------------------------------------------------


class _HalfTickLat(OmniSenseLatencyModel):
    """A latency model whose pod-tick charge is half the barrier max
    (a distinctive curve, so charging through the wrong model shows)."""

    def tick_inference_delay(self, group_costs) -> float:
        return 0.5 * max(group_costs, default=0.0)

    def tick_overlap_delay(self, group_costs, carry_in=None) -> float:
        carry = carry_in or {}
        return 0.5 * max((carry.get(g, 0.0) + c
                          for g, c in group_costs.items()), default=0.0)


class _HookFreeLat:
    """Wraps a latency model, exposing only the per-dispatch surface —
    no pod-level tick hooks (a stream with "no opinion")."""

    def __init__(self, inner):
        self._inner = inner

    def delays(self, srois, variants):
        return self._inner.delays(srois, variants)

    def batched_inference_delay(self, variant, b):
        return self._inner.batched_inference_delay(variant, b)


class TestTickHookResolution:
    @staticmethod
    def _pod(lat_fn, n_streams=2, policy=None):
        variants = profiles.make_ladder()[3:5]
        loops, backends = [], []
        for s in range(n_streams):
            backend = OracleBackend(make_video(n_frames=12, n_objects=30,
                                               seed=200 + s))
            backends.append(backend)
            loops.append(OmniSenseLoop(variants, lat_fn(s), backend,
                                       budget_s=1.8))
        return PodServer(loops, backends, max_batch=8, policy=policy)

    def test_conflicting_tick_curves_rejected_at_construction(self):
        """A pod mixing latency models with DIFFERENT tick curves has
        no well-defined tick charge; the old per-dispatch ``or
        getattr`` silently charged whichever stream dispatched first."""
        base = OmniSenseLatencyModel(profiles.paper_profile(),
                                     NetworkModel())
        half = _HalfTickLat(profiles.paper_profile(), NetworkModel())
        with pytest.raises(ValueError, match="conflicting"):
            self._pod(lambda s: base if s == 0 else half)

    def test_same_class_instances_do_not_conflict(self):
        """Many instances of one latency-model class share the curve
        function — that's agreement, not a conflict."""
        server = self._pod(lambda s: OmniSenseLatencyModel(
            profiles.paper_profile(), NetworkModel()))
        stats = server.run(range(3))
        assert stats.frames == 2 * 3

    def test_charge_independent_of_stream_order(self):
        """One stream's model provides the (distinctive) tick curve,
        the other has no opinion: the charge must come from the
        providing model no matter which position it sits in — the old
        first-dispatch resolution made it an ordering lottery."""
        half = _HalfTickLat(profiles.paper_profile(), NetworkModel())
        runs = {}
        for order in ("half-first", "half-last"):
            server = self._pod(
                lambda s, o=order: half if (s == 0) == (o == "half-first")
                else _HookFreeLat(half))
            assert server._tick_lat is not None
            runs[order] = server.run(range(4)).sum_tick_inf_s
        assert runs["half-first"] == pytest.approx(runs["half-last"])
        # and it is genuinely the half curve, not the barrier fallback
        barrier = self._pod(lambda s: OmniSenseLatencyModel(
            profiles.paper_profile(), NetworkModel())).run(range(4))
        assert runs["half-first"] == pytest.approx(
            0.5 * barrier.sum_tick_inf_s)


# ---------------------------------------------------------------------------
# flush: bounded settling + diagnostic failure
# ---------------------------------------------------------------------------


class TestRebalancePoint:
    """``SchedulePolicy.rebalance_point`` (PR 8): the policy owns WHEN
    placement rebalances may fire — PodServer consults the hook
    wherever it used to call ``placement.maybe_rebalance()``
    unconditionally."""

    def test_barrier_policies_rebalance_every_emission(self):
        """The base rule is every emission — bit-identical to the
        pre-hook hard-wired timing — even while a group is busy (the
        barrier never starts a tick with carry-in anyway)."""
        clock = GroupClock()
        clock.dispatch(0, 5.0)
        for policy in (SyncTickPolicy(), DeadlineOrderPolicy()):
            assert policy.rebalance_point(None, clock, {})

    def test_async_policy_waits_for_capacity_boundary(self):
        """Async carry prices in-flight dispatches against the current
        partition: moving devices mid-carry would invalidate that, so
        the hook defers until every group is free."""
        policy = AsyncDrainPolicy()
        clock = GroupClock()
        assert policy.rebalance_point(None, clock, {})  # all free
        clock.dispatch(0, 2.0)
        assert not policy.rebalance_point(None, clock, {})  # carrying
        clock.advance(2.0)
        assert policy.rebalance_point(None, clock, {})  # boundary


class TestFlushDepth:
    def test_deep_async_carry_settles_within_bound(self):
        """A pod with carried work and deep queues settles without
        tripping the round bound (the bound keys to max_carry and the
        deepest queue, so legitimate tails always fit)."""
        server = _oracle_pod(6, frames=6,
                             policy=AsyncDrainPolicy(max_carry=3))
        stats = server.run(range(6))
        assert stats.frames == 6 * 6
        assert not len(server.queues) and not server._inflight

    def test_unsettleable_pod_raises_diagnostic(self):
        """An in-flight frame whose requests were never queued can
        never complete; flush must raise a RuntimeError naming the
        stream instead of tripping a bare assert."""
        from repro.serving.server import _InFlightFrame

        server = _oracle_pod(2, frames=6)
        for f in range(3):
            server.step(f)
        loop, backend = server.loops[0], server.backends[0]
        pending = None
        for f in range(3, 6):  # first frame that actually plans work
            backend.set_frame(f)
            pending = loop.begin_frame(None)
            if pending.requests:
                break
        assert pending is not None and pending.requests
        entry = _InFlightFrame(loop=loop, pending=pending,
                               emitted_s=server.clock.now,
                               done_s=server.clock.now,
                               frame_idx=3, stream=0)
        server._inflight.append(entry)
        server._by_owner[id(pending)] = entry
        with pytest.raises(RuntimeError, match="stream 0"):
            server.flush()


# ---------------------------------------------------------------------------
# real replica groups: one async-drain tick under the multidevice lane
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
class TestAsyncMultiDevice:
    def test_async_carry_over_on_real_replica_groups(self):
        """One async-drain carry cycle on REAL sharded replica groups:
        residuals carried past a tick still execute through the
        shard_map path and every frame finishes."""
        import jax

        if len(jax.devices()) < 8:
            pytest.skip("needs 8 local devices (XLA_FLAGS="
                        "--xla_force_host_platform_device_count=8)")
        import dataclasses as dc

        from repro.models import detector as det_mod
        from repro.serving.placement import VariantPlacement
        from repro.serving.scheduler import JaxDetectorBackend

        rng = np.random.default_rng(5)
        n_streams, n_frames = 4, 3
        cfgs = [dc.replace(det_mod.PAPER_LADDER[i], input_size=64,
                           n_classes=8) for i in range(2)]
        params = [det_mod.init_params(jax.random.PRNGKey(i), c)
                  for i, c in enumerate(cfgs)]
        variants = profiles.make_ladder(n_categories=8, seed=0)[:2]
        backend = JaxDetectorBackend(
            cfgs, params, conf=0.01, use_kernel=False, max_det=4,
            buckets=ShapeBuckets((1, 2, 4, 8), resolutions=(64,)))
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        frames = {(s, f): rng.random((64, 128, 3)).astype(np.float32)
                  for s in range(n_streams) for f in range(n_frames)}
        loops = []
        for s in range(n_streams):
            loop = OmniSenseLoop(variants, lat, backend, budget_s=4.0,
                                 n_categories=8, explore_every=0)
            loop.seed_history([sroi_mod.Detection(
                box=np.array([rng.uniform(-2, 2), rng.uniform(-0.8, 0.8),
                              0.5, 0.5]), category=int(rng.integers(8)),
                score=0.9) for _ in range(2)])
            loops.append(loop)
        placement = VariantPlacement(variants, devices=jax.devices()[:8])
        server = PodServer(loops, [backend] * n_streams, max_batch=8,
                           frame_source=lambda s, f: frames[(s, f)],
                           placement=placement, policy="async")
        stats = server.run(range(n_frames))
        assert stats.frames == n_streams * n_frames
        assert not len(server.queues) and not server._inflight
        # the sharded jit cache stays bounded by the bucket ladder even
        # with carried chunks changing batch shapes across ticks
        n_buckets = len(backend.buckets.batch_sizes)
        assert backend.trace_count <= 2 * n_buckets * len(cfgs)

"""Open-loop traffic: arrival clocks, churn, admission, conservation.

Property tests (hypothesis, optional) pin the arrival process's
invariants — strictly monotone per-stream clocks, seeded
reproducibility, time-ordered merges — and the open-loop serving
conservation law: every arrival is exactly one of admitted / rejected /
missed, and every admitted frame finishes.  Fixed-seed twins keep the
same pins when hypothesis is absent.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.runtime import (ADMIT, DEGRADE, REJECT, AdmissionPolicy,
                                   AsyncDrainPolicy, SloAdmissionPolicy,
                                   SyncTickPolicy, make_admission)
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer, format_open_loop_report
from repro.serving.traffic import Arrival, ArrivalProcess, ChurnEvent, \
    StreamClock

# ---------------------------------------------------------------------------
# stream clocks
# ---------------------------------------------------------------------------


class TestStreamClock:
    def test_unjittered_clock_ticks_at_fps(self):
        clock = StreamClock(stream=0, fps=2.0)
        times = [clock.next_arrival() for _ in range(5)]
        np.testing.assert_allclose(times, [0.5, 1.0, 1.5, 2.0, 2.5])

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 1000), stream=st.integers(0, 64),
           fps=st.floats(0.1, 30.0), jitter=st.floats(0.0, 1.0))
    def test_clock_strictly_monotone(self, seed, stream, fps, jitter):
        """Multiplicative lognormal jitter on a positive interval can
        never stall or reverse the clock."""
        clock = StreamClock(stream, fps, jitter, seed)
        times = [clock.next_arrival() for _ in range(50)]
        assert all(b > a for a, b in zip(times, times[1:]))
        assert times[0] > 0

    def test_seeded_reproducibility_and_stream_independence(self):
        a1 = [StreamClock(0, 1.0, 0.3, seed=7).next_arrival()
              for _ in range(1)]
        runs = [[StreamClock(0, 1.0, 0.3, seed=7).next_arrival()
                 for _ in range(20)] for _ in range(2)]
        assert runs[0] == runs[1]  # same (seed, stream) -> same draws
        del a1
        other_stream = [StreamClock(1, 1.0, 0.3, seed=7).next_arrival()
                        for _ in range(20)]
        other_seed = [StreamClock(0, 1.0, 0.3, seed=8).next_arrival()
                      for _ in range(20)]
        assert runs[0] != other_stream  # streams never share sequences
        assert runs[0] != other_seed

    def test_rate_trace_scales_intervals(self):
        """A 2x burst segment halves the inter-arrival interval for
        exactly the emissions falling inside it."""
        clock = StreamClock(0, fps=1.0, rate_trace=((3.0, 2.0),))
        times = [clock.next_arrival() for _ in range(7)]
        np.testing.assert_allclose(
            times, [1.0, 2.0, 3.0, 3.5, 4.0, 4.5, 5.0])

    def test_invalid_args_rejected(self):
        with pytest.raises(ValueError):
            StreamClock(0, fps=0.0)
        with pytest.raises(ValueError):
            StreamClock(0, fps=1.0, jitter=-0.1)
        with pytest.raises(ValueError):
            StreamClock(0, fps=1.0, rate_trace=((0.0, -1.0),))


# ---------------------------------------------------------------------------
# the merged arrival process
# ---------------------------------------------------------------------------


class TestArrivalProcess:
    def test_merge_is_time_ordered_with_contiguous_frame_indices(self):
        proc = ArrivalProcess(n_streams=3, fps=1.5, jitter=0.2, seed=3,
                              horizon_s=12.0)
        arr = proc.arrivals()
        assert arr == sorted(arr, key=lambda a: (a.t_s, a.stream))
        for s in range(3):
            idxs = [a.frame_idx for a in arr if a.stream == s]
            assert idxs == list(range(len(idxs)))  # 0,1,2,... per stream

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 500), n=st.integers(1, 6),
           jitter=st.floats(0.0, 0.5))
    def test_seeded_process_reproducible(self, seed, n, jitter):
        mk = lambda: ArrivalProcess(n, fps=1.0, jitter=jitter, seed=seed,
                                    horizon_s=8.0).arrivals()
        assert mk() == mk()

    def test_churn_gates_emissions_without_fabricating(self):
        """Disconnect windows emit nothing; the camera timeline keeps
        running, so reconnect resumes the SAME clock (no burst of
        fabricated catch-up frames) and frame indices stay contiguous."""
        churn = (ChurnEvent(4.0, 0, False), ChurnEvent(8.0, 0, True))
        gated = ArrivalProcess(2, fps=1.0, seed=0, horizon_s=12.0,
                               churn=churn).arrivals()
        free = ArrivalProcess(2, fps=1.0, seed=0, horizon_s=12.0).arrivals()
        s0 = [a for a in gated if a.stream == 0]
        assert all(not (4.0 <= a.t_s < 8.0) for a in s0)
        # stream 1 is untouched by stream 0's churn
        assert [a.t_s for a in gated if a.stream == 1] == \
            [a.t_s for a in free if a.stream == 1]
        # emissions outside the gap share the free-run clock times
        free_s0 = {a.t_s for a in free if a.stream == 0}
        assert all(a.t_s in free_s0 for a in s0)
        assert [a.frame_idx for a in s0] == list(range(len(s0)))

    def test_late_joiner_starts_disconnected(self):
        churn = (ChurnEvent(6.0, 1, True),)
        arr = ArrivalProcess(2, fps=1.0, seed=0, horizon_s=10.0,
                             churn=churn).arrivals()
        s1 = [a.t_s for a in arr if a.stream == 1]
        assert s1 and min(s1) >= 6.0

    def test_offered_rate_tracks_fps(self):
        proc = ArrivalProcess(4, fps=2.0, seed=1, horizon_s=50.0)
        assert proc.offered_rate() == pytest.approx(8.0, rel=0.05)


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------


class TestAdmissionPolicy:
    def test_registry_and_defaults(self):
        assert isinstance(make_admission(None), AdmissionPolicy)
        assert isinstance(make_admission("slo"), SloAdmissionPolicy)
        p = SloAdmissionPolicy(slack=2.0)
        assert make_admission(p) is p
        with pytest.raises(ValueError):
            make_admission("drop-everything")
        # every schedule policy carries the hook; default admits all
        assert SyncTickPolicy().admission.name == "admit-all"
        assert AsyncDrainPolicy(admission="slo").admission.name == "slo"

    def test_slo_verdict_ladder(self):
        p = SloAdmissionPolicy()
        kw = dict(plan_cost_s=0.5, degraded_cost_s=0.1, slo_s=1.0)
        assert p.decide(backlog_s=0.2, **kw) == ADMIT       # 0.7 <= 1
        assert p.decide(backlog_s=0.7, **kw) == DEGRADE     # 1.2 > 1 > 0.8
        assert p.decide(backlog_s=1.5, **kw) == REJECT      # even degraded
        assert p.decide(backlog_s=9.9, plan_cost_s=1.0, degraded_cost_s=1.0,
                        slo_s=None) == ADMIT                # no SLO -> admit


# ---------------------------------------------------------------------------
# open-loop serving: conservation + SLO behaviour
# ---------------------------------------------------------------------------


def _open_pod(n_streams, policy=None, seed0=300, budget=1.8, variants=None):
    variants = variants if variants is not None \
        else profiles.make_ladder()[3:5]
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    loops, backends = [], []
    for s in range(n_streams):
        backend = OracleBackend(make_video(n_frames=64, n_objects=30,
                                           seed=seed0 + s))
        backends.append(backend)
        loops.append(OmniSenseLoop(variants, lat, backend, budget_s=budget))
    return PodServer(loops, backends, max_batch=8, policy=policy)


class TestOpenLoopServing:
    def _conservation(self, stats):
        assert stats.arrivals == (stats.admitted + stats.rejected
                                  + stats.missed)
        assert stats.frames == stats.admitted  # every admitted finishes
        assert stats.degraded <= stats.admitted

    def test_conservation_across_policies(self):
        for policy in (None, "deadline", AsyncDrainPolicy(),
                       SyncTickPolicy(admission="slo")):
            server = _open_pod(3, policy=policy)
            traffic = ArrivalProcess(3, fps=0.8, jitter=0.2, seed=5,
                                     horizon_s=15.0)
            stats = server.run_open_loop(traffic, slo_s=2.5)
            assert stats.arrivals == len(traffic.arrivals())
            self._conservation(stats)
            assert not len(server.queues) and not server._inflight

    def test_missed_frames_counted_under_carry(self):
        """With async carry a stream's previous frame can still be in
        flight when the next arrival fires; the depth-1 camera buffer
        drops (and counts) the newcomer instead of fabricating a queue
        behind it.  The budget must be loose: deadline-aware carry
        refuses to withhold chunks a tight deadline could not survive,
        and without carry nothing stays in flight long enough to miss."""
        server = _open_pod(3, policy=AsyncDrainPolicy(max_carry=3),
                           budget=6.0)
        stats = server.run_open_loop(
            ArrivalProcess(3, fps=3.0, jitter=0.1, seed=2, horizon_s=8.0))
        self._conservation(stats)
        assert stats.carried_requests > 0  # carry actually engaged
        assert stats.missed > 0

    def test_churned_stream_serves_both_sessions(self):
        server = _open_pod(2)
        churn = (ChurnEvent(4.0, 1, False), ChurnEvent(9.0, 1, True))
        traffic = ArrivalProcess(2, fps=0.6, seed=4, horizon_s=14.0,
                                 churn=churn)
        stats = server.run_open_loop(traffic)
        self._conservation(stats)
        s1 = [a.t_s for a in traffic.arrivals() if a.stream == 1]
        assert any(t < 4.0 for t in s1) and any(t >= 9.0 for t in s1)

    def test_queue_delay_and_violations_grow_with_offered_load(self):
        out = {}
        for fps in (0.2, 3.0):
            server = _open_pod(3)
            stats = server.run_open_loop(
                ArrivalProcess(3, fps=fps, seed=6, horizon_s=10.0),
                slo_s=2.0)
            out[fps] = stats
        assert out[3.0].mean_queue_delay > out[0.2].mean_queue_delay
        assert out[3.0].slo_violations > out[0.2].slo_violations
        assert out[0.2].slo_violations == 0

    def test_slo_admission_degrades_before_rejecting(self):
        """Under pressure the SLO policy first forces the P1 variant;
        the degraded plans emit only skip/P1 requests.  (Full ladder:
        P1 is the cheap on-device variant, so the degrade band —
        backlogs where only the degraded plan fits the envelope — is
        wide enough to be exercised.)"""
        server = _open_pod(4, policy=SyncTickPolicy(admission="slo"),
                           variants=profiles.make_ladder())
        p1_name = server.loops[0].variants[0].name
        degraded_variants = set()
        orig = server._admit_arrival

        def spy(arrival):
            before = server.stats.degraded
            orig(arrival)
            if server.stats.degraded > before:
                e = server._stream_frame.get(arrival.stream)
                if e is not None:
                    degraded_variants.update(
                        r.variant.name for r in e.pending.requests)

        server._admit_arrival = spy
        stats = server.run_open_loop(
            ArrivalProcess(4, fps=2.5, seed=8, horizon_s=8.0), slo_s=1.0)
        self._conservation(stats)
        assert stats.degraded > 0
        assert degraded_variants <= {p1_name}

    def test_slo_admission_noop_under_light_load(self):
        """At light load admission must not interfere: identical
        service to admit-all (the bench gate's 'matching' half).
        Light means service time genuinely under the arrival spacing
        (cheap variants here) — equal-fps unjittered streams collide
        at every emission, so jitter keeps the clocks staggered."""
        runs = {}
        for admission in (None, "slo"):
            server = _open_pod(2, policy=SyncTickPolicy(admission=admission),
                               variants=profiles.make_ladder()[:2])
            runs[admission] = server.run_open_loop(
                ArrivalProcess(2, fps=0.15, jitter=0.3, seed=9,
                               horizon_s=20.0),
                slo_s=2.5)
        assert runs["slo"].rejected == 0 and runs["slo"].degraded == 0
        assert runs["slo"].frames == runs[None].frames
        assert runs["slo"].goodput_frames == runs[None].goodput_frames
        assert runs["slo"].event_e2e == runs[None].event_e2e

    def test_slo_admission_beats_admit_all_at_saturation(self):
        """The bench gate's other half: at saturation, shedding load
        keeps served frames inside the SLO — strictly more goodput.
        Gated on USEFUL goodput (frames that did inference work):
        under congestion collapse the starved predictor plans nothing
        for most frames, and those instant empty completions must not
        count in admit-all's favour."""
        runs = {}
        for admission in (None, "slo"):
            server = _open_pod(4, policy=SyncTickPolicy(admission=admission))
            runs[admission] = server.run_open_loop(
                ArrivalProcess(4, fps=2.0, seed=10, horizon_s=10.0),
                slo_s=1.5)
        assert (runs["slo"].useful_goodput_frames
                > runs[None].useful_goodput_frames)

    def test_pod_allocate_policy_served_with_slo_envelope(self):
        """Pod-allocate policies run open-loop since solve_pod accepts
        the SLO capacity envelope directly: same-instant arrivals plan
        jointly through the fixed point and conservation holds."""
        server = _open_pod(3, policy=SyncTickPolicy(pod_allocate=True))
        stats = server.run_open_loop(
            ArrivalProcess(3, fps=0.8, jitter=0.2, seed=5, horizon_s=12.0),
            slo_s=2.5)
        self._conservation(stats)
        assert stats.pod_ticks > 0
        assert not len(server.queues) and not server._inflight

    def test_pod_allocate_without_slo_deprecated(self):
        """The envelope-less regime (pod fixed point with no SLO) is
        the one-PR deprecation window: it still runs, but warns."""
        server = _open_pod(2, policy=SyncTickPolicy(pod_allocate=True))
        with pytest.warns(DeprecationWarning, match="slo_s"):
            stats = server.run_open_loop(
                ArrivalProcess(2, fps=0.5, seed=0, horizon_s=6.0))
        self._conservation(stats)

    def test_causality_and_report(self):
        server = _open_pod(3, policy=AsyncDrainPolicy())
        traffic = ArrivalProcess(3, fps=1.0, jitter=0.3, seed=11,
                                 horizon_s=10.0)
        stats = server.run_open_loop(traffic, slo_s=2.0)
        for tl in server.timelines:
            for e in tl.events:
                assert e.launch_s >= e.emitted_s - 1e-9
                assert e.complete_s == pytest.approx(e.launch_s + e.cost_s)
        lines = format_open_loop_report(stats, traffic.horizon_s)
        assert any("arrivals" in ln for ln in lines)
        assert any("SLO" in ln for ln in lines)

    def test_arrivals_accepted_as_plain_iterable(self):
        server = _open_pod(1)
        stats = server.run_open_loop(
            [Arrival(0.5, 0, 0), Arrival(1.0, 0, 1), Arrival(2.0, 0, 2)])
        assert stats.arrivals == 3
        self._conservation(stats)

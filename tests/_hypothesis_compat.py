"""Degrade gracefully when ``hypothesis`` is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly.  With hypothesis present (see
``requirements-dev.txt``) the real names are re-exported and the
property tests run as usual; without it, each ``@given`` test becomes a
single skipped test with a clear reason, and fixed-example tests in the
same module keep running — the suite stays collectible either way.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in for a hypothesis strategy (chainable)."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    class _StrategiesModule:
        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

    st = _StrategiesModule()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # Deliberately NOT functools.wraps: pytest must see the
            # bare (*a, **k) signature, or it would treat the original
            # hypothesis-strategy parameters as missing fixtures.
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed; property test "
                            "skipped (pip install -r requirements-dev.txt)")

            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

"""Substrate tests: optimizer, compression, checkpoint, elastic, pipeline."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager, mesh_signature
from repro.data.pipeline import Prefetcher, lm_batches
from repro.distributed import elastic
from repro.training import compression as comp
from repro.training import optimizer as opt_mod


class TestOptimizer:
    def test_adamw_converges_on_quadratic(self):
        opt = opt_mod.adamw(lr=0.1)
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        target = jnp.array([1.0, 1.0])
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state = opt.update(grads, params, state)
        np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                                   atol=1e-2)

    def test_sgd_momentum(self):
        opt = opt_mod.sgd(lr=0.05, momentum=0.9)
        params = {"w": jnp.array(4.0)}
        state = opt.init(params)
        for _ in range(300):
            params, state = opt.update({"w": 2 * params["w"]}, params, state)
        assert abs(float(params["w"])) < 1e-2

    def test_clip_by_global_norm(self):
        grads = {"a": jnp.full((4,), 100.0)}
        clipped, norm = opt_mod.clip_by_global_norm(grads, 1.0)
        assert float(norm) == 200.0
        assert np.isclose(float(opt_mod.global_norm(clipped)), 1.0, rtol=1e-5)


class TestCompression:
    def test_bf16_error_feedback_unbiased(self):
        grads = {"w": jnp.array([1e-4, 1.0, 3.14159])}
        state = comp.CompressionState.zeros_like(grads)
        acc = jnp.zeros(3)
        for _ in range(50):
            payload, state = comp.bf16_compress(grads, state)
            acc = acc + comp.bf16_decompress(payload)["w"]
        # mean of decompressed equals the true gradient (error feedback)
        np.testing.assert_allclose(np.asarray(acc) / 50,
                                   np.asarray(grads["w"]), rtol=1e-2)

    def test_topk_roundtrip_and_ratio(self):
        rng = np.random.default_rng(0)
        grads = {"w": jnp.asarray(rng.standard_normal(1000).astype(np.float32))}
        state = comp.CompressionState.zeros_like(grads)
        payload, state = comp.topk_compress(grads, state, ratio=0.1)
        dense = comp.topk_decompress(payload, grads)
        # only k entries nonzero; they match the largest magnitudes
        nz = np.count_nonzero(np.asarray(dense["w"]))
        assert nz == 100
        assert comp.compression_ratio(payload, grads) < 0.25

    def test_topk_error_feedback_conservation(self):
        # exact EF invariant: cumulative decompressed + residual ==
        # cumulative true gradient (nothing is ever lost, only delayed)
        grads = {"w": jnp.array([10.0, 0.1, -3.0, 0.02])}
        state = comp.CompressionState.zeros_like(grads)
        total = jnp.zeros(4)
        n = 30
        for _ in range(n):
            payload, state = comp.topk_compress(grads, state, ratio=0.25)
            total = total + comp.topk_decompress(payload, grads)["w"]
        np.testing.assert_allclose(
            np.asarray(total + state.residual["w"]),
            np.asarray(grads["w"]) * n, rtol=1e-5)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=2)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "step": jnp.asarray(7)}
        mgr.save(7, state, {"shape": [1, 1], "axes": ["data", "model"]})
        assert mgr.latest_step() == 7
        restored = mgr.restore(7, state)
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=2)
        state = {"w": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert mgr.steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        state = {"w": jnp.ones((32, 32))}
        mgr.save_async(11, state)
        mgr.wait()
        assert mgr.latest_step() == 11

    def test_crash_leaves_no_partial_commit(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        # simulate a crash: a stale .tmp dir must be ignored and reused
        (tmp_path / "step_5.tmp").mkdir()
        (tmp_path / "step_5.tmp" / "garbage").write_text("x")
        assert mgr.latest_step() is None
        mgr.save(5, {"w": jnp.zeros(2)})
        assert mgr.latest_step() == 5

    def test_structure_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"w": jnp.zeros(3)})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.zeros(3), "extra": jnp.zeros(1)})


class TestElastic:
    def test_failure_detection(self):
        tr = elastic.HealthTracker(4, beat_interval=1.0, max_missed=2)
        for t in range(5):
            for h in (0, 1, 2):
                tr.heartbeat(h, float(t), 1.0)
            tr.tick(float(t))
        assert tr.healthy() == [0, 1, 2]
        assert 3 not in tr.healthy()

    def test_straggler_detection(self):
        tr = elastic.HealthTracker(4)
        for h, t in [(0, 1.0), (1, 1.1), (2, 0.9), (3, 5.0)]:
            tr.heartbeat(h, 0.0, t)
        assert tr.stragglers() == [3]

    def test_remesh_preserves_model_axis(self):
        plan = elastic.remesh_plan((2, 16, 16), ("pod", "data", "model"), 300)
        assert plan["shape"][2] == 16
        assert plan["devices_used"] <= 300
        assert plan["checkpoint_compatible"]

    def test_remesh_single_pod_shrink(self):
        plan = elastic.remesh_plan((16, 16), ("data", "model"), 200)
        assert plan["shape"] == (8, 16)
        assert plan["batch_scale"] == 0.5

    def test_remesh_infeasible(self):
        with pytest.raises(ValueError):
            elastic.remesh_plan((16, 16), ("data", "model"), 8)

    def test_straggler_policy(self):
        pol = elastic.StragglerPolicy(margin=1.3)
        out = pol.step({0: 1.0, 1: 1.05, 2: 0.95, 3: 4.0})
        assert out["drop"] == [3]
        assert np.isclose(out["grad_scale"], 4 / 3)


class TestPipeline:
    def test_prefetcher_order_and_completion(self):
        items = list(Prefetcher(iter(range(10)), depth=3))
        assert items == list(range(10))

    def test_prefetcher_propagates_errors(self):
        def gen():
            yield 1
            raise RuntimeError("boom")
        it = Prefetcher(gen())
        assert next(it) == 1
        with pytest.raises(RuntimeError):
            list(it)

    def test_lm_batches_learnable(self):
        gen = lm_batches(vocab=17, batch=4, seq=8, n_batches=3)
        batches = list(gen)
        assert len(batches) == 3
        assert batches[0]["tokens"].shape == (4, 8)
        # targets are the shifted stream (teacher forcing layout)
        assert batches[0]["tokens"].dtype == np.int32

"""Fleet tier: multi-pod routing, elastic scaling, conservation.

Pins the fleet's contracts: the conservation law (every arrival is
routed to exactly one pod and lands in exactly one of that pod's
admitted / rejected / missed buckets — across routings AND scale
events), routing determinism under fixed seeds, the retiring-pod
drain (in-flight frames finish, streams re-route with reason
``migrate``), consistent-hashing arc stability on grow, and the
1-pod fleet's bit-identity with the plain ``PodServer`` open loop.
"""

import pytest

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.distributed.elastic import HealthTracker, serving_scale_plan
from repro.serving import profiles
from repro.serving.fleet import (AffinityRouting, ElasticController,
                                 FleetServer, LeastLoadedRouting,
                                 RoutingPolicy, default_affinity_key,
                                 format_fleet_report, make_fleet_pods,
                                 make_routing)
from repro.serving.network import NetworkModel
from repro.serving.replay import stats_fingerprint
from repro.serving.runtime import make_policy
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer
from repro.serving.telemetry import MemorySink
from repro.serving.traffic import Arrival, ArrivalProcess, split_arrivals

# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def _streams(n_streams, seed0=300, budget=0.9):
    variants = profiles.make_ladder()[3:5]
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    return make_fleet_pods(
        n_streams,
        make_backend=lambda s: OracleBackend(
            make_video(n_frames=64, n_objects=30 + 5 * (s % 4),
                       seed=seed0 + s)),
        make_loop=lambda s, b: OmniSenseLoop(variants, lat, b,
                                             budget_s=budget),
        pod_server_kwargs=lambda pid: {
            "max_batch": 8,
            "policy": make_policy("async", admission="slo")},
    )


def _fleet(n_streams, pods, routing="least-loaded", elastic=None,
           telemetry=None, seed0=300):
    _, _, make_pod = _streams(n_streams, seed0=seed0)
    return FleetServer(make_pod, pods, routing=routing, elastic=elastic,
                       telemetry=telemetry)


def _traffic(n_streams, seed=5, horizon_s=12.0, fps=0.8):
    return ArrivalProcess(n_streams, fps=fps, jitter=0.2, seed=seed,
                          horizon_s=horizon_s)


def _check_conservation(fstats, n_arrivals):
    # fleet-wide: every arrival was routed to exactly one pod
    assert fstats.arrivals == n_arrivals
    assert fstats.arrivals == sum(
        s.arrivals for s in fstats.pod_stats)
    assert fstats.arrivals == sum(
        s.admitted + s.rejected + s.missed for s in fstats.pod_stats)
    for s in fstats.pod_stats:  # per pod: every admitted frame finished
        assert s.arrivals == s.admitted + s.rejected + s.missed
        assert s.frames == s.admitted


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


class TestRouting:
    def test_make_routing_resolves_names_and_instances(self):
        assert isinstance(make_routing("least-loaded"), LeastLoadedRouting)
        assert isinstance(make_routing("affinity"), AffinityRouting)
        inst = LeastLoadedRouting()
        assert make_routing(inst) is inst

    def test_make_routing_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown routing"):
            make_routing("round-robin")
        with pytest.raises(ValueError, match="unknown routing"):
            make_routing(None)

    def test_base_policy_is_abstract(self):
        fleet = _fleet(2, 1)
        with pytest.raises(NotImplementedError):
            RoutingPolicy().assign(0, fleet)

    def test_least_loaded_balances_new_streams(self):
        fleet = _fleet(8, 2)
        for s in range(8):
            fleet._route(Arrival(stream=s, t_s=0.1 * (s + 1), frame_idx=0))
        counts = fleet.assigned_counts()
        assert sorted(counts.values()) == [4, 4]

    def test_affinity_colocates_same_key_streams(self):
        """The default key buckets streams by content class (s % 4):
        all streams of one class hash to the SAME ring arc, hence the
        same pod — that is the whole point of affinity routing."""
        fleet = _fleet(16, 4, routing="affinity")
        for s in range(16):
            fleet._route(Arrival(stream=s, t_s=0.1 * (s + 1), frame_idx=0))
        for s in range(16):
            assert (fleet.assignment[s]
                    == fleet.assignment[s % 4]), s
            assert default_affinity_key(s) == f"c{s % 4}"

    def test_affinity_arcs_stable_on_grow(self):
        """Consistent hashing: adding a pod may only move keys TO the
        new pod — no key ever moves between two old pods."""
        fleet = _fleet(4, 3, routing="affinity")
        keys = [f"k{i}" for i in range(64)]
        fleet.routing.affinity_key = lambda s: keys[s]
        before = {i: fleet.routing.assign(i, fleet) for i in range(64)}
        new_pid = fleet.grow(t_s=1.0, pressure=0.5)
        after = {i: fleet.routing.assign(i, fleet) for i in range(64)}
        moved = [i for i in range(64) if after[i] != before[i]]
        assert all(after[i] == new_pid for i in moved)
        assert len(moved) < 64  # most arcs stay put

    def test_least_loaded_marks_overflow_for_reroute_on_scale(self):
        fleet = _fleet(6, 2)
        for s in range(6):
            fleet._route(Arrival(stream=s, t_s=0.1 * (s + 1), frame_idx=0))
        fleet.grow(t_s=1.0, pressure=0.5)
        # 6 streams over 3 pods -> balanced share 2; each old pod holds
        # 3, so exactly one stream per old pod is marked for reroute
        marked = [s for s in range(6) if fleet.routing.wants_reroute(s)]
        assert len(marked) == 2


# ---------------------------------------------------------------------------
# fleet serving: conservation + determinism
# ---------------------------------------------------------------------------


class TestFleetServing:
    @pytest.mark.parametrize("routing", ["least-loaded", "affinity"])
    def test_conservation_across_routings(self, routing):
        fleet = _fleet(9, 3, routing=routing)
        traffic = _traffic(9)
        fstats = fleet.run_open_loop(traffic, slo_s=2.0)
        _check_conservation(fstats, len(traffic.arrivals()))
        assert fstats.routes >= 9  # every stream was routed at least once

    @pytest.mark.parametrize("routing", ["least-loaded", "affinity"])
    def test_fixed_seed_determinism(self, routing):
        """Two identical fleet runs produce bit-identical fingerprints:
        per-pod ServeStats AND the routing/scaling control plane."""
        runs = []
        for _ in range(2):
            fleet = _fleet(6, 2, routing=routing)
            runs.append(stats_fingerprint(
                fleet.run_open_loop(_traffic(6), slo_s=2.0)))
        assert runs[0] == runs[1]

    def test_single_pod_fleet_bit_identical_to_pod_server(self):
        """A 1-pod fleet is the degenerate case: same arrivals, same
        batching rounds, same stats as the plain PodServer open loop."""
        fleet = _fleet(5, 1)
        fstats = fleet.run_open_loop(_traffic(5), slo_s=2.0)

        loops, backends, _ = _streams(5)
        solo = PodServer(loops, backends, max_batch=8,
                         policy=make_policy("async", admission="slo"))
        sstats = solo.run_open_loop(_traffic(5), slo_s=2.0)
        assert (stats_fingerprint(fstats)["pods"][0]
                == stats_fingerprint(sstats))

    def test_route_telemetry_tagged_with_pods(self):
        sink = MemorySink()
        fleet = _fleet(4, 2, telemetry=sink)
        fleet.run_open_loop(_traffic(4, horizon_s=6.0), slo_s=2.0)
        routes = [e for e in sink.events if e["event"] == "route"]
        assert {e["reason"] for e in routes} == {"new"}
        assert {e["stream"] for e in routes} == set(range(4))
        # the _PodSink wrapper tags every per-pod server event too
        assert all("pod" in e for e in sink.events)
        assert any(e["event"] == "dispatch_launch" for e in sink.events)

    def test_fleet_requires_at_least_one_pod(self):
        _, _, make_pod = _streams(2)
        with pytest.raises(ValueError, match="n_pods"):
            FleetServer(make_pod, 0)

    def test_retire_guards(self):
        fleet = _fleet(2, 2)
        with pytest.raises(ValueError, match="not active"):
            fleet.retire(7, t_s=0.0, pressure=0.0)
        fleet.retire(1, t_s=0.0, pressure=0.0)
        with pytest.raises(ValueError, match="last active pod"):
            fleet.retire(0, t_s=0.0, pressure=0.0)

    def test_fleet_stats_aggregation_and_report(self):
        fleet = _fleet(6, 2)
        horizon = 12.0
        fstats = fleet.run_open_loop(_traffic(6, horizon_s=horizon),
                                     slo_s=2.0)
        assert fstats.n_pods == 2
        assert fstats.admitted == sum(s.admitted for s in fstats.pod_stats)
        assert fstats.frames == sum(s.frames for s in fstats.pod_stats)
        assert len(fstats.event_e2e) == sum(
            len(s.event_e2e) for s in fstats.pod_stats)
        pct = fstats.event_e2e_percentiles()
        assert pct[50] <= pct[95] <= pct[99]
        report = format_fleet_report(fstats, horizon)
        assert any("useful goodput" in line for line in report)


# ---------------------------------------------------------------------------
# split_arrivals: the static-assignment equivalence helper
# ---------------------------------------------------------------------------


class TestSplitArrivals:
    def test_partition_preserves_order(self):
        arrivals = _traffic(4).arrivals()
        assignment = {0: 0, 1: 1, 2: 0, 3: 1}
        parts = split_arrivals(arrivals, assignment)
        assert sum(len(sub) for sub in parts.values()) == len(arrivals)
        for pod, sub in parts.items():
            assert all(assignment[a.stream] == pod for a in sub)
            assert all(a.t_s <= b.t_s for a, b in zip(sub, sub[1:]))

    def test_unassigned_stream_raises(self):
        arrivals = _traffic(3).arrivals()
        with pytest.raises(ValueError, match="no pod assignment"):
            split_arrivals(arrivals, {0: 0, 1: 0})


# ---------------------------------------------------------------------------
# elastic scaling
# ---------------------------------------------------------------------------


class TestElasticController:
    def test_invalid_args_rejected(self):
        with pytest.raises(ValueError):
            ElasticController(min_pods=0)
        with pytest.raises(ValueError):
            ElasticController(min_pods=3, max_pods=2)
        with pytest.raises(ValueError):
            ElasticController(interval_s=0.0)
        with pytest.raises(ValueError):
            ElasticController(sustain=0)

    def test_grow_on_sustained_pressure(self):
        """Fabricated hot intervals (high shed fraction) grow one pod
        per sustained window, capped at max_pods; a single hot interval
        is NOT enough (hysteresis)."""
        fleet = _fleet(4, 1)
        ctl = ElasticController(min_pods=1, max_pods=3, interval_s=1.0,
                                sustain=2)
        t = 0.0
        for _ in range(5):
            t += 1.0
            for pid in fleet.active:
                fleet.pods[pid].stats.arrivals += 10
                fleet.pods[pid].stats.rejected += 8  # pressure 0.8
            ctl.control(fleet, t)
        # hot at t=1,2 -> grow; hot at t=3,4 -> grow; capped at 3
        assert len(fleet.active) == 3
        assert fleet.scale_ups == 2
        t += 1.0
        for pid in fleet.active:
            fleet.pods[pid].stats.arrivals += 10
            fleet.pods[pid].stats.rejected += 8
        ctl.control(fleet, t)
        assert len(fleet.active) == 3  # max_pods respected

    def test_shrink_on_sustained_cold_respects_min_pods(self):
        fleet = _fleet(4, 3)
        ctl = ElasticController(min_pods=2, max_pods=3, interval_s=1.0,
                                sustain=2)
        t = 0.0
        for _ in range(6):  # zero-delta intervals: pressure 0.0
            t += 1.0
            ctl.control(fleet, t)
        assert len(fleet.active) == 2  # one retire, then floored
        assert fleet.scale_downs == 1

    def test_shrink_victim_prefers_empty_then_highest_id(self):
        fleet = _fleet(6, 3)
        # pods 0 and 1 hold streams, pod 2 is empty -> victim is 2
        fleet.assignment = {0: 0, 1: 0, 2: 1}
        assert ElasticController._pick_victim(fleet) == 2
        # all empty -> ties break to the HIGHEST id (founders persist)
        fleet.assignment = {}
        assert ElasticController._pick_victim(fleet) == 2

    def test_catch_up_after_lull_takes_one_action(self):
        """A long traffic lull spanning many intervals must not queue a
        burst of back-to-back scale actions."""
        fleet = _fleet(4, 3)
        ctl = ElasticController(min_pods=1, max_pods=3, interval_s=1.0,
                                sustain=1)
        ctl.control(fleet, 50.0)  # one cold step despite 50 intervals
        assert len(fleet.active) == 2
        assert fleet.scale_downs == 1

    def test_retiring_pod_drains_and_streams_migrate(self):
        """The drain contract across a real scale-down: the retired
        pod's admitted frames all finish, its streams re-route with
        reason ``migrate``, and the fleet-wide conservation law holds
        across the scale event."""
        sink = MemorySink()
        # always-cold controller: retires one pod per interval down to
        # min_pods while traffic is still arriving
        ctl = ElasticController(min_pods=1, max_pods=3, interval_s=3.0,
                                grow_threshold=2.0, shrink_threshold=1.1,
                                sustain=1)
        fleet = _fleet(6, 3, elastic=ctl, telemetry=sink)
        traffic = _traffic(6, horizon_s=15.0)
        fstats = fleet.run_open_loop(traffic, slo_s=2.0)
        assert fstats.scale_downs == 2
        assert len(fleet.active) == 1
        _check_conservation(fstats, len(traffic.arrivals()))
        migrations = [e for e in sink.events if e["event"] == "route"
                      and e["reason"] == "migrate"]
        assert migrations and fstats.migrations >= len(migrations)
        scale = [e for e in sink.events if e["event"] == "scale"]
        assert [e["action"] for e in scale] == ["shrink", "shrink"]
        # the drained pods kept nothing in flight
        for pid in set(fleet.pods) - set(fleet.active):
            assert not fleet.pods[pid]._inflight
            assert not len(fleet.pods[pid].queues)

    def test_grow_mid_run_serves_new_pod(self):
        """An always-hot controller grows to max_pods mid-run; the new
        pods receive re-routed streams and conservation holds."""
        ctl = ElasticController(min_pods=1, max_pods=3, interval_s=3.0,
                                grow_threshold=0.0, sustain=1)
        fleet = _fleet(6, 1, elastic=ctl)
        traffic = _traffic(6, horizon_s=15.0)
        fstats = fleet.run_open_loop(traffic, slo_s=2.0)
        assert fstats.scale_ups == 2
        assert len(fleet.active) == 3
        _check_conservation(fstats, len(traffic.arrivals()))

    def test_health_tracker_integration(self):
        """The controller heartbeats per-pod pressure into the training
        stack's HealthTracker: hosts appear via ensure_host, leave via
        remove_host, and stragglers() exposes the pressure outliers."""
        tracker = HealthTracker(0, beat_interval=8.0)
        fleet = _fleet(4, 3)
        ctl = ElasticController(min_pods=1, max_pods=3, interval_s=1.0,
                                sustain=99, tracker=tracker)
        for pid in (0, 1):  # light pressure on the founders...
            fleet.pods[pid].stats.arrivals += 10
            fleet.pods[pid].stats.rejected += 1
        fleet.pods[2].stats.arrivals += 10
        fleet.pods[2].stats.rejected += 10  # ...pod 2 sheds everything
        ctl.control(fleet, 1.0)
        assert set(tracker.hosts) >= {0, 1, 2}
        assert ctl.stragglers() == [2]
        tracker.remove_host(2)
        assert 2 not in tracker.hosts


class TestServingScalePlan:
    def test_even_split(self):
        plan = serving_scale_plan(8, 4)
        assert plan == {"n_pods": 4, "per_pod_devices": 2,
                        "devices_used": 8, "devices_idle": 0}

    def test_remainder_stays_idle(self):
        plan = serving_scale_plan(8, 3)
        assert plan["per_pod_devices"] == 2
        assert plan["devices_used"] == 6 and plan["devices_idle"] == 2

    def test_zero_devices(self):
        plan = serving_scale_plan(0, 4)
        assert plan["per_pod_devices"] == 0 and plan["devices_used"] == 0


# ---------------------------------------------------------------------------
# fleet replay: record -> check round trip
# ---------------------------------------------------------------------------


class TestFleetReplay:
    def test_record_then_replay_is_bit_identical(self, tmp_path):
        from repro.serving.replay import CorpusSpec, record, replay
        from repro.serving.telemetry import JsonlSink

        spec = CorpusSpec(mode="open", n_streams=4, frames=8,
                          budget_s=0.9, devices=4, max_batch=8,
                          policy="async", admission="slo", slo_s=2.0,
                          fps=0.8, jitter=0.2, horizon_s=8.0,
                          pods=2, routing="least-loaded")
        log = tmp_path / "fleet.jsonl"
        record(spec, JsonlSink(str(log)))
        result = replay(str(log))
        assert result.identical, result.drift

    def test_fleet_spec_requires_open_mode(self):
        from repro.serving.replay import CorpusSpec, build_fleet

        spec = CorpusSpec(mode="closed", n_streams=2, frames=4,
                          budget_s=0.9, devices=0, max_batch=8,
                          policy="sync", pods=2)
        with pytest.raises(ValueError):
            build_fleet(spec)

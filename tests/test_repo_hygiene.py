"""Repository hygiene gates, enforced in the fast tier (and CI).

  * no compiled Python artifacts (``__pycache__``/``*.pyc``) may ever
    be committed — they are machine-specific noise and mask real diffs;
  * ``PodServer.step`` must stay a real batched execution engine: the
    ``collections.Counter`` variant-batching *simulation* it replaced
    (PR 2) must not creep back in.
"""

import inspect
import re
import subprocess

import pytest

from repro.serving import server as server_mod

COMPILED = re.compile(r"(\.py[co]$|(^|/)__pycache__(/|$))")


def _tracked_files():
    try:
        out = subprocess.run(
            ["git", "ls-files"], capture_output=True, text=True, timeout=30,
            cwd=str(__import__("pathlib").Path(__file__).resolve().parent.parent))
    except (OSError, subprocess.TimeoutExpired):  # pragma: no cover
        pytest.skip("git unavailable")
    if out.returncode != 0:  # pragma: no cover - e.g. sdist without .git
        pytest.skip("not a git checkout")
    return out.stdout.splitlines()


def test_no_tracked_compiled_artifacts():
    offenders = [p for p in _tracked_files() if COMPILED.search(p)]
    assert not offenders, (
        f"compiled artifacts committed: {offenders}; "
        "remove them (git rm --cached) — .gitignore already excludes them")


def test_pod_server_has_no_counter_simulation():
    src = inspect.getsource(server_mod)
    assert "Counter" not in src, (
        "PodServer must batch variants through real per-variant queues "
        "(repro.serving.batching), not a collections.Counter simulation")
    assert "VariantQueues" in src

"""Algorithm 2 (latency-constrained allocation): exactness + properties."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import allocation


def rand_instance(rng, m, r):
    acc = rng.uniform(0, 1, (m, r))
    acc[0] = 0.0
    d_pre = rng.uniform(0.01, 0.2, (m, r))
    d_pre[0] = 0.0
    d_inf = rng.uniform(0.02, 0.6, (m, r))
    d_inf[0] = 0.0
    return acc, d_pre, d_inf


class TestExactness:
    @given(st.integers(0, 10_000), st.integers(2, 4), st.integers(1, 5),
           st.floats(0.1, 3.0))
    @settings(max_examples=60, deadline=None)
    def test_matches_bruteforce(self, seed, m, r, budget):
        rng = np.random.default_rng(seed)
        acc, d_pre, d_inf = rand_instance(rng, m, r)
        got = allocation.allocate(acc, d_pre, d_inf, budget)
        want = allocation.allocate_bruteforce(acc, d_pre, d_inf, budget)
        assert (got is None) == (want is None)
        if got is not None:
            assert np.isclose(got.value, want.value, atol=1e-9)

    def test_skip_always_feasible(self):
        rng = np.random.default_rng(0)
        acc, d_pre, d_inf = rand_instance(rng, 4, 6)
        plan = allocation.allocate(acc, d_pre, d_inf, budget=1e-9)
        assert plan is not None
        assert all(m == 0 for m in plan.models)
        assert plan.value == 0.0


class TestProperties:
    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_budget_respected(self, seed):
        rng = np.random.default_rng(seed)
        acc, d_pre, d_inf = rand_instance(rng, 5, 6)
        budget = float(rng.uniform(0.2, 2.0))
        plan = allocation.allocate(acc, d_pre, d_inf, budget)
        assert plan is not None
        lat = allocation.plan_latency(plan.models, d_pre, d_inf)
        assert lat <= budget + 1e-9
        assert np.isclose(lat, plan.t_done, atol=1e-9)

    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_monotone_in_budget(self, seed):
        rng = np.random.default_rng(seed)
        acc, d_pre, d_inf = rand_instance(rng, 4, 5)
        v_prev = -1.0
        for budget in (0.2, 0.5, 1.0, 2.0, 5.0):
            plan = allocation.allocate(acc, d_pre, d_inf, budget)
            assert plan.value >= v_prev - 1e-12
            v_prev = plan.value

    def test_pipelining_beats_serial(self):
        # pipelined latency never exceeds the serial sum
        rng = np.random.default_rng(7)
        acc, d_pre, d_inf = rand_instance(rng, 4, 6)
        models = (1, 2, 3, 1, 2, 3)
        pipelined = allocation.plan_latency(models, d_pre, d_inf)
        serial = sum(d_pre[m, j] + d_inf[m, j] for j, m in enumerate(models))
        assert pipelined <= serial + 1e-12

    def test_dominance_pruning_keeps_frontier(self):
        plans = [
            allocation.Plan(1.0, 1.0, 2.0, (1,)),
            allocation.Plan(1.0, 2.0, 3.0, (2,)),  # dominated
            allocation.Plan(0.5, 0.5, 1.0, (3,)),  # cheaper, kept
        ]
        kept = allocation._prune_dominated(plans)
        assert len(kept) == 2
        assert {p.models for p in kept} == {(1,), (3,)}

"""Correctness envelope of the device-resident fused tick (PR 9).

The fused path replaces per-crop projection dispatches and per-detection
back-projection dispatches with batched device programs, plus two
cross-tick reuse levers and a reduced-precision IoU variant.  Each lever
has an exactness (or bounded-error) contract pinned here:

  * batched gnomonic projection: rows are bit-identical across batch
    sizes, and the fused backend's detections are bit-identical to the
    staged per-crop path's (f32 mode);
  * crop cache: a sub-pixel region drift reuses the anchor's PI *and
    geometry*, so the drifted tick's detections are bit-identical to
    re-serving the anchor;
  * incremental NMS: recomputing only churned rows equals a full
    recompute exactly (row independence);
  * vectorised ``_row_to_dets``: one ``pi_box_to_sphbb`` dispatch per
    row, bit-equal to the per-detection loop it replaced;
  * bf16 SphIoU: keep-mask flips stay under the measured bound and only
    ever touch rows with an IoU near the 0.6 threshold.

Property tests follow the repo convention: a hypothesis ``@given`` form
plus a fixed-seed twin that runs without hypothesis installed.
"""

from __future__ import annotations

import dataclasses
import math
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import sphere  # noqa: E402
from repro.core import sroi as sroi_mod  # noqa: E402
from repro.core.sphere import IncrementalNms, pad_detection_rows  # noqa: E402
from repro.kernels.gnomonic.ops import project_srois_batched  # noqa: E402
from repro.models import detector as det_mod  # noqa: E402
from repro.serving import profiles  # noqa: E402
from repro.serving.batching import ShapeBuckets  # noqa: E402
from repro.serving.scheduler import JaxDetectorBackend  # noqa: E402

THR = 0.6
FOV = (math.radians(60), math.radians(60))


def _random_boxes(rng, n):
    return np.stack([rng.uniform(-3, 3, n), rng.uniform(-1.2, 1.2, n),
                     rng.uniform(0.3, 1.2, n), rng.uniform(0.3, 1.2, n)], -1)


def _dets_equal(a, b) -> bool:
    """Bitwise equality of two per-item detection-list sequences."""
    if len(a) != len(b):
        return False
    for row_a, row_b in zip(a, b):
        if len(row_a) != len(row_b):
            return False
        for da, db in zip(row_a, row_b):
            if (da.category != db.category or da.score != db.score
                    or not np.array_equal(np.asarray(da.box),
                                          np.asarray(db.box))):
                return False
    return True


@pytest.fixture(scope="module")
def detector():
    cfg = dataclasses.replace(det_mod.PAPER_LADDER[0], input_size=64,
                              n_classes=8)
    params = det_mod.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _backend(detector, **kw):
    cfg, params = detector
    kw.setdefault("buckets", ShapeBuckets((1, 2, 4, 8), resolutions=(64,)))
    return JaxDetectorBackend([cfg], [params], conf=0.01, use_kernel=False,
                              max_det=4, **kw)


def _regions(rng, n, fov=FOV):
    return [sroi_mod.SRoI(center=(float(rng.uniform(-2.5, 2.5)),
                                  float(rng.uniform(-0.9, 0.9))), fov=fov)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Batched projection + fused-vs-staged bit-identity
# ---------------------------------------------------------------------------


class TestFusedProjection:
    def test_rows_bit_identical_across_batch_sizes(self):
        """The batched projector at B=8 produces the exact rows the
        same program produces one crop at a time — the invariant that
        lets cached (anchor-batch) PIs mix freely with fresh ones."""
        rng = np.random.default_rng(0)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        centers = np.stack([rng.uniform(-2.5, 2.5, 8),
                            rng.uniform(-0.9, 0.9, 8)], -1)
        fovs = np.full((8, 2), FOV[0])
        full = np.asarray(project_srois_batched(
            [frame] * 8, centers, fovs, (32, 32)))
        ones = np.stack([np.asarray(project_srois_batched(
            [frame], centers[i:i + 1], fovs[i:i + 1], (32, 32)))[0]
            for i in range(8)])
        assert np.array_equal(full, ones)

    def test_fused_backend_matches_staged_bitwise(self, detector):
        """f32 acceptance: the fused tick (batched projection + crop
        cache + vectorised back-projection) produces bit-identical
        detections to the staged per-crop path at B=8."""
        rng = np.random.default_rng(1)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        variant = profiles.make_ladder(seed=0)[0]
        items = [(frame, r) for r in _regions(rng, 8)]
        fused = _backend(detector, fused=True)
        staged = _backend(detector, fused=False)
        out_fused = fused.infer_srois_batched(items, variant)
        out_staged = staged.infer_srois_batched(items, variant)
        assert sum(len(d) for d in out_fused) > 0
        assert _dets_equal(out_fused, out_staged)
        assert fused.crop_cache_misses == 8  # first tick: all cold


# ---------------------------------------------------------------------------
# Crop-cache reuse under sub-pixel drift
# ---------------------------------------------------------------------------


class TestCropCache:
    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_subpixel_drift_reuses_bit_identical_property(self, seed,
                                                          detector):
        self._check_drift(seed, detector)

    def test_subpixel_drift_reuses_bit_identical_fixed(self, detector):
        for seed in (0, 1, 2):
            self._check_drift(seed, detector)

    @staticmethod
    def _check_drift(seed, detector):
        """A tick whose regions drifted less than half the pixel pitch
        hits the crop cache for every crop, and its detections are
        bit-identical to re-serving the anchor regions (the cache
        returns the anchor's PI and back-projects through the anchor's
        geometry)."""
        rng = np.random.default_rng(seed)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        variant = profiles.make_ladder(seed=0)[0]
        size = 64
        px, py = FOV[0] / size, FOV[1] / size
        # anchors on pitch-quantisation bucket centres so any drift
        # under pitch/2 provably lands in the anchor's bucket
        anchors = [sroi_mod.SRoI(
            center=(round(float(rng.uniform(-2.5, 2.5)) / px) * px,
                    round(float(rng.uniform(-0.9, 0.9)) / py) * py),
            fov=FOV) for _ in range(4)]
        drifted = [sroi_mod.SRoI(
            center=(r.center[0] + float(rng.uniform(-0.45, 0.45)) * px,
                    r.center[1] + float(rng.uniform(-0.45, 0.45)) * py),
            fov=FOV) for r in anchors]
        backend = _backend(detector, fused=True)
        out_anchor = backend.infer_srois_batched(
            [(frame, r) for r in anchors], variant)
        hits0 = backend.crop_cache_hits
        out_drift = backend.infer_srois_batched(
            [(frame, r) for r in drifted], variant)
        assert backend.crop_cache_hits - hits0 == len(anchors)
        assert _dets_equal(out_anchor, out_drift)

    def test_different_frame_never_reuses(self, detector):
        """Same geometry on a DIFFERENT frame must miss: the content
        guard keeps id() reuse from aliasing across frames."""
        rng = np.random.default_rng(3)
        variant = profiles.make_ladder(seed=0)[0]
        regions = _regions(rng, 2)
        backend = _backend(detector, fused=True)
        frame_a = rng.random((64, 128, 3)).astype(np.float32)
        frame_b = rng.random((64, 128, 3)).astype(np.float32)
        backend.infer_srois_batched([(frame_a, r) for r in regions], variant)
        hits0 = backend.crop_cache_hits
        backend.infer_srois_batched([(frame_b, r) for r in regions], variant)
        assert backend.crop_cache_hits == hits0

    def test_cache_disabled_when_staged(self, detector):
        backend = _backend(detector, fused=False)
        assert backend.crop_cache_size == 0


# ---------------------------------------------------------------------------
# Incremental cross-tick NMS == full recompute
# ---------------------------------------------------------------------------


class _Det:
    def __init__(self, box, score):
        self.box = box
        self.score = score


def _random_rows(rng, b, base=None, churn=1.0):
    rows = []
    for r in range(b):
        if base is not None and rng.random() > churn:
            rows.append(base[r])
            continue
        n = int(rng.integers(0, 12))
        boxes = _random_boxes(rng, n)
        rows.append([_Det(boxes[i], float(rng.uniform(0.1, 1)))
                     for i in range(n)])
    return rows


class TestIncrementalNms:
    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_equals_full_recompute_property(self, seed):
        self._check_churn(seed)

    def test_equals_full_recompute_fixed(self):
        for seed in (0, 1, 2, 3, 4):
            self._check_churn(seed)

    @staticmethod
    def _check_churn(seed):
        """Across ticks that churn a random subset of rows (and change
        the padded N), the incremental keep-mask equals a from-scratch
        ``sph_nms_batch`` exactly, and unchurned rows hit the cache."""
        rng = np.random.default_rng(seed)
        b = int(rng.integers(2, 8))
        inc = IncrementalNms(THR, backend="host")
        keys = list(range(b))
        rows = None
        for _ in range(4):
            rows = _random_rows(rng, b, rows,
                                churn=float(rng.uniform(0.0, 0.7)))
            boxes, scores, mask = pad_detection_rows(rows)
            if not boxes.size:
                continue
            keep_inc = inc.suppress(keys, boxes, scores, mask)
            keep_full = sphere.sph_nms_batch(boxes, scores, mask,
                                             iou_threshold=THR,
                                             backend="host")
            assert np.array_equal(keep_inc, keep_full)
        assert inc.hits > 0 or inc.misses > 0

    def test_reuse_survives_padded_n_changes(self):
        """A row kept byte-identical must HIT even when other rows grow
        the padded N between ticks (padding is not part of the row's
        canonical form)."""
        rng = np.random.default_rng(7)
        inc = IncrementalNms(THR, backend="host")
        stable = _random_rows(rng, 1)[0]
        tick1 = [stable, _random_rows(rng, 1)[0]]
        tick2 = [stable, [_Det(b, 0.5) for b in _random_boxes(rng, 20)]]
        inc.suppress([0, 1], *pad_detection_rows(tick1))
        hits0 = inc.hits
        keep = inc.suppress([0, 1], *pad_detection_rows(tick2))
        assert inc.hits == hits0 + 1
        full = sphere.sph_nms_batch(*pad_detection_rows(tick2),
                                    iou_threshold=THR, backend="host")
        assert np.array_equal(keep, full)


# ---------------------------------------------------------------------------
# bf16 SphIoU keep-mask flip bound
# ---------------------------------------------------------------------------

# acceptance bound, mirrored by the nightly gate (check_regression.py):
# measured flip rate is ~0.1% on random box sets; 1% is the envelope.
BF16_FLIP_BOUND = 0.01
# rows with no IoU pair this close to the threshold must never flip
BF16_NEAR_MARGIN = 0.05


class TestBf16SphIoU:
    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_flip_bound_property(self, seed):
        self._check_flips(seed)

    def test_flip_bound_fixed(self):
        flips = total = 0
        for seed in (0, 1, 2, 3):
            f, t = self._check_flips(seed)
            flips += f
            total += t
        assert flips / total <= BF16_FLIP_BOUND

    @staticmethod
    def _check_flips(seed):
        """bf16 IoU may flip keep decisions only on rows holding a
        near-threshold pair, and at a rate under the gated bound."""
        rng = np.random.default_rng(seed)
        b, n = 8, 24
        boxes = _random_boxes(rng, b * n).reshape(b, n, 4)
        scores = rng.uniform(0.1, 1, (b, n))
        k32 = sphere.sph_nms_batch(boxes, scores, None, THR, backend="jit")
        k16 = sphere.sph_nms_batch(boxes, scores, None, THR, backend="jit",
                                   iou_dtype=jnp.bfloat16)
        diff = k32 != k16
        iou = np.stack([sphere.sph_iou_matrix_np(boxes[i].astype(np.float64),
                                                 boxes[i].astype(np.float64))
                        for i in range(b)])
        near = np.abs(iou - THR) <= BF16_NEAR_MARGIN
        np.einsum("bii->bi", near)[:] = False  # self-IoU is always 1
        far_rows = ~near.any(axis=(1, 2))
        assert not (diff.any(axis=1) & far_rows).any(), \
            "bf16 flipped a row with no near-threshold IoU pair"
        return int(diff.sum()), int(diff.size)

    def test_host_backend_rejects_iou_dtype(self):
        rng = np.random.default_rng(0)
        boxes = _random_boxes(rng, 8)[None]
        scores = rng.uniform(0.1, 1, (1, 8))
        with pytest.raises(ValueError, match="iou_dtype"):
            sphere.sph_nms_batch(boxes, scores, None, THR, backend="host",
                                 iou_dtype=jnp.bfloat16)


# ---------------------------------------------------------------------------
# Vectorised _row_to_dets == per-detection loop
# ---------------------------------------------------------------------------


class TestRowToDets:
    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_bit_equal_to_loop_property(self, seed, detector):
        self._check_row(seed, detector)

    def test_bit_equal_to_loop_fixed(self, detector):
        for seed in (0, 1, 2):
            self._check_row(seed, detector)

    @staticmethod
    def _check_row(seed, detector):
        """One vectorised ``pi_box_to_sphbb`` call over the row's live
        detections is bit-equal to the per-detection dispatch loop it
        replaced (including zero-score skipping and ordering)."""
        rng = np.random.default_rng(seed)
        backend = _backend(detector, fused=True)
        size = 64
        k = int(rng.integers(1, 9))
        boxes = np.sort(rng.uniform(0, size, (k, 2, 2)), axis=1)
        boxes = boxes.transpose(0, 2, 1).reshape(k, 4)[:, [0, 2, 1, 3]]
        scores = rng.uniform(0, 1, k) * (rng.random(k) < 0.7)
        classes = rng.integers(0, 8, k)
        region = sroi_mod.SRoI(center=(float(rng.uniform(-2.5, 2.5)),
                                       float(rng.uniform(-0.9, 0.9))),
                               fov=FOV)
        got = backend._row_to_dets(boxes, scores, classes, region, size)
        # the pre-vectorisation implementation, inlined as the oracle
        want = []
        for bx, s, c in zip(boxes, scores, classes):
            if s <= 0:
                continue
            sphbb = np.asarray(sphere.pi_box_to_sphbb(
                jnp.asarray(bx), jnp.asarray(region.center[0]),
                jnp.asarray(region.center[1]), region.fov, (size, size)))
            want.append(sroi_mod.Detection(box=sphbb, category=int(c),
                                           score=float(s)))
        assert len(got) == len(want)
        for dg, dw in zip(got, want):
            assert dg.category == dw.category
            assert dg.score == dw.score
            assert np.array_equal(np.asarray(dg.box), np.asarray(dw.box))


# ---------------------------------------------------------------------------
# Odd-N block clamp (satellite bugfix regression)
# ---------------------------------------------------------------------------


class TestBlockClamp:
    def test_clamp_is_lane_aligned(self):
        """8 < n < block must round UP to a multiple of 8: the old
        ``min(block, n)`` produced e.g. a 100-wide Pallas block for
        n=100, which Mosaic rejects on real TPUs."""
        from repro.kernels.sphiou.ops import _clamp_block

        for n in range(1, 300):
            blk = _clamp_block(256, n)
            assert blk % 8 == 0
            assert blk >= min(8, n)
            assert blk >= min(256, n)  # covers the padded problem
            assert blk <= 256

    def test_odd_n_matches_reference(self):
        """n=100, m=37 (both non-lane-aligned) through the default
        block clamp matches the numpy oracle."""
        from repro.kernels.sphiou.ops import sphiou_matrix

        rng = np.random.default_rng(0)
        a = jnp.asarray(_random_boxes(rng, 100), jnp.float32)
        b = jnp.asarray(_random_boxes(rng, 37), jnp.float32)
        got = np.asarray(sphiou_matrix(a, b))
        want = sphere.sph_iou_matrix_np(np.asarray(a, np.float64),
                                        np.asarray(b, np.float64))
        assert got.shape == (100, 37)
        np.testing.assert_allclose(got, want, atol=2e-5)

"""Multi-device sharded pod serving (PR 3).

Pins the placement subsystem and the device-aware tick model:

  * the greedy partition is a DISJOINT COVER of the devices — every
    device in exactly one replica group, every variant mapped to
    exactly one group, heavier variants get more devices;
  * popularity-EMA rebalancing swaps partitions atomically: every
    variant keeps a group at all times, so a rebalance with requests
    already queued never strands a non-empty queue;
  * ``sharded_inference_delay`` prices the largest per-device shard
    and reduces to the batched delay on one device;
    ``tick_inference_delay`` is max-over-groups (concurrent groups);
  * a placed PodServer tick produces BIT-IDENTICAL detections to the
    single-device path on the oracle backend — placement moves
    compute, never results;
  * (multidevice) the ``shard_map``-sharded Jax forward matches the
    unsharded batched path, and its jit retraces stay bounded by the
    bucket ladder.
"""

import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import sroi as sroi_mod
from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.models import detector as det_mod
from repro.serving import profiles
from repro.serving.batching import ShapeBuckets
from repro.serving.network import NetworkModel
from repro.serving.placement import VariantPlacement
from repro.serving.scheduler import (JaxDetectorBackend, OmniSenseLatencyModel,
                                     OracleBackend)
from repro.serving.server import PodServer

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 local devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _variants(n=5):
    return profiles.make_ladder(seed=0)[:n]


class TestPartition:
    def test_disjoint_cover_of_devices(self):
        """Every device lands in exactly one group and every variant
        maps to exactly one group, for any (V, D) combination."""
        for n_var in (1, 2, 3, 5):
            variants = _variants(n_var)
            for n_dev in (1, 2, 3, 5, 8, 16):
                p = VariantPlacement(variants, devices=list(range(n_dev)))
                seen = [d for g in p.groups for d in g.devices]
                assert sorted(seen) == list(range(n_dev)), (n_var, n_dev)
                assigned = [v for g in p.groups for v in g.variants]
                assert sorted(assigned) == sorted(v.name for v in variants)
                for v in variants:
                    assert p.group_for(v.name) in p.groups

    def test_heavier_variant_gets_more_devices(self):
        variants = _variants(2)
        heavy = dataclasses.replace(variants[1], infer_s=variants[0].infer_s * 5)
        p = VariantPlacement([variants[0], heavy], devices=list(range(12)))
        counts = p.device_counts()
        assert counts[heavy.name] > counts[variants[0].name]
        assert sum(counts.values()) == 12

    def test_more_variants_than_devices_shares_groups(self):
        variants = _variants(5)
        p = VariantPlacement(variants, devices=list(range(2)))
        assert len(p.groups) == 2
        for v in variants:  # every variant still routed
            assert p.group_for(v.name).n_devices >= 1

    def test_partition_deterministic(self):
        variants = _variants(4)
        a = VariantPlacement(variants, devices=list(range(8)))
        b = VariantPlacement(variants, devices=list(range(8)))
        assert [(g.variants, g.devices) for g in a.groups] == \
               [(g.variants, g.devices) for g in b.groups]

    def test_virtual_group_has_no_mesh(self):
        p = VariantPlacement.virtual(_variants(2), 4)
        with pytest.raises(TypeError):
            _ = p.groups[0].mesh

    def test_shard_batch_rounds_to_group_width(self):
        p = VariantPlacement.virtual(_variants(1), 3)
        g = p.groups[0]
        assert g.n_devices == 3
        assert [g.shard_batch(b) for b in (1, 2, 3, 4, 7)] == [3, 3, 3, 6, 9]

    def test_empty_inputs_rejected(self):
        with pytest.raises(ValueError):
            VariantPlacement([], devices=[0])
        with pytest.raises(ValueError):
            VariantPlacement(_variants(1), devices=[])


class TestRebalance:
    def test_popularity_shift_moves_devices(self):
        variants = _variants(5)
        p = VariantPlacement(variants, devices=list(range(8)))
        before = p.device_counts()
        hot = variants[0].name
        for _ in range(8):
            p.observe({hot: 50})
        assert p.maybe_rebalance()
        after = p.device_counts()
        assert after[hot] > before[hot]
        assert p.rebalances == 1
        # still a disjoint cover after the swap
        seen = [d for g in p.groups for d in g.devices]
        assert sorted(seen) == list(range(8))

    def test_small_shift_does_not_thrash(self):
        p = VariantPlacement(_variants(3), devices=list(range(8)))
        counts = {v.name: 10 for v in _variants(3)}
        p.observe(counts)
        assert not p.maybe_rebalance()  # uniform load, nothing to move
        assert p.rebalances == 0

    def test_rebalance_never_strands_a_nonempty_queue(self):
        """The nasty window: requests are already queued per variant
        when the allocator shift triggers a rebalance.  Every queued
        variant must still resolve to a live group and drain."""
        from repro.core.omnisense import InferenceRequest
        from repro.serving.batching import QueuedRequest, VariantQueues

        class _CountingBackend:
            semantic_batch = True

            def __init__(self):
                self.served = 0

            def infer_srois_batched(self, items, variant):
                self.served += len(items)
                return [[] for _ in items]

        variants = _variants(4)
        p = VariantPlacement(variants, devices=list(range(8)),
                             rebalance_threshold=0.0)
        backend = _CountingBackend()
        q = VariantQueues(ShapeBuckets((1, 2, 4)))
        for slot, v in enumerate(variants * 3):  # every queue non-empty
            q.put(QueuedRequest(
                request=InferenceRequest(
                    region=sroi_mod.SRoI(center=(0.0, 0.0), fov=(1.0, 1.0)),
                    variant=v, slot=slot, special=False),
                owner=None, backend=backend))
        n_queued = len(q)
        # allocator shift: one variant takes all the traffic
        for _ in range(8):
            p.observe({variants[-1].name: 100})
        assert p.maybe_rebalance()
        results, dispatches = q.drain(p)
        assert len(results) == n_queued and backend.served == n_queued
        assert len(q) == 0
        for d in dispatches:  # every dispatch routed to a live group
            assert d["group"] in p.groups


class TestDeviceAwareTickModel:
    def _lat(self):
        return OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())

    def test_one_device_reduces_to_batched(self):
        lat = self._lat()
        for v in _variants(5):
            for b in (1, 3, 8):
                assert lat.sharded_inference_delay(v, b, 1) == \
                    lat.batched_inference_delay(v, b)

    def test_shards_price_largest_per_device_batch(self):
        lat = self._lat()
        v = _variants(5)[3]
        assert lat.sharded_inference_delay(v, 8, 4) == \
            lat.batched_inference_delay(v, 2)
        assert lat.sharded_inference_delay(v, 7, 4) == \
            lat.batched_inference_delay(v, 2)  # ceil(7/4) = 2

    def test_more_devices_never_cost_more(self):
        lat = self._lat()
        v = _variants(5)[4]
        costs = [lat.sharded_inference_delay(v, 16, d) for d in (1, 2, 4, 8)]
        assert all(a >= b for a, b in zip(costs, costs[1:]))

    def test_invalid_device_count_rejected(self):
        with pytest.raises(ValueError):
            self._lat().sharded_inference_delay(_variants(1)[0], 4, 0)

    def test_tick_is_max_over_groups(self):
        lat = self._lat()
        assert lat.tick_inference_delay([1.0, 3.0, 2.0]) == 3.0
        assert lat.tick_inference_delay([]) == 0.0


def _oracle_pod(n_streams, seed0=40, budget=2.0):
    variants = profiles.make_ladder(seed=0)
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=16, n_objects=30, seed=seed0 + s)
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        b = OracleBackend(video)
        backends.append(b)
        loops.append(OmniSenseLoop(variants, lat, b, budget_s=budget))
    return variants, loops, backends


class TestPlacedPodServer:
    def test_placed_tick_bit_identical_to_single_device(self):
        """Placement moves compute across replica groups; results must
        be bit-identical to the single-device drain on the oracle."""
        n_streams, n_frames = 6, 8
        _, loops_a, backends_a = _oracle_pod(n_streams)
        variants, loops_b, backends_b = _oracle_pod(n_streams)
        single = PodServer(loops_a, backends_a, max_batch=8)
        placed = PodServer(loops_b, backends_b, max_batch=8,
                           placement=VariantPlacement.virtual(variants, 8))
        for f in range(n_frames):
            single.step(f)
            placed.step(f)
            for la, lb in zip(loops_a, loops_b):
                da, db = la._history[-1], lb._history[-1]
                assert len(da) == len(db)
                for a, b in zip(da, db):
                    np.testing.assert_array_equal(a.box, b.box)
                    assert a.score == b.score and a.category == b.category
        assert single.stats.total_detections == placed.stats.total_detections
        assert single.stats.total_detections > 0

    def test_tick_cost_is_max_over_groups_not_sum(self):
        variants, loops, backends = _oracle_pod(6)
        placement = VariantPlacement.virtual(variants, 8)
        server = PodServer(loops, backends, max_batch=8, placement=placement)
        stats = server.run(range(8))
        assert stats.ticks == 8
        # concurrent groups: the tick pays strictly less than the
        # serialised dispatch sum once >1 group is busy in some tick
        assert 0 < stats.sum_tick_inf_s < stats.sum_batched_inf_s
        assert stats.sharding_gain > 1.0
        util = stats.group_utilisation()
        assert util and all(0.0 <= u <= 1.0 + 1e-9 for u in util.values())
        # at least one group is the per-tick bottleneck somewhere
        assert max(util.values()) > 0.5

    def test_single_device_pod_keeps_sum_semantics(self):
        _, loops, backends = _oracle_pod(4)
        server = PodServer(loops, backends, max_batch=8)
        stats = server.run(range(6))
        assert stats.sum_tick_inf_s == pytest.approx(stats.sum_batched_inf_s)
        assert stats.sharding_gain == pytest.approx(1.0)

    def test_placement_missing_variant_rejected(self):
        variants, loops, backends = _oracle_pod(2)
        partial = VariantPlacement.virtual(variants[:2], 4)
        with pytest.raises(ValueError):
            PodServer(loops, backends, placement=partial)

    def test_virtual_group_prices_but_never_reaches_execution(self):
        """A virtual (simulation) placement must price the tick while
        real backends fall back to the PLAIN batched forward — a
        meshless group handed to the sharded path would crash."""
        from repro.core.omnisense import InferenceRequest
        from repro.serving.batching import QueuedRequest, VariantQueues

        class _LaunchBackend:
            def __init__(self):
                self.exec_groups = []

            def launch_srois_batched(self, items, variant, group=None):
                self.exec_groups.append(group)
                return lambda: [[] for _ in items]

        variants = _variants(2)
        placement = VariantPlacement.virtual(variants, 4)
        backend = _LaunchBackend()
        q = VariantQueues(ShapeBuckets((1, 2)))
        for slot, v in enumerate(variants):
            q.put(QueuedRequest(
                request=InferenceRequest(
                    region=sroi_mod.SRoI(center=(0.0, 0.0), fov=(1.0, 1.0)),
                    variant=v, slot=slot, special=False),
                owner=None, backend=backend))
        results, dispatches = q.drain(placement)
        assert len(results) == 2
        assert backend.exec_groups == [None, None]  # execution fallback
        for d in dispatches:  # ...while pricing keeps the group
            assert d["group"] is placement.group_for(d["variant"])
            assert d["group"].is_virtual


# ---------------------------------------------------------------------------
# real sharded path (runs in the CI multidevice lane; skips on 1 device)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sharded_setup():
    cfgs = [dataclasses.replace(det_mod.PAPER_LADDER[i], input_size=64,
                                n_classes=8) for i in range(2)]
    params = [det_mod.init_params(jax.random.PRNGKey(i), c)
              for i, c in enumerate(cfgs)]
    backend = JaxDetectorBackend(
        cfgs, params, conf=0.01, use_kernel=False, max_det=4,
        buckets=ShapeBuckets((1, 2, 4, 8), resolutions=(64,)))
    variants = profiles.make_ladder(n_categories=8, seed=0)[:2]
    placement = VariantPlacement(variants, devices=jax.devices()[:8])
    return backend, variants, placement


def _regions(rng, n):
    fov = (math.radians(60), math.radians(60))
    return [sroi_mod.SRoI(center=(float(rng.uniform(-2.5, 2.5)),
                                  float(rng.uniform(-0.9, 0.9))), fov=fov)
            for _ in range(n)]


@pytest.mark.multidevice
@needs_devices
class TestShardedJaxBackend:
    def test_sharded_forward_matches_unsharded(self, sharded_setup):
        backend, variants, placement = sharded_setup
        rng = np.random.default_rng(0)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        items = [(frame, r) for r in _regions(rng, 7)]
        for v in variants:
            group = placement.group_for(v.name)
            assert group.n_devices > 1  # the point of the lane
            plain = backend.infer_srois_batched(items, v)
            sharded = backend.infer_srois_batched(items, v, group=group)
            assert len(plain) == len(sharded)
            assert sum(len(d) for d in plain) > 0
            for dets_a, dets_b in zip(plain, sharded):
                assert len(dets_a) == len(dets_b)
                for da, db in zip(dets_a, dets_b):
                    assert da.category == db.category
                    np.testing.assert_allclose(da.box, db.box,
                                               rtol=1e-4, atol=1e-4)
                    np.testing.assert_allclose(da.score, db.score,
                                               rtol=1e-4, atol=1e-5)

    def test_launch_overlaps_groups_then_resolves(self, sharded_setup):
        """The pod drain's two-phase form: every group's forward is
        launched before any result is resolved; results match the
        blocking entry point."""
        backend, variants, placement = sharded_setup
        rng = np.random.default_rng(1)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        items = [(frame, r) for r in _regions(rng, 5)]
        resolvers = [(v, backend.launch_srois_batched(
            items, v, placement.group_for(v.name))) for v in variants]
        for v, resolve in resolvers:
            got = resolve()
            want = backend.infer_srois_batched(items, v)
            assert [len(d) for d in got] == [len(d) for d in want]

    def test_sharded_retraces_bounded_by_buckets(self, sharded_setup):
        backend, variants, placement = sharded_setup
        rng = np.random.default_rng(2)
        frame = rng.random((64, 128, 3)).astype(np.float32)
        v = variants[0]
        group = placement.group_for(v.name)
        start = backend.trace_count
        for count in (1, 2, 3, 5, 1, 4, 2):  # mixed-size "ticks"
            backend.infer_srois_batched(
                [(frame, r) for r in _regions(rng, count)], v, group=group)
        n_buckets = len(backend.buckets.batch_sizes)
        assert backend.trace_count - start <= n_buckets
        # sharded programs key on (variant, padded batch, group devices)
        # and the padded batch always divides over its group
        for key in backend._jit_cache:
            assert len(key) in (2, 3)
            if len(key) == 3:
                assert key[1] % len(key[2]) == 0

    def test_placed_pod_on_real_detector_matches_single_device(self):
        """End-to-end: a placed PodServer on the REAL detector path
        (frames, shard_map groups) matches the unplaced pod
        detection-for-detection."""
        rng = np.random.default_rng(5)
        n_streams, n_frames = 4, 2
        cfgs = [dataclasses.replace(det_mod.PAPER_LADDER[i], input_size=64,
                                    n_classes=8) for i in range(2)]
        params = [det_mod.init_params(jax.random.PRNGKey(i), c)
                  for i, c in enumerate(cfgs)]
        variants = profiles.make_ladder(n_categories=8, seed=0)[:2]
        frames = {(s, f): rng.random((64, 128, 3)).astype(np.float32)
                  for s in range(n_streams) for f in range(n_frames)}
        seeds = [[sroi_mod.Detection(
                      box=np.array([rng.uniform(-2, 2), rng.uniform(-0.8, 0.8),
                                    0.5, 0.5]), category=int(rng.integers(8)),
                      score=0.9) for _ in range(2)]
                 for _ in range(n_streams)]

        def build(placement):
            backend = JaxDetectorBackend(
                cfgs, params, conf=0.01, use_kernel=False, max_det=4,
                buckets=ShapeBuckets((1, 2, 4, 8), resolutions=(64,)))
            lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                        NetworkModel())
            loops = []
            for s in range(n_streams):
                loop = OmniSenseLoop(variants, lat, backend, budget_s=4.0,
                                     n_categories=8, explore_every=0)
                loop.seed_history(list(seeds[s]))
                loops.append(loop)
            return loops, PodServer(
                loops, [backend] * n_streams, max_batch=8,
                frame_source=lambda s, f: frames[(s, f)],
                placement=placement)

        loops_a, single = build(None)
        loops_b, placed = build(
            VariantPlacement(variants, devices=jax.devices()[:8]))
        saw = 0
        for f in range(n_frames):
            single.step(f)
            placed.step(f)
            for la, lb in zip(loops_a, loops_b):
                da, db = la._history[-1], lb._history[-1]
                assert len(da) == len(db)
                for a, b in zip(da, db):
                    assert a.category == b.category
                    np.testing.assert_allclose(a.box, b.box,
                                               rtol=1e-4, atol=1e-4)
                saw += len(da)
        assert saw > 0
        # tick accounting is device-aware (max over groups can only be
        # <= the dispatch sum; equality when every tick keeps a single
        # group busy, which a 2-variant allocator is free to do)
        assert placed.stats.sum_tick_inf_s <= placed.stats.sum_batched_inf_s
        assert placed.stats.group_busy_s

"""Pod-level allocation (PR 4): the fixed-point coupling of the
per-stream knapsacks through batched costs and group utilisation.

Pins the new subsystem end to end:

  * the ``allocation.allocate`` cost hook is bit-identical when absent
    (or the identity), and hook semantics match the brute-force oracle;
  * the b=1 amortization pin: ``pod_amortization(v, 1) == 1.0`` exactly
    and zero-co-stream prices are the exact identity, so legacy plans
    stay byte-identical;
  * the fixed-point solver terminates within the round cap, a
    convergent run is a genuine fixed point (re-running a best-response
    sweep changes nothing), and the capacity envelope bounds the
    projected tick by the uncoupled projection;
  * degenerate pods (V=1 or S=1) reproduce per-stream ``allocate``
    exactly;
  * coupled prices are monotone: wider replica groups and lower
    utilisation never make any variant dearer, so adding idle capacity
    never worsens a chosen plan's latency;
  * tiny-pod joint brute force never loses to the fixed point
    (Pareto sanity) and the fixed point is jointly feasible;
  * ``PodServer(pod_allocate=True)`` dominates the uncoupled pod on
    the accuracy proxy at equal-or-lower tick latency (the bench
    acceptance invariant at test scale), and keeps the jit trace
    counts inside the ``ShapeBuckets`` ladder (the coupling is
    host-side only).
"""

import dataclasses
import types

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import allocation
from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import pod_allocation as pa
from repro.serving import profiles
from repro.serving.batching import ShapeBuckets
from repro.serving.network import NetworkModel
from repro.serving.placement import VariantPlacement
from repro.serving.runtime import SyncTickPolicy
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer


def _lat():
    return OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())


def _variants(v=3):
    return profiles.make_ladder(seed=0)[:v]


def rand_problem(rng, n_variants, r, budget=None):
    """Random (1 + V, R) allocator instance (row 0 = zero-cost skip)."""
    acc = rng.uniform(0, 1, (1 + n_variants, r))
    acc[0] = 0.0
    d_pre = rng.uniform(0.01, 0.2, (1 + n_variants, r))
    d_pre[0] = 0.0
    d_inf = rng.uniform(0.02, 0.6, (1 + n_variants, r))
    d_inf[0] = 0.0
    budget = budget if budget is not None else float(rng.uniform(0.2, 2.5))
    return pa.StreamProblem(acc, d_pre, d_inf, budget)


def _stub_placement(spec):
    """Placement stand-in: ``spec`` maps variant name -> (gidx, n_dev)."""
    groups = {name: types.SimpleNamespace(index=g, n_devices=n)
              for name, (g, n) in spec.items()}
    return types.SimpleNamespace(group_for=lambda name: groups[name])


def _plans_equal(a, b):
    if (a is None) != (b is None):
        return False
    return a is None or (a.models == b.models and a.value == b.value)


class TestCostHook:
    def _check_identity(self, seed):
        rng = np.random.default_rng(seed)
        p = rand_problem(rng, 3, 4)
        plain = allocation.allocate(p.acc, p.d_pre, p.d_inf, p.budget)
        hooked = allocation.allocate(
            p.acc, p.d_pre, p.d_inf, p.budget,
            cost_hook=lambda i, j, dp, di: (dp, di))
        assert plain.models == hooked.models
        assert plain.value == hooked.value
        assert plain.t_pre == hooked.t_pre
        assert plain.t_done == hooked.t_done

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_identity_hook_bit_identical_property(self, seed):
        self._check_identity(seed)

    def test_identity_hook_bit_identical_fixed(self):
        for seed in (0, 1, 2, 3, 4):
            self._check_identity(seed)

    def _check_hook_vs_bruteforce(self, seed):
        """A non-trivial hook must keep allocate exact vs brute force."""
        rng = np.random.default_rng(seed)
        p = rand_problem(rng, 2, 3)

        def hook(i, j, dp, di):
            return dp, di * (0.5 + 0.25 * i) + 0.01 * j

        got = allocation.allocate(p.acc, p.d_pre, p.d_inf, p.budget,
                                  cost_hook=hook)
        want = allocation.allocate_bruteforce(p.acc, p.d_pre, p.d_inf,
                                              p.budget, cost_hook=hook)
        assert (got is None) == (want is None)
        if got is not None:
            assert np.isclose(got.value, want.value, atol=1e-9)

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_hooked_allocate_matches_bruteforce_property(self, seed):
        self._check_hook_vs_bruteforce(seed)

    def test_hooked_allocate_matches_bruteforce_fixed(self):
        for seed in (10, 11, 12, 13):
            self._check_hook_vs_bruteforce(seed)

    def test_b1_amortization_pin(self):
        """``batched_inference_delay(v, 1) == _inf(v)`` and
        ``pod_amortization(v, 1) == 1.0`` EXACTLY — the pin that keeps
        legacy (uncoupled) plans byte-identical."""
        lat = _lat()
        buckets = ShapeBuckets()
        for v in _variants(5):
            assert lat.batched_inference_delay(v, 1) == lat._inf(v)
            assert lat.pod_amortization(v, 1, buckets) == 1.0
            assert lat.variant_queue_cost(v, 0, buckets) == 0.0

    def test_zero_co_stream_prices_are_exact_identity(self):
        """With no co-streams and no utilisation, every coupled price
        is the exact (1.0, 0.0, 1.0) identity and the hooked matrices
        are bit-identical to the base matrices."""
        lat = _lat()
        variants = _variants(3)
        buckets = ShapeBuckets()
        prices = pa.stream_prices(variants, {v.name: 0 for v in variants},
                                  lat, buckets)
        for v in variants:
            assert prices[v.name] == pa.VariantPrice(1.0, 0.0, 1.0)
        rng = np.random.default_rng(0)
        p = rand_problem(rng, 3, 4)
        d_pre_c, d_inf_c = allocation.apply_cost_hook(
            pa.price_hook(prices, variants), p.d_pre, p.d_inf)
        assert (d_pre_c == p.d_pre).all()
        assert (d_inf_c == p.d_inf).all()


class TestFixedPoint:
    def _rand_pod(self, rng, s=None, v=None, r=None):
        s = s or int(rng.integers(2, 5))
        v = v or int(rng.integers(2, 4))
        r = r or int(rng.integers(1, 4))
        variants = _variants(v)
        problems = [rand_problem(rng, v, int(rng.integers(1, r + 1)))
                    for _ in range(s)]
        return problems, variants

    def _check_termination_and_fixed_point(self, seed):
        rng = np.random.default_rng(seed)
        problems, variants = self._rand_pod(rng)
        lat = _lat()
        buckets = ShapeBuckets()
        sol = pa.solve_pod(problems, variants, lat, buckets=buckets)
        assert 1 <= sol.rounds <= pa.DEFAULT_MAX_ROUNDS
        assert sol.coupled
        # the envelope bounds the projection
        assert sol.projected_tick <= sol.tick_cap + 1e-9
        # counts describe the returned plans
        assert sol.counts == pa._total_counts(sol.plans, variants)
        if sol.converged:
            # a convergent run is a GENUINE fixed point: one more
            # best-response sweep changes nothing
            replans, changed = pa.best_response(
                problems, sol.plans, variants, lat, buckets,
                tick_cap=sol.tick_cap)
            assert not changed
            for a, b in zip(sol.plans, replans):
                assert _plans_equal(a, b)

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_termination_and_fixed_point_property(self, seed):
        self._check_termination_and_fixed_point(seed)

    def test_termination_and_fixed_point_fixed(self):
        for seed in (0, 1, 2, 3, 7, 21):
            self._check_termination_and_fixed_point(seed)

    def _check_value_never_below_uncoupled(self, seed):
        """Hysteresis + incumbents: with per-variant replica groups
        (coupled prices never above base — the bench topology) the
        coupled total value can never fall below the uncoupled round-0
        total.  (On a SHARED group the queue-wait term may price an
        overcommitted incumbent out of its budget and legitimately shed
        work, so the guarantee is scoped to split groups.)"""
        rng = np.random.default_rng(seed)
        problems, variants = self._rand_pod(rng, v=2)
        a, b = (v.name for v in variants)
        placement = _stub_placement({a: (0, 1), b: (1, 1)})
        lat = _lat()
        sol = pa.solve_pod(problems, variants, lat, placement=placement)
        base = [allocation.allocate(p.acc, p.d_pre, p.d_inf, p.budget)
                for p in problems]
        tot = sum(p.value for p in sol.plans if p is not None)
        tot_base = sum(p.value for p in base if p is not None)
        assert tot >= tot_base - 1e-9

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_value_never_below_uncoupled_property(self, seed):
        self._check_value_never_below_uncoupled(seed)

    def test_value_never_below_uncoupled_fixed(self):
        for seed in (5, 6, 8, 13):
            self._check_value_never_below_uncoupled(seed)

    def test_single_variant_equals_per_stream_allocate(self):
        """V=1: no cross-variant choice to couple — plans are the
        per-stream ``allocate`` results, bit-identical."""
        rng = np.random.default_rng(3)
        variants = _variants(1)
        problems = [rand_problem(rng, 1, 3) for _ in range(4)]
        sol = pa.solve_pod(problems, variants, _lat())
        assert not sol.coupled and sol.rounds == 0 and sol.converged
        for p, plan in zip(problems, sol.plans):
            want = allocation.allocate(p.acc, p.d_pre, p.d_inf, p.budget)
            assert plan.models == want.models
            assert plan.value == want.value
            assert plan.t_pre == want.t_pre
            assert plan.t_done == want.t_done

    def test_single_stream_equals_per_stream_allocate(self):
        """S=1: no co-streams — the short-circuit returns the
        uncoupled plan bit-identical, AND the pricing path agrees
        naturally (a lone best-response sweep changes nothing because
        zero-co prices are the exact identity)."""
        rng = np.random.default_rng(4)
        variants = _variants(3)
        problems = [rand_problem(rng, 3, 4)]
        lat = _lat()
        sol = pa.solve_pod(problems, variants, lat)
        want = allocation.allocate(problems[0].acc, problems[0].d_pre,
                                   problems[0].d_inf, problems[0].budget)
        assert not sol.coupled
        assert sol.plans[0].models == want.models
        assert sol.plans[0].value == want.value
        assert sol.plans[0].t_done == want.t_done
        # natural identity, no short-circuit involved
        replans, changed = pa.best_response(
            problems, [want], variants, lat, ShapeBuckets())
        assert not changed and replans[0].models == want.models
        assert replans[0].t_done == pytest.approx(want.t_done)

    def test_damping_caps_switches_per_sweep(self):
        """damping < 1 bounds how many streams may switch per round;
        the solver still terminates and ends on a no-switch sweep when
        it reports convergence."""
        rng = np.random.default_rng(11)
        problems, variants = self._rand_pod(rng, s=4, v=3, r=2)
        lat = _lat()
        sol = pa.solve_pod(problems, variants, lat, damping=0.25,
                           max_rounds=12)
        assert 1 <= sol.rounds <= 12
        if sol.converged:
            _, changed = pa.best_response(
                problems, sol.plans, variants, lat, ShapeBuckets(),
                tick_cap=sol.tick_cap, max_switches=1)
            assert not changed


class TestSloEnvelope:
    """``solve_pod(slo_s=...)``: the run's SLO as the capacity envelope
    (PR 8) — ``T_cap = min(uncoupled projected tick, slo_s)``, so the
    batching discount may upgrade plans only into device time that also
    fits the service objective.  Replaces the per-stream budget
    workaround (see README 'Migration')."""

    def _pod(self, seed, s=4, v=3):
        rng = np.random.default_rng(seed)
        return [rand_problem(rng, v, 2) for _ in range(s)], _variants(v)

    @staticmethod
    def _uncoupled_tick(problems, variants, lat, buckets):
        plans = [allocation.allocate(p.acc, p.d_pre, p.d_inf, p.budget)
                 for p in problems]
        counts = pa._total_counts(plans, variants)
        load = pa.projected_group_load(counts, variants, lat, buckets)
        return max(load.values(), default=0.0)

    def test_loose_slo_is_bit_identical_to_none(self):
        """An SLO above the uncoupled projection never binds: the clamp
        is the identity and the solution stays byte-identical to the
        default self-referential envelope."""
        for seed in (0, 3, 11):
            problems, variants = self._pod(seed)
            lat, buckets = _lat(), ShapeBuckets()
            a = pa.solve_pod(problems, variants, lat, buckets=buckets)
            b = pa.solve_pod(problems, variants, lat, buckets=buckets,
                             slo_s=1e9)
            assert b.tick_cap == a.tick_cap
            assert all(_plans_equal(x, y) for x, y in zip(a.plans, b.plans))

    def test_tick_cap_clamps_to_min_of_uncoupled_and_slo(self):
        problems, variants = self._pod(1)
        lat, buckets = _lat(), ShapeBuckets()
        u = self._uncoupled_tick(problems, variants, lat, buckets)
        assert u > 0
        loose = pa.solve_pod(problems, variants, lat, buckets=buckets,
                             slo_s=10 * u)
        tight = pa.solve_pod(problems, variants, lat, buckets=buckets,
                             slo_s=0.5 * u)
        assert loose.tick_cap == pytest.approx(u)
        assert tight.tick_cap == pytest.approx(0.5 * u)

    def test_clamped_envelope_gates_upgrades(self):
        """Under a binding SLO every ADOPTED switch fits the clamped
        envelope, and kept incumbents never exceed the uncoupled
        round-0 projection — so the returned plans' projection is
        bounded by max(uncoupled, cap) regardless of how tight the
        clamp is (incumbents above the cap are hysteresis, not a
        violation: their load was already paid for)."""
        for seed in (0, 1, 2, 5, 9):
            problems, variants = self._pod(seed)
            lat, buckets = _lat(), ShapeBuckets()
            u = self._uncoupled_tick(problems, variants, lat, buckets)
            for frac in (0.5, 0.25):
                sol = pa.solve_pod(problems, variants, lat,
                                   buckets=buckets, slo_s=frac * u)
                assert sol.tick_cap == pytest.approx(frac * u)
                assert sol.projected_tick <= max(u, sol.tick_cap) + 1e-6

    def test_single_stream_short_circuit_reports_clamp(self):
        """S=1 keeps the calibrated per-stream plan byte-identical, but
        the returned envelope still reflects the clamp and
        ``projected_tick`` always reports the returned plans'
        projection (possibly above a tiny cap)."""
        problems, variants = self._pod(4, s=1)
        lat, buckets = _lat(), ShapeBuckets()
        u = self._uncoupled_tick(problems, variants, lat, buckets)
        sol = pa.solve_pod(problems, variants, lat, buckets=buckets,
                           slo_s=0.1 * u)
        assert not sol.coupled and sol.rounds == 0
        assert sol.tick_cap == pytest.approx(0.1 * u)
        assert sol.projected_tick == pytest.approx(u)
        base = allocation.allocate(problems[0].acc, problems[0].d_pre,
                                   problems[0].d_inf, problems[0].budget)
        assert _plans_equal(sol.plans[0], base)


class TestMonotonicity:
    def _prices(self, spec, co, util=None):
        variants = _variants(2)
        return pa.stream_prices(
            variants, co, _lat(), ShapeBuckets(),
            placement=_stub_placement(spec), group_utilisation=util)

    def test_wider_group_never_dearer(self):
        """Adding devices to a variant's replica group (an idle group
        absorbing it, a widened group) never raises any coupled
        price."""
        variants = _variants(2)
        a, b = (v.name for v in variants)
        co = {a: 5, b: 3}
        d_inf = np.array([0.3, 0.7])
        for n1, n2 in ((1, 2), (2, 4), (1, 8), (3, 4)):
            narrow = self._prices({a: (0, n1), b: (1, 1)}, co)
            wide = self._prices({a: (0, n2), b: (1, 1)}, co)
            for name, base in zip((a, b), d_inf):
                assert wide[name].apply(base) <= \
                    narrow[name].apply(base) + 1e-12, (n1, n2, name)

    def test_separate_group_never_dearer_than_shared(self):
        """Splitting a shared group (an idle group takes one variant)
        removes the co-variant queue wait for both sides."""
        variants = _variants(2)
        a, b = (v.name for v in variants)
        co = {a: 4, b: 4}
        shared = self._prices({a: (0, 1), b: (0, 1)}, co)
        split = self._prices({a: (0, 1), b: (1, 1)}, co)
        for name, base in ((a, 0.3), (b, 0.7)):
            assert split[name].apply(base) <= shared[name].apply(base) + 1e-12
        # the shared group genuinely paid a queue wait
        assert shared[a].extra > 0 and split[a].extra == 0.0

    def test_lower_utilisation_never_dearer(self):
        variants = _variants(2)
        a, b = (v.name for v in variants)
        co = {a: 3, b: 2}
        spec = {a: (0, 2), b: (1, 1)}
        busy = self._prices(spec, co, util={0: 1.0, 1: 0.8})
        idle = self._prices(spec, co, util={0: 0.2, 1: 0.0})
        for name, base in ((a, 0.3), (b, 0.7)):
            assert idle[name].apply(base) <= busy[name].apply(base) + 1e-12

    def test_added_devices_never_worsen_chosen_latency(self):
        """Solver-level: re-pricing a solution's plans with extra idle
        devices (same variant->group mapping) never increases any
        stream's plan latency."""
        rng = np.random.default_rng(9)
        variants = _variants(2)
        a, b = (v.name for v in variants)
        problems = [rand_problem(rng, 2, 2) for _ in range(3)]
        lat = _lat()
        buckets = ShapeBuckets()
        narrow = _stub_placement({a: (0, 1), b: (1, 1)})
        wide = _stub_placement({a: (0, 4), b: (1, 2)})
        sol = pa.solve_pod(problems, variants, lat, buckets=buckets,
                           placement=narrow)
        counts = pa._total_counts(sol.plans, variants)
        for s, (prob, plan) in enumerate(zip(problems, sol.plans)):
            if plan is None:
                continue
            own = pa._plan_counts(plan, variants)
            co = {n: counts[n] - own[n] for n in own}
            lats = {}
            for tag, placement in (("narrow", narrow), ("wide", wide)):
                prices = pa.stream_prices(variants, co, lat, buckets,
                                          placement=placement)
                dp, di = allocation.apply_cost_hook(
                    pa.price_hook(prices, variants), prob.d_pre, prob.d_inf)
                lats[tag] = allocation.plan_latency(plan.models, dp, di)
            assert lats["wide"] <= lats["narrow"] + 1e-12, s


class TestJointOracle:
    """Brute-force joint allocation on tiny pods: the fixed point is
    jointly feasible and never beats the joint optimum."""

    def _tiny_pod(self, rng):
        s = int(rng.integers(2, 4))
        variants = _variants(2)
        a, b = (v.name for v in variants)
        problems = [rand_problem(rng, 2, int(rng.integers(1, 3)))
                    for _ in range(s)]
        # each variant its own single-device group: coupled prices are
        # then never above base, so incumbents stay feasible and the
        # fixed point lives inside the oracle's feasible set
        placement = _stub_placement({a: (0, 1), b: (1, 1)})
        return problems, variants, placement

    def _check_oracle(self, seed):
        rng = np.random.default_rng(seed)
        problems, variants, placement = self._tiny_pod(rng)
        lat = _lat()
        buckets = ShapeBuckets()
        sol = pa.solve_pod(problems, variants, lat, buckets=buckets,
                           placement=placement)
        counts = pa._total_counts(sol.plans, variants)
        # joint feasibility of the fixed point under its own prices
        for s, (prob, plan) in enumerate(zip(problems, sol.plans)):
            if plan is None:
                continue
            own = pa._plan_counts(plan, variants)
            co = {n: counts[n] - own[n] for n in own}
            prices = pa.stream_prices(variants, co, lat, buckets,
                                      placement=placement)
            dp, di = allocation.apply_cost_hook(
                pa.price_hook(prices, variants), prob.d_pre, prob.d_inf)
            assert allocation.plan_latency(plan.models, dp, di) \
                <= prob.budget + 1e-9, s
        # ...and the joint optimum never loses to it
        _, best = pa.solve_pod_bruteforce(
            problems, variants, lat, buckets=buckets, placement=placement,
            tick_cap=sol.tick_cap)
        tot = sum(p.value for p in sol.plans if p is not None)
        assert best >= tot - 1e-9

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_fixed_point_never_beats_bruteforce_property(self, seed):
        self._check_oracle(seed)

    def test_fixed_point_never_beats_bruteforce_fixed(self):
        for seed in (0, 1, 2, 5):
            self._check_oracle(seed)


def _oracle_pod(n_streams, pod_allocate, frames=8, seed0=100, budget=1.8):
    """The bench acceptance pod at test scale: 2 variants (p5-896 /
    p6-1280), 8 virtual device slots, shared latency model."""
    variants = profiles.make_ladder()[3:5]
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    loops, backends = [], []
    for s in range(n_streams):
        video = make_video(n_frames=frames + 8, n_objects=30 + 5 * (s % 4),
                           seed=seed0 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        loops.append(OmniSenseLoop(variants, lat, backend, budget_s=budget,
                                   explore_costs=costs))
    placement = VariantPlacement.virtual(variants, 8, cost_fn=lat._inf)
    return PodServer(loops, backends, max_batch=8, placement=placement,
                     policy=SyncTickPolicy(pod_allocate=pod_allocate))


class TestPodServerCoupling:
    def test_pod_allocate_defaults_off(self):
        server = _oracle_pod(2, False)
        assert server.pod_allocate is False

    def test_mixed_ladders_rejected(self):
        variants = profiles.make_ladder()
        lat = _lat()
        loops = []
        for vs in (variants[:2], variants[1:3]):
            video = make_video(n_frames=8, n_objects=20, seed=1)
            loops.append(OmniSenseLoop(vs, lat, OracleBackend(video),
                                       budget_s=1.8))
        backends = [loop.backend for loop in loops]
        with pytest.raises(ValueError):
            PodServer(loops, backends,
                      policy=SyncTickPolicy(pod_allocate=True))
        PodServer(loops, backends)  # uncoupled pods may mix ladders

    def test_coupled_pod_serves_and_converges(self):
        server = _oracle_pod(4, True, frames=6)
        stats = server.run(range(6))
        assert stats.frames == 24
        assert stats.pod_ticks == 6
        assert stats.pod_rounds >= stats.pod_ticks  # >= 1 round/tick
        assert stats.pod_converged_ticks == stats.pod_ticks
        assert stats.total_detections > 0
        # coupled plans still respect every stream's budget estimate
        for loop in server.loops:
            assert loop.budget_s == 1.8

    def test_uncoupled_pod_reports_no_rounds(self):
        server = _oracle_pod(2, False, frames=4)
        stats = server.run(range(4))
        assert stats.pod_ticks == 0 and stats.pod_rounds == 0
        assert stats.sum_plan_value > 0  # the proxy accrues regardless

    def test_coupled_dominates_uncoupled(self):
        """The acceptance invariant at test scale: at 8 streams / 2
        variants the coupled pod is strictly better on the accuracy
        proxy at equal-or-lower mean tick inference latency."""
        base = _oracle_pod(8, False).run(range(8))
        coup = _oracle_pod(8, True).run(range(8))
        assert coup.accuracy_proxy > base.accuracy_proxy
        assert (coup.sum_tick_inf_s / coup.ticks
                <= base.sum_tick_inf_s / base.ticks + 1e-9)


class TestTraceRegression:
    """``pod_allocate=True`` must stay host-side: no new compiled
    shapes beyond the existing ``ShapeBuckets`` ladder for either the
    batched detector forward or the device NMS path."""

    def test_jit_traces_bounded_under_pod_allocate(self):
        import jax

        from repro.core import sroi as sroi_mod
        from repro.core.sphere import nms_device_trace_count
        from repro.models import detector as det_mod
        from repro.serving.scheduler import JaxDetectorBackend

        rng = np.random.default_rng(5)
        n_streams, n_frames = 2, 2
        cfgs = [dataclasses.replace(det_mod.PAPER_LADDER[i], input_size=64,
                                    n_classes=8) for i in range(2)]
        params = [det_mod.init_params(jax.random.PRNGKey(i), c)
                  for i, c in enumerate(cfgs)]
        variants = profiles.make_ladder(n_categories=8, seed=0)[:2]
        backend = JaxDetectorBackend(
            cfgs, params, conf=0.01, use_kernel=False, max_det=4,
            buckets=ShapeBuckets((1, 2, 4), resolutions=(64,)))
        lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
        frames = {(s, f): rng.random((64, 128, 3)).astype(np.float32)
                  for s in range(n_streams) for f in range(n_frames)}
        loops = []
        for s in range(n_streams):
            loop = OmniSenseLoop(variants, lat, backend, budget_s=4.0,
                                 n_categories=8, explore_every=0)
            loop.seed_history([sroi_mod.Detection(
                box=np.array([rng.uniform(-2, 2), rng.uniform(-0.8, 0.8),
                              0.5, 0.5]), category=int(rng.integers(8)),
                score=0.9) for _ in range(2)])
            loops.append(loop)
        server = PodServer(loops, [backend] * n_streams, max_batch=4,
                           buckets=ShapeBuckets((1, 2, 4)),
                           frame_source=lambda s, f: frames[(s, f)],
                           policy=SyncTickPolicy(pod_allocate=True))
        nms_traces = nms_device_trace_count()
        server.run(range(n_frames))
        n_buckets = len(backend.buckets.batch_sizes)
        assert backend.trace_count <= n_buckets * len(cfgs)
        for key in backend._jit_cache:
            assert key[1] in backend.buckets.batch_sizes
        # the tick NMS stays on the host path here: coupling must not
        # have pushed anything through the jitted device NMS
        assert nms_device_trace_count() == nms_traces

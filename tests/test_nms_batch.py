"""Batched spherical NMS subsystem: cross-implementation equivalence.

Three independent implementations must produce bit-identical keep-masks:

  * ``sph_nms_lax``    — jit-compatible ``lax.fori_loop`` (the oracle),
  * ``sph_nms_host``   — vectorised NumPy greedy (serving fast path),
  * ``sph_nms_batch``  — the padded (B, N) subsystem, exercised through
    BOTH backends: vectorised host and the batched Pallas SphIoU kernel
    + ``lax.while_loop`` (interpret mode on CPU).

``sph_nms`` itself is now the B=1 entry point of ``sph_nms_batch``
(the ROADMAP fold); ``TestSingleRowFold`` pins it against the kept-old
``sph_nms_lax`` oracle on this suite's corpus.

Sweeps cover antimeridian seam-wrap boxes, all-padded rows, single-box
rows and empty inputs; property tests (shimmed when hypothesis is
absent) pin the keep-mask's invariance under score-preserving
permutations and that padding is never kept.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import sphere

THR = 0.6


def random_boxes(rng, n, seam_frac=0.25):
    """Random SphBBs; a fraction hugs the +-pi antimeridian seam."""
    theta = rng.uniform(-math.pi, math.pi, n)
    seam = rng.random(n) < seam_frac
    theta[seam] = np.sign(rng.standard_normal(seam.sum())) * (
        math.pi - rng.uniform(0.0, 0.1, seam.sum()))
    return np.stack([
        theta,
        rng.uniform(-1.3, 1.3, n),
        rng.uniform(0.05, 0.9, n),
        rng.uniform(0.05, 0.9, n)], axis=-1).astype(np.float32)


def padded_batch(rng, b, n_max, min_n=0):
    boxes = np.zeros((b, n_max, 4), np.float32)
    scores = np.zeros((b, n_max), np.float32)
    mask = np.zeros((b, n_max), bool)
    for r in range(b):
        n = int(rng.integers(min_n, n_max + 1))
        if n:
            boxes[r, :n] = random_boxes(rng, n)
            scores[r, :n] = rng.uniform(0.01, 1.0, n)
            mask[r, :n] = True
    return boxes, scores, mask


class TestEquivalence:
    def test_1024_random_rows_host_backend(self):
        """Acceptance sweep: >=1000 padded rows, host backend, per-row
        keep-masks identical to the single-row host reference."""
        rng = np.random.default_rng(7)
        boxes, scores, mask = padded_batch(rng, 1024, 24)
        keep = sphere.sph_nms_batch(boxes, scores, mask, THR, backend="host")
        assert not keep[~mask].any()
        for r in range(boxes.shape[0]):
            n = int(mask[r].sum())
            ref = sphere.sph_nms_host(boxes[r, :n], scores[r, :n], THR)
            assert (keep[r, :n] == ref).all(), f"row {r}"

    def test_lax_oracle_agrees(self):
        """The jit ``sph_nms_lax`` oracle vs host/batched paths on a
        few fixed shapes (each distinct N compiles the fori_loop once)."""
        rng = np.random.default_rng(13)
        for n in (1, 2, 17, 24):
            for _ in range(4):
                boxes = random_boxes(rng, n)
                scores = rng.uniform(0.01, 1.0, n).astype(np.float32)
                ref_lax = np.asarray(sphere.sph_nms_lax(
                    jnp.asarray(boxes), jnp.asarray(scores), THR))
                host = sphere.sph_nms_host(boxes, scores, THR)
                batch = sphere.sph_nms_batch(
                    boxes[None], scores[None], None, THR, backend="host")[0]
                assert (ref_lax == host).all(), n
                assert (ref_lax == batch).all(), n

    def test_pallas_interpret_matches_host(self):
        """Device backend (Pallas-interpret SphIoU + lax.while_loop) vs
        the vectorised host path on the same padded batch."""
        rng = np.random.default_rng(11)
        boxes, scores, mask = padded_batch(rng, 48, 20)
        k_host = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                      backend="host")
        k_dev = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                     backend="device")
        assert (k_host == k_dev).all()

    def test_jit_backend_matches_host(self):
        """The XLA-compiled path (fused jnp IoU + lax.while_loop) —
        the CPU bench/bulk path — against the host reference, with a
        chunk size that forces the row-chunked dispatch."""
        rng = np.random.default_rng(19)
        boxes, scores, mask = padded_batch(rng, 32, 16)
        k_host = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                      backend="host")
        k_jit = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                     backend="jit")
        assert (k_host == k_jit).all()

    def test_jit_backend_chunked(self, monkeypatch):
        rng = np.random.default_rng(23)
        boxes, scores, mask = padded_batch(rng, 6, 12, min_n=1)
        full = sphere.sph_nms_batch(boxes, scores, mask, THR, backend="jit")
        monkeypatch.setattr(sphere, "_DEVICE_CHUNK_ELEMS", 2 * 12 * 12)
        chunked = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                       backend="jit")
        assert (full == chunked).all()

    def test_seam_wrap_pair_suppressed(self):
        # two near-identical boxes straddling +-pi: one must suppress
        # the other in every implementation
        boxes = np.array([[math.pi - 0.02, 0.0, 0.4, 0.4],
                          [-math.pi + 0.02, 0.0, 0.4, 0.4]], np.float32)
        scores = np.array([0.9, 0.8], np.float32)
        assert sphere.sph_nms_host(boxes, scores, THR).tolist() == [True, False]
        for backend in ("host", "device"):
            keep = sphere.sph_nms_batch(boxes[None], scores[None], None, THR,
                                        backend=backend)[0]
            assert keep.tolist() == [True, False], backend

    def test_all_padded_rows(self):
        boxes = np.zeros((3, 8, 4), np.float32)
        scores = np.zeros((3, 8), np.float32)
        mask = np.zeros((3, 8), bool)
        for backend in ("host", "device"):
            keep = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                        backend=backend)
            assert not keep.any(), backend

    def test_single_box_rows(self):
        rng = np.random.default_rng(3)
        boxes = np.zeros((4, 1, 4), np.float32)
        boxes[:, 0] = random_boxes(rng, 4)
        scores = rng.uniform(0.1, 1, (4, 1)).astype(np.float32)
        for backend in ("host", "device"):
            keep = sphere.sph_nms_batch(boxes, scores, None, THR,
                                        backend=backend)
            assert keep.all(), backend

    def test_empty_n(self):
        keep = sphere.sph_nms_batch(np.zeros((2, 0, 4), np.float32),
                                    np.zeros((2, 0), np.float32))
        assert keep.shape == (2, 0)

    def test_max_out_ranks_by_score(self):
        rng = np.random.default_rng(5)
        boxes = random_boxes(rng, 30)[None]
        scores = rng.uniform(0, 1, (1, 30)).astype(np.float32)
        full = sphere.sph_nms_batch(boxes, scores, None, THR)
        capped = sphere.sph_nms_batch(boxes, scores, None, THR, max_out=2)
        assert capped.sum() == min(2, full.sum())
        # capped survivors are the top-scoring survivors of the full run
        kept_scores = scores[0][capped[0]]
        assert (kept_scores >= scores[0][full[0]].min() - 1e-9).all()
        assert (capped & ~full).sum() == 0


class TestSingleRowFold:
    """ROADMAP fold (PR 4 satellite): ``sph_nms`` is now expressed as
    ``sph_nms_batch(boxes[None], ...)``; the ORIGINAL jit-compatible
    implementation is kept as ``sph_nms_lax`` and these tests pin
    keep-mask equality on the existing property-suite corpus."""

    def test_fold_matches_old_oracle_on_corpus(self):
        rng = np.random.default_rng(13)  # the lax-oracle corpus
        for n in (1, 2, 17, 24, 40):
            for _ in range(4):
                boxes = random_boxes(rng, n)
                scores = rng.uniform(0.01, 1.0, n).astype(np.float32)
                old = np.asarray(sphere.sph_nms_lax(
                    jnp.asarray(boxes), jnp.asarray(scores), THR))
                new = sphere.sph_nms(boxes, scores, THR)
                assert (new == old).all(), n

    def test_fold_is_the_batch_single_row(self):
        rng = np.random.default_rng(29)
        boxes = random_boxes(rng, 20)
        scores = rng.uniform(0.01, 1.0, 20).astype(np.float32)
        keep = sphere.sph_nms(boxes, scores, THR)
        batch = sphere.sph_nms_batch(boxes[None], scores[None], None, THR)[0]
        assert (keep == batch).all()
        assert keep.shape == (20,)

    def test_fold_max_out_matches_old_oracle(self):
        rng = np.random.default_rng(31)
        boxes = random_boxes(rng, 30)
        # distinct scores so max_out's score ranking is unambiguous
        scores = (rng.permutation(30) + 1.0).astype(np.float32) / 30.0
        for max_out in (1, 3, 8, None):
            old = np.asarray(sphere.sph_nms_lax(
                jnp.asarray(boxes), jnp.asarray(scores), THR,
                max_out=max_out))
            new = sphere.sph_nms(boxes, scores, THR, max_out=max_out)
            assert (new == old).all(), max_out

    def test_fold_seam_and_empty(self):
        boxes = np.array([[math.pi - 0.02, 0.0, 0.4, 0.4],
                          [-math.pi + 0.02, 0.0, 0.4, 0.4]], np.float32)
        scores = np.array([0.9, 0.8], np.float32)
        assert sphere.sph_nms(boxes, scores, THR).tolist() == [True, False]
        empty = sphere.sph_nms(np.zeros((0, 4), np.float32),
                               np.zeros((0,), np.float32))
        assert empty.shape == (0,)


class TestProperties:
    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_permutation_invariance_property(self, seed):
        self._check_permutation(seed)

    def test_permutation_invariance_fixed(self):
        for seed in (0, 1, 2, 3, 4):
            self._check_permutation(seed)

    @staticmethod
    def _check_permutation(seed):
        """A score-preserving shuffle of the boxes permutes the
        keep-mask but never changes WHICH boxes survive."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 32))
        boxes = random_boxes(rng, n)
        # distinct scores so the greedy order is permutation-independent
        scores = (np.arange(1, n + 1) / n).astype(np.float32)
        rng.shuffle(scores)
        perm = rng.permutation(n)
        keep = sphere.sph_nms_batch(boxes[None], scores[None], None, THR,
                                    backend="host")[0]
        keep_p = sphere.sph_nms_batch(boxes[perm][None], scores[perm][None],
                                      None, THR, backend="host")[0]
        assert (keep_p == keep[perm]).all()

    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_padding_never_kept_property(self, seed):
        self._check_padding(seed)

    def test_padding_never_kept_fixed(self):
        for seed in (10, 11, 12):
            self._check_padding(seed)

    @staticmethod
    def _check_padding(seed):
        """Masked entries are never kept — even with forged high scores
        and non-degenerate box geometry in the padded slots."""
        rng = np.random.default_rng(seed)
        b, n = int(rng.integers(1, 6)), int(rng.integers(1, 16))
        boxes = random_boxes(rng, b * n).reshape(b, n, 4)
        scores = rng.uniform(0, 1, (b, n)).astype(np.float32)
        mask = rng.random((b, n)) < 0.5
        scores[~mask] = 2.0  # padding must lose even with the top score
        for backend in ("host", "device"):
            keep = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                        backend=backend)
            assert not keep[~mask].any(), backend

    def test_survivors_mutually_nonoverlapping_batch(self):
        rng = np.random.default_rng(21)
        boxes, scores, mask = padded_batch(rng, 16, 24, min_n=2)
        keep = sphere.sph_nms_batch(boxes, scores, mask, THR, backend="host")
        for r in range(boxes.shape[0]):
            surv = boxes[r][keep[r]]
            if len(surv) > 1:
                iou = sphere.sph_iou_matrix_np(
                    surv.astype(np.float64), surv.astype(np.float64))
                np.fill_diagonal(iou, 0)
                assert iou.max() <= THR + 1e-6


class TestBatchedIoUHostPath:
    def test_batched_np_matrix_matches_unbatched(self):
        rng = np.random.default_rng(2)
        stack = np.stack([random_boxes(rng, 12) for _ in range(5)])
        batched = sphere.sph_iou_matrix_np(stack.astype(np.float64),
                                           stack.astype(np.float64))
        for r in range(5):
            single = sphere.sph_iou_matrix_np(stack[r].astype(np.float64),
                                              stack[r].astype(np.float64))
            np.testing.assert_allclose(batched[r], single, rtol=1e-12)

    def test_host_chunking_consistent(self, monkeypatch):
        rng = np.random.default_rng(9)
        boxes, scores, mask = padded_batch(rng, 10, 16, min_n=1)
        full = sphere.sph_nms_batch(boxes, scores, mask, THR, backend="host")
        monkeypatch.setattr(sphere, "_HOST_CHUNK_ELEMS", 16 * 16)  # 1 row
        chunked = sphere.sph_nms_batch(boxes, scores, mask, THR,
                                       backend="host")
        assert (full == chunked).all()

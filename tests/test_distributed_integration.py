"""Multi-device integration tests (run in a subprocess with 8 fake
CPU devices so the main test process keeps its single-device world)."""

import json
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

# slow: subprocess jax restarts dominate runtime; multidevice: the CI
# multidevice lane runs these per PR (the subprocesses force their own
# 8 host devices, so the marker is routing, not a requirement)
pytestmark = [pytest.mark.slow, pytest.mark.multidevice]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import shard_map

    from repro.training import compression as comp
    from repro.training import optimizer as opt_mod

    mesh = jax.make_mesh((8,), ("data",))
    opt = opt_mod.sgd(lr=0.1, momentum=0.0)

    # data-parallel quadratic: each shard holds its own target; the
    # compressed psum must converge to the MEAN target.
    targets = jnp.arange(8.0)  # per-shard target
    params = {"w": jnp.zeros(())}
    state = opt.init(params)
    cstate = comp.CompressionState.zeros_like({"w": jnp.zeros(())})

    def local_grad(w, tgt):
        return {"w": 2 * (w - tgt)}

    @jax.jit
    def step(params, state, cstate, targets):
        def inner(p, tgt, cres):
            grads = local_grad(p["w"], tgt[0])
            mean, new_c = comp.compressed_psum_step(
                grads, comp.CompressionState({"w": cres}), "data",
                mode="bf16")
            return mean["w"], new_c.residual["w"]

        mean_g, new_res = shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P("data"), P()),
            out_specs=(P(), P()),
            check_vma=False,
        )(params, targets, cstate.residual["w"])
        new_params, new_state = opt.update({"w": mean_g}, params, state)
        return new_params, new_state, comp.CompressionState({"w": new_res})

    for _ in range(80):
        params, state, cstate = step(params, state, cstate, targets)

    print(json.dumps({"w": float(params["w"]),
                      "target": float(jnp.mean(targets))}))
""")

SCRIPT_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.distributed.elastic import remesh_plan

    # train on an 8-device mesh, checkpoint, "lose" 4 devices, restore
    # on the remesh plan's smaller mesh.
    import tempfile
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d)

    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    sh = NamedSharding(mesh8, P("data", "model"))
    w = jax.device_put(jnp.arange(32.0).reshape(8, 4), sh)
    mgr.save(1, {"w": w}, {"shape": [4, 2], "axes": ["data", "model"]})

    plan = remesh_plan((4, 2), ("data", "model"), healthy_devices=4)
    mesh_new = jax.make_mesh(plan["shape"], plan["axes"],
                             devices=jax.devices()[:plan["devices_used"]])
    restored = mgr.restore(1, {"w": jnp.zeros((8, 4))})
    w2 = jax.device_put(jnp.asarray(restored["w"]),
                        NamedSharding(mesh_new, P("data", "model")))
    ok = bool(jnp.all(w2 == jnp.arange(32.0).reshape(8, 4)))
    print(json.dumps({"ok": ok, "shape": list(plan["shape"]),
                      "devices": plan["devices_used"]}))
""")


def _run(script: str) -> dict:
    # 8 fake devices on few-core CI runners oversubscribe the host and
    # the shard_map compile dominates wall time, so the budget is wide;
    # CPU time per script is ~90s
    repo_root = pathlib.Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"}, timeout=900, cwd=repo_root)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_compressed_psum_shard_map_converges():
    res = _run(SCRIPT)
    assert abs(res["w"] - res["target"]) < 0.05, res


def test_elastic_checkpoint_remesh_roundtrip():
    res = _run(SCRIPT_ELASTIC)
    assert res["ok"]
    assert res["devices"] == 4


SCRIPT_MOE_A2A = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import transformer as T

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = T.TransformerConfig(name="m", n_layers=1, d_model=32, n_heads=4,
                              n_kv_heads=4, d_head=8, d_ff=0, vocab_size=11,
                              moe=True, n_experts=8, moe_top_k=2,
                              d_ff_expert=16, capacity_factor=16.0,
                              sequence_parallel=True, moe_a2a=True)
    p = jax.tree.map(lambda a: a[0],
                     T.init_params(jax.random.PRNGKey(0), cfg)
                     ["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    with mesh:
        xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None)))
        out_a2a, _ = jax.jit(lambda p, x: T.moe_block_a2a(p, x, cfg))(p, xs)
        out_ref, _ = jax.jit(lambda p, x: T.moe_block(p, x, cfg))(p, x)
        fwd = float(jnp.max(jnp.abs(out_a2a.astype(jnp.float32)
                                    - out_ref.astype(jnp.float32))))
        g1 = jax.jit(jax.grad(lambda p, x: jnp.sum(
            T.moe_block_a2a(p, x, cfg)[0] ** 2)))(p, xs)
    g2 = jax.jit(jax.grad(lambda p, x: jnp.sum(
        T.moe_block(p, x, cfg)[0] ** 2)))(p, x)
    gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    print(json.dumps({"fwd": fwd, "grad": gerr}))
""")


def test_moe_a2a_matches_implicit_path():
    """shard_map all-to-all EP == SPMD path, forward AND gradients
    (no capacity drops at cf=16)."""
    res = _run(SCRIPT_MOE_A2A)
    assert res["fwd"] < 1e-5, res
    assert res["grad"] < 1e-4, res

"""Per-kernel allclose sweeps against the pure-jnp oracles.

Every Pallas kernel runs in interpret mode on CPU (the kernel body is
executed exactly as written; only the Mosaic lowering is TPU-only).
Shapes and dtypes are swept per the brief.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projection
from repro.kernels.attention.ops import flash_attention, flash_attention_ref
from repro.kernels.gnomonic import ops as gno_ops
from repro.kernels.gnomonic.ref import gnomonic_sample_ref
from repro.kernels.sphiou.ops import sphiou_matrix, sphiou_matrix_batch
from repro.kernels.sphiou.ref import sphiou_ref, sphiou_ref_batch

RNG = np.random.default_rng(0)


# -- gnomonic -----------------------------------------------------------------


@pytest.mark.parametrize("center", [
    (0.0, 0.0), (3.0, 0.4), (-2.8, -0.9), (1.5, 1.3), (math.pi, 0.0),
])
@pytest.mark.parametrize("out,fov", [(64, 60), (32, 90), (48, 45)])
def test_gnomonic_matches_oracle(center, out, fov):
    erp = jnp.asarray(RNG.random((128, 256, 3)).astype(np.float32))
    fovr = (math.radians(fov), math.radians(fov))
    u, v = projection.gnomonic_coords(
        jnp.asarray(center[0]), jnp.asarray(center[1]), fovr, (out, out),
        erp.shape[:2])
    ref = gnomonic_sample_ref(erp, u, v)
    got = gno_ops.gnomonic_sample(erp, np.asarray(u), np.asarray(v))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-6, rtol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_gnomonic_dtypes(dtype):
    erp = jnp.asarray(RNG.random((64, 128, 3)).astype(dtype))
    fovr = (math.radians(60), math.radians(60))
    u, v = projection.gnomonic_coords(
        jnp.asarray(0.5), jnp.asarray(0.2), fovr, (32, 32), erp.shape[:2])
    ref = gnomonic_sample_ref(erp, u, v)
    got = gno_ops.gnomonic_sample(erp, np.asarray(u), np.asarray(v))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-3)


def test_gnomonic_vmem_fallback():
    """Pole-centred PI with a tiny VMEM cap falls back to the oracle."""
    erp = jnp.asarray(RNG.random((128, 256, 3)).astype(np.float32))
    fovr = (math.radians(120), math.radians(120))
    u, v = projection.gnomonic_coords(
        jnp.asarray(0.0), jnp.asarray(1.5), fovr, (16, 16), erp.shape[:2])
    got = gno_ops.gnomonic_sample(erp, np.asarray(u), np.asarray(v),
                                  vmem_cap=1024)
    ref = gnomonic_sample_ref(erp, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-6)


def test_project_sroi_kernel_end_to_end():
    erp = jnp.asarray(RNG.random((128, 256, 3)).astype(np.float32))
    pi_k = gno_ops.project_sroi_kernel(
        erp, 0.3, -0.1, (math.radians(60), math.radians(60)), (40, 40))
    pi_ref = projection.project_sroi(
        erp, jnp.asarray(0.3), jnp.asarray(-0.1),
        (math.radians(60), math.radians(60)), (40, 40))
    # coordinate maps are computed once eagerly and once under jit; op
    # fusion perturbs u/v at ~1e-7, which bilinear amplifies to ~1e-5.
    np.testing.assert_allclose(np.asarray(pi_k), np.asarray(pi_ref), atol=5e-5)


# -- sphiou -------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(1, 1), (7, 13), (64, 64), (100, 257),
                                 (256, 33)])
def test_sphiou_matches_oracle(n, m):
    rng = np.random.default_rng(n * 1000 + m)
    def boxes(k):
        return np.stack([
            rng.uniform(-math.pi, math.pi, k), rng.uniform(-1.4, 1.4, k),
            rng.uniform(0.05, 1.2, k), rng.uniform(0.05, 1.2, k)],
            axis=-1).astype(np.float32)
    a, b = boxes(n), boxes(m)
    ref = np.asarray(sphiou_ref(jnp.asarray(a), jnp.asarray(b)))
    got = np.asarray(sphiou_matrix(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, ref, atol=5e-6)


@pytest.mark.parametrize("b,n,m", [(1, 8, 8), (3, 17, 9), (4, 64, 64)])
def test_sphiou_batch_matches_vmapped_oracle(b, n, m):
    rng = np.random.default_rng(b * 100 + n)
    def boxes(rows, k):
        return np.stack([
            rng.uniform(-math.pi, math.pi, (rows, k)),
            rng.uniform(-1.4, 1.4, (rows, k)),
            rng.uniform(0.05, 1.2, (rows, k)),
            rng.uniform(0.05, 1.2, (rows, k))],
            axis=-1).astype(np.float32)
    a, bb = boxes(b, n), boxes(b, m)
    ref = np.asarray(sphiou_ref_batch(jnp.asarray(a), jnp.asarray(bb)))
    got = np.asarray(sphiou_matrix_batch(jnp.asarray(a), jnp.asarray(bb)))
    np.testing.assert_allclose(got, ref, atol=5e-6)


def test_sphiou_batch_rows_independent():
    # row r of the batched kernel == the unbatched kernel on row r
    rng = np.random.default_rng(17)
    a = np.stack([
        rng.uniform(-math.pi, math.pi, (3, 12)), rng.uniform(-1.2, 1.2, (3, 12)),
        rng.uniform(0.1, 1.0, (3, 12)), rng.uniform(0.1, 1.0, (3, 12))],
        axis=-1).astype(np.float32)
    got = np.asarray(sphiou_matrix_batch(jnp.asarray(a), jnp.asarray(a)))
    for r in range(3):
        single = np.asarray(sphiou_matrix(jnp.asarray(a[r]), jnp.asarray(a[r])))
        np.testing.assert_allclose(got[r], single, atol=1e-6)


def test_sphiou_diag_is_one():
    rng = np.random.default_rng(3)
    a = np.stack([rng.uniform(-3, 3, 32), rng.uniform(-1.2, 1.2, 32),
                  rng.uniform(0.1, 1.0, 32), rng.uniform(0.1, 1.0, 32)],
                 axis=-1).astype(np.float32)
    got = np.asarray(sphiou_matrix(jnp.asarray(a), jnp.asarray(a)))
    np.testing.assert_allclose(np.diag(got), 1.0, atol=1e-4)


# -- flash attention ----------------------------------------------------------

CASES = [
    dict(b=2, sq=64, skv=64, hq=4, hkv=4, d=32, causal=True, window=None),
    dict(b=1, sq=128, skv=128, hq=8, hkv=2, d=64, causal=True, window=None),
    dict(b=1, sq=96, skv=96, hq=2, hkv=2, d=32, causal=True, window=32),
    dict(b=2, sq=1, skv=200, hq=4, hkv=1, d=32, causal=True, window=None),
    dict(b=1, sq=64, skv=64, hq=2, hkv=2, d=32, causal=False, window=None),
    dict(b=1, sq=80, skv=160, hq=2, hkv=2, d=16, causal=True, window=64),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_matches_oracle(case):
    rng = np.random.default_rng(42)
    def mk(s, h):
        return jnp.asarray(rng.standard_normal(
            (case["b"], s, h, case["d"])).astype(np.float32))
    q = mk(case["sq"], case["hq"])
    k = mk(case["skv"], case["hkv"])
    v = mk(case["skv"], case["hkv"])
    qoff = case["skv"] - case["sq"] if case["causal"] else 0
    ref = flash_attention_ref(q, k, v, causal=case["causal"],
                              window=case["window"], q_offset=qoff)
    got = flash_attention(q, k, v, causal=case["causal"],
                          window=case["window"], q_offset=qoff)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 32)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((1, 64, 4, 32)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((1, 64, 4, 32)), dtype=dtype)
    ref = flash_attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_flash_attention_block_sizes():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 100, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 100, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 100, 2, 16)).astype(np.float32))
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(16, 16), (32, 64), (128, 128)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)

"""Serving driver: ``python -m repro.launch.serve [...]``.

Multiplexes N synthetic 360-degree streams through the OmniSense pod
scheduler (the paper's pipeline as the pod's control plane) and prints
per-tick throughput / batching stats. ``--backend jax`` runs the real
detector ladder on rendered frames; the default oracle backend is the
calibrated fast path.

    PYTHONPATH=src python -m repro.launch.serve --streams 8 --frames 16
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--budget", type=float, default=1.8)
    ap.add_argument("--bandwidth-mbps", type=float, default=17.9)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    variants = profiles.make_ladder()
    lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                NetworkModel(args.bandwidth_mbps))
    costs = [lat._pre(v) + lat._inf(v) for v in variants]

    loops, backends = [], []
    for s in range(args.streams):
        video = make_video(n_frames=args.frames + 8,
                           n_objects=30 + 5 * (s % 4), seed=100 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        loops.append(OmniSenseLoop(variants, lat, backend,
                                   budget_s=args.budget,
                                   explore_costs=costs))

    server = PodServer(loops, backends, max_batch=args.max_batch)
    stats = server.run(range(args.frames))
    print(f"served {stats.frames} frames across {args.streams} streams")
    print(f"detections: {stats.total_detections}  "
          f"mean plan latency: {stats.mean_e2e:.2f}s (budget {args.budget}s)")
    print(f"control-plane overhead: "
          f"{1e3 * stats.sum_overhead / stats.frames:.2f} ms/frame")
    if stats.batch_sizes:
        print(f"variant batches: mean={stats.mean_batch:.2f} "
              f"p95={int(np.percentile(stats.batch_sizes, 95))}")
    print(f"batched dispatches: {stats.dispatches}  "
          f"inference gain: {stats.batching_gain:.2f}x "
          f"({stats.sum_batched_inf_s:.1f}s batched vs "
          f"{stats.sum_per_request_inf_s:.1f}s per-request)")


if __name__ == "__main__":
    main()

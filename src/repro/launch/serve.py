"""Serving driver: ``python -m repro.launch.serve [...]``.

Multiplexes N synthetic 360-degree streams through the OmniSense pod
scheduler (the paper's pipeline as the pod's control plane) and prints
per-tick throughput / batching stats. ``--backend jax`` runs the real
detector ladder on rendered frames; the default oracle backend is the
calibrated fast path.

``--policy {sync,deadline,async}`` picks the drain policy of the
event-clock serving runtime (``repro.serving.runtime``):

  * ``sync``     — the tick barrier (default; pre-runtime behaviour,
    bit-identical);
  * ``deadline`` — earliest-deadline / weighted-shortest-first
    cross-variant dispatch ordering over the streams' budgets;
  * ``async``    — residual sub-bucket chunks carry to the next tick
    while their replica group is busy, priced by the overlap model:

    PYTHONPATH=src python -m repro.launch.serve --streams 8 --devices 8 \
        --policy async

``--devices D`` partitions D VIRTUAL device slots into per-variant
replica groups (``repro.serving.placement``): the V per-variant
forwards are scheduled concurrently and the tick model switches to the
device-aware max-over-groups — priced by the calibrated latency model,
no accelerators consulted:

    PYTHONPATH=src python -m repro.launch.serve --streams 8 --devices 8

``--pod-allocate`` switches admission to the pod-level allocator
(``repro.serving.pod_allocation``): each tick the per-stream knapsacks
are coupled through amortized batched costs and per-group queue
depth/utilisation by a fixed-point loop.  Since the runtime refactor
this is a property of the POLICY (``SchedulePolicy(pod_allocate=True)``;
the transitional bare-flag DeprecationWarning was removed on schedule):

    PYTHONPATH=src python -m repro.launch.serve --streams 8 --devices 8 \
        --policy sync --pod-allocate

``--open-loop`` (PR 6) feeds the pod arrival-clocked OPEN-LOOP traffic
(``repro.serving.traffic``) instead of the closed-loop frame barrier:
each stream's camera ticks at ``--fps`` with seeded lognormal
``--jitter``, a frame whose predecessor still occupies the depth-1
camera buffer is counted missed (never fabricated), and every arrival
passes the policy's admission hook against the ``--slo`` envelope —
``--admission slo`` degrades or rejects when the projected queueing
load would blow it:

    PYTHONPATH=src python -m repro.launch.serve --streams 8 \
        --open-loop --fps 0.5 --jitter 0.2 --slo 2.0 --admission slo

``--pods P`` serves the open-loop traffic through the FLEET tier
(``repro.serving.fleet``): P pods behind a ``--routing`` stream router
(sticky ``least-loaded`` balance, or ``affinity`` consistent hashing
so co-variant streams co-locate and batch), with ``--devices`` split
per pod by ``serving_scale_plan``:

    PYTHONPATH=src python -m repro.launch.serve --streams 32 \
        --open-loop --fps 0.5 --slo 2.0 --admission slo \
        --devices 8 --pods 4 --routing affinity

The REAL shard_map-sharded detector path is exercised by
``benchmarks/serving_bench.py --devices 8`` and the `multidevice` test
lane (both force fake host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.runtime import make_policy
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import PodServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--budget", type=float, default=1.8)
    ap.add_argument("--bandwidth-mbps", type=float, default=17.9)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0,
                    help="partition this many device slots into per-variant "
                         "replica groups (0 = single-device pod)")
    ap.add_argument("--policy", choices=("sync", "deadline", "async"),
                    default=None,
                    help="drain policy of the serving runtime "
                         "(repro.serving.runtime; default sync — the "
                         "pre-runtime tick barrier, bit-identical)")
    ap.add_argument("--pod-allocate", action="store_true",
                    help="couple the per-stream knapsacks through batched "
                         "costs and group utilisation (the fixed-point "
                         "pod-level allocator; an admission property of "
                         "the --policy object since the runtime refactor)")
    ap.add_argument("--open-loop", action="store_true",
                    help="feed arrival-clocked open-loop traffic "
                         "(repro.serving.traffic) instead of the "
                         "closed-loop frame barrier: per-stream fps "
                         "clocks, depth-1 camera buffer, admission "
                         "control, SLO goodput accounting")
    ap.add_argument("--fps", type=float, default=0.5,
                    help="per-stream arrival rate for --open-loop")
    ap.add_argument("--jitter", type=float, default=0.2,
                    help="lognormal sigma on open-loop inter-arrival times")
    ap.add_argument("--slo", type=float, default=2.0,
                    help="end-to-end SLO for open-loop goodput accounting")
    ap.add_argument("--admission", choices=("admit-all", "slo"),
                    default="admit-all",
                    help="open-loop admission policy: admit everything, or "
                         "degrade/reject when projected load exceeds the "
                         "SLO envelope")
    ap.add_argument("--events", default=None, metavar="PATH",
                    help="write the structured JSONL telemetry event log "
                         "here (repro.serving.telemetry; inspect with "
                         "python -m repro.launch.replay report PATH)")
    ap.add_argument("--pods", type=int, default=0,
                    help="serve through a FleetServer of this many pods "
                         "(repro.serving.fleet; requires --open-loop; "
                         "--devices is the FLEET-wide budget split per "
                         "pod; 0 = the single-pod server)")
    ap.add_argument("--routing", choices=("least-loaded", "affinity"),
                    default="least-loaded",
                    help="fleet stream-routing policy (with --pods): "
                         "sticky least-loaded balance, or consistent "
                         "hashing on content affinity so co-variant "
                         "streams batch together")
    ap.add_argument("--tasks", choices=("detection", "action", "mixed"),
                    default="detection",
                    help="analytics task mix (repro.serving.tasks "
                         "registry): homogeneous detection (default, "
                         "honours --bandwidth-mbps), homogeneous "
                         "action recognition, or an alternating mixed "
                         "pod whose two variant ladders share one "
                         "capacity envelope")
    args = ap.parse_args()
    if args.pods and not args.open_loop:
        ap.error("--pods requires --open-loop (the fleet tier serves "
                 "arrival-clocked traffic)")
    policy = make_policy(args.policy or "sync",
                         pod_allocate=args.pod_allocate,
                         admission=args.admission if args.open_loop
                         else None)

    if args.tasks == "detection":
        variants = profiles.make_ladder()
        lat = OmniSenseLatencyModel(profiles.paper_profile(),
                                    NetworkModel(args.bandwidth_mbps))
        costs = [lat._pre(v) + lat._inf(v) for v in variants]
        cost_fn = lat._inf
        loops, backends = [], []
        for s in range(args.streams):
            video = make_video(n_frames=args.frames + 8,
                               n_objects=30 + 5 * (s % 4), seed=100 + s)
            backend = OracleBackend(video)
            backends.append(backend)
            loops.append(OmniSenseLoop(variants, lat, backend,
                                       budget_s=args.budget,
                                       explore_costs=costs))
    else:
        from repro.serving import tasks as task_registry

        stream_tasks = task_registry.stream_tasks_for(args.tasks,
                                                      args.streams)
        videos = [make_video(n_frames=args.frames + 8,
                             n_objects=30 + 5 * (s % 4), seed=100 + s)
                  for s in range(args.streams)]
        variants, loops, backends, cost_fn = \
            task_registry.build_task_streams(
                stream_tasks, videos, [args.budget] * args.streams)

    placement = None
    if args.devices > 0:
        from repro.serving.placement import VariantPlacement

        placement = VariantPlacement.virtual(variants, args.devices,
                                             cost_fn=cost_fn)

    telemetry = None
    if args.events:
        from repro.serving.telemetry import JsonlSink

        telemetry = JsonlSink(args.events)

    if args.pods > 0:
        from repro.distributed.elastic import serving_scale_plan
        from repro.serving.fleet import FleetServer, format_fleet_report
        from repro.serving.traffic import ArrivalProcess

        per_pod = serving_scale_plan(args.devices,
                                     args.pods)["per_pod_devices"]

        def make_pod(pod_id: int) -> PodServer:
            pod_placement = None
            if per_pod > 0:
                from repro.serving.placement import VariantPlacement

                pod_placement = VariantPlacement.virtual(
                    variants, per_pod, cost_fn=cost_fn)
            pol = make_policy(args.policy or "sync",
                              pod_allocate=args.pod_allocate,
                              admission=args.admission)
            return PodServer(loops, backends, max_batch=args.max_batch,
                             placement=pod_placement, policy=pol)

        fleet = FleetServer(make_pod, args.pods, routing=args.routing,
                            telemetry=telemetry)
        horizon_s = args.frames / args.fps
        traffic = ArrivalProcess(args.streams, fps=args.fps,
                                 jitter=args.jitter, seed=0,
                                 horizon_s=horizon_s)
        fstats = fleet.run_open_loop(traffic, slo_s=args.slo)
        if telemetry is not None:
            telemetry.close()
            print(f"telemetry event log: {args.events}")
        for line in format_fleet_report(fstats, horizon_s):
            print(line)
        return

    server = PodServer(loops, backends, max_batch=args.max_batch,
                       placement=placement, policy=policy,
                       telemetry=telemetry)
    horizon_s = None
    if args.open_loop:
        from repro.serving.traffic import ArrivalProcess

        horizon_s = args.frames / args.fps
        traffic = ArrivalProcess(args.streams, fps=args.fps,
                                 jitter=args.jitter, seed=0,
                                 horizon_s=horizon_s)
        stats = server.run_open_loop(traffic, slo_s=args.slo)
    else:
        stats = server.run(range(args.frames))
    if telemetry is not None:
        telemetry.close()
        print(f"telemetry event log: {args.events}")
    print(f"served {stats.frames} frames across {args.streams} streams "
          f"[{stats.policy} policy]")
    print(f"detections: {stats.total_detections}  "
          f"mean plan latency: {stats.mean_e2e:.2f}s (budget {args.budget}s)")
    if policy.pod_allocate:
        from repro.serving.server import format_pod_allocation_report

        print(format_pod_allocation_report(stats))
    if len(server.tasks) > 1:
        per = ", ".join(
            f"{t}: {stats.frames_by_task.get(t, 0)} frames, "
            f"proxy {p:.3f}"
            for t, p in stats.accuracy_proxy_by_task.items())
        print(f"per-task ({'+'.join(server.tasks)} pod): {per}")
    print(f"control-plane overhead: "
          f"{1e3 * stats.sum_overhead / stats.frames:.2f} ms/frame")
    if stats.batch_sizes:
        print(f"variant batches: mean={stats.mean_batch:.2f} "
              f"p95={int(np.percentile(stats.batch_sizes, 95))}")
    print(f"batched dispatches: {stats.dispatches}  "
          f"inference gain: {stats.batching_gain:.2f}x "
          f"({stats.sum_batched_inf_s:.1f}s batched vs "
          f"{stats.sum_per_request_inf_s:.1f}s per-request)")
    pct = stats.event_e2e_percentiles()
    print(f"event-clock tick: mean={stats.mean_tick:.3f}s  "
          f"E2E p50/p95/p99={pct[50]:.2f}/{pct[95]:.2f}/{pct[99]:.2f}s  "
          f"carried requests: {stats.carried_requests} "
          f"({stats.carry_tick_slots} request-ticks)")
    if placement is not None:
        from repro.serving.server import format_group_report

        for line in format_group_report(stats, placement):
            print(line)
    if horizon_s is not None:
        from repro.serving.server import format_open_loop_report

        for line in format_open_loop_report(stats, horizon_s):
            print(line)


if __name__ == "__main__":
    main()

"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs a reduced-config training loop for any assigned architecture on
the local device(s): synthetic data pipeline, AdamW, gradient clipping,
async checkpointing with crash-restart, straggler-policy bookkeeping.
The FULL configs are exercised via ``repro.launch.dryrun`` (compile
only); this driver proves the loop end-to-end at smoke scale.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import base as cfgbase
from repro.data.pipeline import Prefetcher, lm_batches
from repro.models import diffusion as diff_mod
from repro.models import transformer as lm_mod
from repro.models import vision as vis_mod
from repro.training import optimizer as opt_mod
from repro.training import steps as steps_mod


def make_batch_gen(arch, cfg, batch, rng):
    if arch.family == "lm":
        return Prefetcher(lm_batches(cfg.vocab_size, batch, 32), depth=2)

    def vision_gen():
        while True:
            yield {
                "images": rng.standard_normal(
                    (batch, cfg.img_res, cfg.img_res, 3)).astype(np.float32),
                "labels": rng.integers(0, cfg.n_classes, batch).astype(np.int32),
            }

    def diffusion_gen():
        is_flux = isinstance(cfg, diff_mod.MMDiTConfig)
        while True:
            b = {"latents": rng.standard_normal(
                    (batch, cfg.latent_res, cfg.latent_res, cfg.latent_ch)
                 ).astype(np.float32),
                 "seed": np.int32(rng.integers(0, 2 ** 31))}
            if is_flux:
                b["ctx"] = rng.standard_normal(
                    (batch, cfg.n_ctx_tokens, cfg.d_ctx)).astype(np.float32)
                b["pooled"] = rng.standard_normal(
                    (batch, cfg.d_pooled)).astype(np.float32)
            else:
                b["ctx"] = rng.standard_normal(
                    (batch, cfg.n_ctx_tokens, cfg.ctx_dim)).astype(np.float32)
                b["add_emb"] = rng.standard_normal(
                    (batch, cfg.d_add)).astype(np.float32)
            yield b

    return Prefetcher(vision_gen() if arch.family == "vision"
                      else diffusion_gen(), depth=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    arch = cfgbase.get_arch(args.arch)
    cfg = arch.smoke
    opt = opt_mod.adamw(lr=args.lr, warmup_steps=10)

    if arch.family == "lm":
        params = lm_mod.init_params(jax.random.PRNGKey(0), cfg)
        step_fn = steps_mod.lm_train_step(cfg, opt)
    elif arch.family == "vision":
        init = {vis_mod.ViTConfig: vis_mod.vit_init,
                vis_mod.ConvNeXtConfig: vis_mod.convnext_init,
                vis_mod.ResNetConfig: vis_mod.resnet_init}[type(cfg)]
        params = init(jax.random.PRNGKey(0), cfg)
        step_fn = steps_mod.vision_train_step(cfg, opt)
    else:
        init = diff_mod.mmdit_init if isinstance(cfg, diff_mod.MMDiTConfig) \
            else diff_mod.unet_init
        params = init(jax.random.PRNGKey(0), cfg)
        step_fn = steps_mod.diffusion_train_step(cfg, opt)

    step_fn = jax.jit(step_fn)
    state = steps_mod.make_state(params, opt)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = jax.tree.map(jnp.asarray, mgr.restore(start, state))
        print(f"restored checkpoint at step {start}")

    gen = make_batch_gen(arch, cfg, args.batch, np.random.default_rng(0))
    t0 = time.time()
    for i, batch in zip(range(start, args.steps), gen):
        state, metrics = step_fn(
            state, {k: jnp.asarray(v) for k, v in batch.items()})
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i + 1:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / max(i + 1 - start, 1):.2f}s/step)")
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save_async(i + 1, state)
    if mgr is not None:
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()

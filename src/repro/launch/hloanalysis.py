"""Trip-count-aware cost analysis of optimised HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts each ``while``
body ONCE, but every model here scans over stacked layer params (an
88-layer transformer is a trip-count-88 while loop), so FLOPs, HBM
traffic and collective bytes would be undercounted by 1-2 orders of
magnitude.  This analyzer parses the post-SPMD HLO text, recovers loop
trip counts from scan-generated conditions, and multiplies each
computation's costs by its dynamic execution count.

Model:
  * FLOPs   — ``dot`` (2 x out_numel x contracted size) and
    ``convolution`` (2 x out_numel x kernel_spatial x in_features/group)
    wherever they appear (top level or inside fusion bodies).
  * HBM bytes — fusion-IO model: for every *control-level* op of an
    HBM-traffic class (fusion, dot, convolution, copy, slice ops, sort,
    collectives, ...), operand bytes + result bytes.  Ops inside fusion
    bodies are register traffic and not counted.
  * collective bytes — operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (and -start forms).

All numbers are PER DEVICE (the module is the SPMD-partitioned program
of one device); multiply by chip count for global totals.

Known approximations (documented in EXPERIMENTS.md):
  * trip counts come from the largest integer constant in the loop
    condition computation (exact for scan-lowered loops);
  * conditional branches count as always-taken (upper bound);
  * reducer/comparator computations (``to_apply=``) are ignored for
    FLOPs (elementwise);
  * the bytes model charges each fusion its full I/O — XLA may still
    keep small operands in registers across fusions.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# Control-level ops whose operands/results move through HBM.  Bare
# layout ops (reshape / transpose / broadcast / copy / pad / slice /
# concatenate / iota) are EXCLUDED: on the TPU target XLA fuses them
# into their consumers, so counting them (as the CPU-compiled module
# materialises them) would overstate HBM traffic several-fold.  This is
# the fusion-IO traffic model documented in EXPERIMENTS.md.
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "sort", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "select-and-scatter", "rng-bit-generator",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
    "custom-call", "cholesky", "triangular-solve",
}


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes mentioned in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _split_top(s: str) -> list[str]:
    """Split on commas at paren/brace depth 0."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


@dataclasses.dataclass
class Op:
    name: str
    result_shape: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    params: dict  # name -> shape string
    ops: list
    symbols: dict  # op name -> result shape string


_OP_RE = re.compile(r"^\s+(?:ROOT )?%([\w\.\-]+) = (.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\((.*)\)\s*->\s*.*\{")


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _HDR_RE.match(line)
        if hdr is not None:
            params = {}
            for entry in _split_top(hdr.group(3)):
                if ":" in entry:
                    pname, pshape = entry.split(":", 1)
                    params[pname.strip()] = pshape.strip()
            cur = Computation(hdr.group(2), bool(hdr.group(1)), params, [],
                              dict(params))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m is None:
            continue
        name, rhs = m.group(1), m.group(2)
        # result shape: up to the opcode token
        om = re.match(r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[\w\[\],]+(?:\{[^}]*\})*))\s+([\w\-]+)\((.*)$", rhs)
        if om is None:
            continue
        rshape, opcode, rest = om.group(1), om.group(2), om.group(3)
        # operands: match parens
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands_str = rest[:idx]
        attrs = rest[idx + 1:]
        operands = [o for o in _split_top(operands_str)]
        cur.ops.append(Op(name, rshape, opcode, operands, attrs, line))
        cur.symbols[name] = rshape
    return comps


def _operand_shape(comp: Computation, operand: str) -> str:
    """Resolve an operand reference to its shape string."""
    # operands look like '%name' or 'f32[2,3] %name' (older dialect) or
    # a literal constant.
    tok = operand.strip()
    if tok.startswith("%"):
        return comp.symbols.get(tok[1:], "")
    # maybe 'dtype[dims] %name'
    m = re.match(r"(.+?)\s+%([\w\.\-]+)$", tok)
    if m:
        return m.group(1)
    return ""


def _dot_flops(comp: Computation, op: Op) -> float:
    out_dims = _shape_dims(op.result_shape)
    out_numel = 1
    for d in out_dims:
        out_numel *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    lhs_shape = _shape_dims(_operand_shape(comp, op.operands[0])) if op.operands else []
    contracted = 1
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contracted *= lhs_shape[int(d)]
    return 2.0 * out_numel * max(contracted, 1)


def _conv_flops(comp: Computation, op: Op) -> float:
    out_numel = 1
    for d in _shape_dims(op.result_shape):
        out_numel *= d
    if len(op.operands) < 2:
        return 0.0
    k_shape = _shape_dims(_operand_shape(comp, op.operands[1]))
    m = re.search(r"dim_labels=\S*_(\S+?)->", op.attrs)
    kernel_in = 1
    spatial = 1
    if m and k_shape:
        labels = m.group(1)
        for dim, lab in enumerate(labels):
            if dim >= len(k_shape):
                continue
            if lab == "i":
                kernel_in = k_shape[dim]
            elif lab not in ("o",):
                spatial *= k_shape[dim]
    else:
        spatial = 1
        kernel_in = 1
    return 2.0 * out_numel * spatial * kernel_in


def _trip_count(comps: dict, cond_name: str) -> int:
    """Trip count of a scan-lowered while: the loop condition compares
    the induction variable against a scalar constant.  We look for the
    constant feeding the ROOT compare/fusion; falls back to the largest
    scalar constant in the condition computation."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    root = next((op for op in reversed(cond.ops)
                 if "ROOT" in op.line), None)
    consts = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    if root is not None:
        for operand in root.operands:
            nm = operand.lstrip("%")
            if nm in consts:
                return max(consts[nm], 1)
    return max(consts.values(), default=1)


def analyze(text: str, detail: bool = False) -> dict:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # multiplicities: control comps execute ops; fused comps only
    # contribute flops for dot/conv inside them.
    control_mult: dict[str, float] = defaultdict(float)
    fused_mult: dict[str, float] = defaultdict(float)
    control_mult[entry.name] = 1.0

    # breadth-first over the call graph
    frontier = [entry.name]
    visited_edges = set()
    while frontier:
        cname = frontier.pop()
        comp = comps[cname]
        mult = control_mult[cname]
        for op in comp.ops:
            if op.opcode == "while":
                bm = re.search(r"body=%([\w\.\-]+)", op.attrs)
                cm = re.search(r"condition=%([\w\.\-]+)", op.attrs)
                if bm:
                    trips = _trip_count(comps, cm.group(1)) if cm else 1
                    key = (cname, bm.group(1))
                    if key not in visited_edges:
                        visited_edges.add(key)
                        control_mult[bm.group(1)] += mult * trips
                        frontier.append(bm.group(1))
            elif op.opcode == "fusion":
                fm = re.search(r"calls=%([\w\.\-]+)", op.attrs)
                if fm:
                    fused_mult[fm.group(1)] += mult
            elif op.opcode in ("call", "async-start"):
                tm = re.search(r"to_apply=%([\w\.\-]+)", op.attrs)
                if tm:
                    key = (cname, tm.group(1))
                    if key not in visited_edges:
                        visited_edges.add(key)
                        control_mult[tm.group(1)] += mult
                        frontier.append(tm.group(1))
            elif op.opcode == "conditional":
                for br in re.findall(r"%([\w\.\-]+)", op.attrs):
                    if br in comps and (cname, br) not in visited_edges:
                        visited_edges.add((cname, br))
                        control_mult[br] += mult
                        frontier.append(br)

    flops = 0.0
    hbm_bytes = 0.0
    coll = {k: {"bytes": 0.0, "count": 0.0} for k in COLLECTIVE_KINDS}
    contributions: list[tuple[float, str]] = []
    byte_contribs: list[tuple[float, str]] = []

    for cname, comp in comps.items():
        cm = control_mult.get(cname, 0.0)
        fm = fused_mult.get(cname, 0.0)
        for op in comp.ops:
            # FLOPs: dot/conv anywhere, weighted by the enclosing
            # computation's execution count.
            w = cm + fm
            if w > 0 and op.opcode == "dot":
                f = w * _dot_flops(comp, op)
                flops += f
                if detail:
                    contributions.append((f, f"x{w:.0f} {cname}: {op.line.strip()[:180]}"))
            elif w > 0 and op.opcode == "convolution":
                f = w * _conv_flops(comp, op)
                flops += f
                if detail:
                    contributions.append((f, f"x{w:.0f} {cname}: {op.line.strip()[:180]}"))

            if cm <= 0:
                continue  # bytes/collectives only at control level
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_KINDS and not op.opcode.endswith("-done"):
                b = sum(_shape_bytes(_operand_shape(comp, o))
                        for o in op.operands)
                coll[base]["bytes"] += cm * b
                coll[base]["count"] += cm
            if op.opcode in _TRAFFIC_OPS:
                rb = _shape_bytes(op.result_shape)
                ob = sum(_shape_bytes(_operand_shape(comp, o))
                         for o in op.operands)
                hbm_bytes += cm * (rb + ob)
                if detail:
                    byte_contribs.append(
                        (cm * (rb + ob),
                         f"x{cm:.0f} {cname}: {op.line.strip()[:170]}"))

    total_coll = sum(v["bytes"] for v in coll.values())
    out = {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": {k: v for k, v in coll.items()},
        "collective_bytes": total_coll,
        "n_computations": len(comps),
    }
    if detail:
        contributions.sort(reverse=True)
        byte_contribs.sort(reverse=True)
        out["top_flops"] = contributions[:25]
        out["top_bytes"] = byte_contribs[:25]
        out["multipliers"] = {k: v for k, v in sorted(
            control_mult.items(), key=lambda kv: -kv[1])[:20]}
    return out

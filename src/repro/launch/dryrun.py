import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, and extract the roofline terms.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first initialisation, so the 512 placeholder
host devices have to be requested before any jax import (including the
transitive ones below).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-34b \
        --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

Per cell it records: compile wall-time, per-device memory analysis,
HLO FLOPs/bytes from ``compiled.cost_analysis()``, and collective
bytes parsed from the optimised HLO (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand sizes) —
everything EXPERIMENTS.md sections Dry-run and Roofline consume.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro.launch import cells as cells_mod
from repro.launch import hloanalysis
from repro.launch import mesh as mesh_mod

def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    cell = cells_mod.build_cell(arch_id, shape_name)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings(mesh))
        lowered = jitted.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        t2 = time.time()
        analysis = hloanalysis.analyze(compiled.as_text())
        t_analyze = time.time() - t2

    n_dev = int(np.prod(mesh.devices.shape))
    mem_fields = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "host_temp_size_in_bytes"):
        mem_fields[f] = int(getattr(mem, f, 0) or 0)
    record = {
        "arch": arch_id,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "memory": mem_fields,
        # global quantities: per-device analyzer numbers x devices
        "hlo_flops": analysis["flops"] * n_dev,
        "hlo_bytes": analysis["hbm_bytes"] * n_dev,
        "collective_bytes": analysis["collective_bytes"] * n_dev,
        "collectives_per_device": analysis["collectives"],
        # raw XLA aggregate (counts while bodies once; kept for reference)
        "xla_cost_flops_per_device": float(cost.get("flops", 0.0)),
        "model_flops": cell.model_flops,
        "comment": cell.comment,
    }
    return record


def roofline_terms(record: dict, chips: int | None = None) -> dict:
    """Three-term roofline (seconds) from a dry-run record."""
    chips = chips or record["devices"]
    compute_s = record["hlo_flops"] / (chips * mesh_mod.PEAK_FLOPS_BF16)
    memory_s = record["hlo_bytes"] / (chips * mesh_mod.HBM_BW)
    coll_s = record["collective_bytes"] / (chips * mesh_mod.ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    useful = record["model_flops"] / max(record["hlo_flops"], 1.0)
    bound = max(terms.values())
    return {**terms, "dominant": dominant, "useful_flops_ratio": useful,
            "roofline_fraction": (record["model_flops"] /
                                  (chips * mesh_mod.PEAK_FLOPS_BF16)) / bound
            if bound > 0 else 0.0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        todo = list(cells_mod.iter_cells())
    else:
        todo = [(args.arch, args.shape, None)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for multi_pod in meshes:
        tag = "multipod" if multi_pod else "singlepod"
        for arch_id, shape_name, skip in todo:
            name = f"{arch_id}__{shape_name}__{tag}"
            path = out_dir / f"{name}.json"
            if skip is not None:
                path.write_text(json.dumps(
                    {"arch": arch_id, "shape": shape_name, "skipped": skip},
                    indent=2))
                print(f"[SKIP] {name}: {skip}")
                continue
            if path.exists():
                print(f"[CACHED] {name}")
                continue
            try:
                rec = run_cell(arch_id, shape_name, multi_pod)
                rec["roofline"] = roofline_terms(rec)
                path.write_text(json.dumps(rec, indent=2))
                r = rec["roofline"]
                print(f"[OK] {name}: compile={rec['compile_s']}s "
                      f"flops={rec['hlo_flops']:.3e} "
                      f"coll={rec['collective_bytes']:.3e}B "
                      f"dominant={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f}", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"[FAIL] {name}: {e}")
                (out_dir / f"{name}.err").write_text(traceback.format_exc())
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()

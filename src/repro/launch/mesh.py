"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant)
so importing this module touches no jax device state.  The dry-run
launcher sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
*before* any jax import; everything else (tests, benches) sees the
real single CPU device.

Axes:
  * single pod:  (data=16, model=16)          — 256 chips (one v5e pod)
  * multi-pod:   (pod=2, data=16, model=16)   — 512 chips across 2 pods

The ``pod`` axis is the outermost (slowest) axis so inter-pod (DCN)
collectives are confined to the pure-DP gradient reduction.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1x1 mesh on the real local device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per the assignment brief)
CHIPS_PER_POD = 256

"""Cell builder: (architecture x input shape x mesh) -> lowerable step.

A *cell* bundles everything the dry-run and roofline need:

  * ``step``          — the jit-able function (train / prefill / decode /
                        denoise / serve)
  * ``abstract_args`` — ShapeDtypeStruct pytrees for every argument
                        (no device allocation, ever)
  * ``in_shardings``  — NamedSharding pytrees matching abstract_args
  * ``model_flops``   — analytic "useful" FLOPs (6ND-style) for the
                        MODEL_FLOPS / HLO_FLOPS roofline ratio
  * ``comment``       — human-readable notes (e.g. sampler-loop factor)

``input_specs(arch, shape)`` returns only the abstract inputs — the
shape-audit entry point required by the brief.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.distributed import sharding as shd
from repro.models import diffusion as diff_mod
from repro.models import transformer as lm_mod
from repro.models import vision as vis_mod
from repro.training import optimizer as opt_mod
from repro.training import steps as steps_mod

Sds = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step: Callable
    abstract_args: tuple
    in_specs: tuple  # PartitionSpec pytrees (mesh-independent description)
    model_flops: float
    comment: str = ""

    def in_shardings(self, mesh: Mesh):
        """NamedShardings adapted to the mesh: axes absent from the mesh
        (e.g. 'pod' on single-pod) or not dividing the dimension evenly
        (e.g. batch=1 long-context cells) are dropped per-leaf."""
        shd.set_mesh_axis_sizes(mesh)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

        def fix(abstract, spec):
            axes = []
            for dim, ax in enumerate(spec):
                ax = shd._filter_axes(ax)
                if ax is None:
                    axes.append(None)
                    continue
                names = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([sizes[a] for a in names]))
                ok = dim < len(abstract.shape) and abstract.shape[dim] % size == 0
                axes.append(ax if ok else None)
            while axes and axes[-1] is None:
                axes.pop()
            return NamedSharding(mesh, P(*axes))

        return jax.tree.map(fix, self.abstract_args, self.in_specs,
                            is_leaf=lambda x: isinstance(x, Sds))


def _abstract(tree):
    return jax.tree.map(lambda x: Sds(x.shape, x.dtype), tree)


def _eval_params(init_fn) -> Any:
    return _abstract(jax.eval_shape(init_fn))


def _opt_abstract(params_abs) -> dict:
    """AdamW state: f32 moments mirroring params + i32 step."""
    moments = jax.tree.map(lambda s: Sds(s.shape, jnp.float32), params_abs)
    return {"mu": moments,
            "nu": jax.tree.map(lambda s: Sds(s.shape, jnp.float32), params_abs),
            "step": Sds((), jnp.int32)}


def _state_abstract(params_abs) -> dict:
    return {"params": params_abs, "opt": _opt_abstract(params_abs),
            "step": Sds((), jnp.int32)}


def _state_specs(param_specs) -> dict:
    return {"params": param_specs,
            "opt": {"mu": param_specs, "nu": param_specs, "step": P()},
            "step": P()}


_OPT = opt_mod.adamw(lr=1e-4)


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------


def _lm_cell(arch: cfgbase.ArchSpec, shape: cfgbase.ShapeSpec) -> Cell:
    cfg: lm_mod.TransformerConfig = arch.config
    b, s = shape.global_batch, shape.seq_len
    params_abs = _eval_params(lambda: lm_mod.init_params(jax.random.PRNGKey(0), cfg))
    param_specs = shd.spec_tree(
        params_abs,
        shd.lm_param_rules(n_experts=cfg.n_experts if cfg.moe else 0))
    tokens_active = b * s

    if shape.kind == "train":
        step = steps_mod.lm_train_step(cfg, _OPT)
        batch_abs = {"tokens": Sds((b, s), jnp.int32),
                     "targets": Sds((b, s), jnp.int32)}
        args = (_state_abstract(params_abs), batch_abs)
        specs = (_state_specs(param_specs), shd.lm_batch_specs("train"))
        flops = 6.0 * cfg.n_active_params * tokens_active
        comment = f"6*N_active*D with N_active={cfg.n_active_params:.3e}"
    elif shape.kind == "prefill":
        step = steps_mod.lm_prefill_step(cfg, max_len=s)
        batch_abs = {"tokens": Sds((b, s), jnp.int32)}
        args = (params_abs, batch_abs)
        specs = (param_specs, shd.lm_batch_specs("prefill"))
        flops = 2.0 * cfg.n_active_params * tokens_active
        comment = "forward-only 2*N_active*D"
    else:  # decode
        step = steps_mod.lm_decode_step(cfg)
        s_cache = lm_mod.cache_length(cfg, s)
        cache_shape = (cfg.n_layers, b, s_cache, cfg.n_kv_heads, cfg.d_head)
        batch_abs = {
            "token": Sds((b,), jnp.int32),
            "cache_k": Sds(cache_shape, cfg.compute_dtype),
            "cache_v": Sds(cache_shape, cfg.compute_dtype),
            "cache_len": Sds((), jnp.int32),
        }
        args = (params_abs, batch_abs)
        specs = (param_specs, shd.lm_batch_specs("decode"))
        # one token per stream + KV-cache attention reads
        flops = 2.0 * cfg.n_active_params * b \
            + 4.0 * cfg.n_layers * b * s_cache * cfg.n_heads * cfg.d_head
        comment = (f"decode: 2*N_active*B + attention over cache "
                   f"(S_cache={s_cache})")
    return Cell(arch.arch_id, shape.name, shape.kind, step, args, specs,
                flops, comment)


# --------------------------------------------------------------------------
# vision cells
# --------------------------------------------------------------------------


def _vision_flops_per_image(cfg, res: int) -> float:
    """Analytic 2*MAC forward-FLOPs per image."""
    if isinstance(cfg, vis_mod.ViTConfig):
        n_tok = (res // cfg.patch) ** 2 + 1
        d, f = cfg.d_model, cfg.d_ff
        per_layer = 2 * n_tok * (4 * d * d + 2 * d * f) + 4 * n_tok * n_tok * d
        stem = 2 * n_tok * cfg.patch ** 2 * 3 * d
        return cfg.n_layers * per_layer + stem
    if isinstance(cfg, vis_mod.ConvNeXtConfig):
        total, res_c = 0.0, res // 4
        total += 2 * (res // 4) ** 2 * 4 * 4 * 3 * cfg.dims[0]
        prev = cfg.dims[0]
        for depth, dim in zip(cfg.depths, cfg.dims):
            if dim != prev:
                res_c //= 2
                total += 2 * res_c ** 2 * 2 * 2 * prev * dim
            total += depth * 2 * res_c ** 2 * (7 * 7 * dim + 8 * dim * dim)
            prev = dim
        return total
    # ResNet bottlenecks
    total = 2 * (res // 2) ** 2 * 7 * 7 * 3 * cfg.width
    res_c = res // 4
    c_in = cfg.width
    for i, depth in enumerate(cfg.depths):
        mid = cfg.width * 2 ** i
        out = mid * 4
        if i > 0:
            res_c //= 2
        total += 2 * res_c ** 2 * (c_in * mid + 9 * mid * mid + mid * out + c_in * out)
        total += (depth - 1) * 2 * res_c ** 2 * (out * mid + 9 * mid * mid + mid * out)
        c_in = out
    return total


def _vision_cell(arch: cfgbase.ArchSpec, shape: cfgbase.ShapeSpec) -> Cell:
    cfg = arch.config
    res, b = shape.img_res, shape.batch
    if getattr(cfg, "img_res", res) != res:
        cfg = dataclasses.replace(cfg, img_res=res)
    init = {vis_mod.ViTConfig: vis_mod.vit_init,
            vis_mod.ConvNeXtConfig: vis_mod.convnext_init,
            vis_mod.ResNetConfig: vis_mod.resnet_init}[type(cfg)]
    params_abs = _eval_params(lambda: init(jax.random.PRNGKey(0), cfg))
    param_specs = shd.spec_tree(params_abs, shd.vision_param_rules())
    fwd = _vision_flops_per_image(cfg, res) * b

    if shape.kind == "train":
        step = steps_mod.vision_train_step(cfg, _OPT)
        batch_abs = {"images": Sds((b, res, res, 3), cfg.compute_dtype),
                     "labels": Sds((b,), jnp.int32)}
        args = (_state_abstract(params_abs), batch_abs)
        specs = (_state_specs(param_specs), shd.vision_batch_specs())
        flops, comment = 3.0 * fwd, "3x analytic forward MACs (fwd+bwd)"
    else:
        step = steps_mod.vision_serve_step(cfg)
        batch_abs = {"images": Sds((b, res, res, 3), cfg.compute_dtype)}
        args = (params_abs, batch_abs)
        specs = (param_specs, {"images": shd.vision_batch_specs()["images"]})
        flops, comment = fwd, "analytic forward MACs"
    return Cell(arch.arch_id, shape.name, shape.kind, step, args, specs,
                flops, comment)


# --------------------------------------------------------------------------
# diffusion cells
# --------------------------------------------------------------------------


def _diffusion_flops(cfg, res_latent: int, b: int) -> float:
    if isinstance(cfg, diff_mod.MMDiTConfig):
        n_img = (res_latent // cfg.patch) ** 2
        n_tok = n_img + cfg.n_ctx_tokens
        d, f = cfg.d_model, cfg.d_model * cfg.mlp_ratio
        dbl = 2 * (2 * n_tok * (4 * d * d + 2 * d * f) / 2  # two streams share attn
                   ) + 4 * n_tok * n_tok * d
        # double block: per-stream qkv+o and mlp on its own tokens
        dbl = 2 * (n_img + cfg.n_ctx_tokens) * (4 * d * d + 2 * d * f) \
            + 4 * n_tok * n_tok * d
        sgl = 2 * n_tok * (4 * d * d + 2 * d * f) + 4 * n_tok * n_tok * d
        return b * (cfg.n_double_blocks * dbl + cfg.n_single_blocks * sgl)
    # UNet analytic: res blocks (convs) + spatial transformers
    # (self-attention is quadratic in tokens and dominates at high res).
    def xformer_flops(tokens: int, d: int, depth: int) -> float:
        per_tok = (4 * d * d          # self qkv + out
                   + 2 * d * d + 2 * d * cfg.ctx_dim  # cross q/out + kv
                   + 12 * d * d)      # GEGLU ff (d->8d, 4d->d)
        quad = 4 * tokens * tokens * d + 4 * tokens * cfg.n_ctx_tokens * d
        return depth * (2 * tokens * per_tok + quad)

    total = 0.0
    res_c = res_latent
    chans = [cfg.ch * m for m in cfg.ch_mult]
    c_prev = cfg.ch
    for li, c in enumerate(chans):
        for _ in range(cfg.n_res_blocks):
            total += 2 * res_c ** 2 * 9 * (c_prev * c + c * c)
            if li > 0:
                total += xformer_flops(res_c ** 2, c, cfg.transformer_depth[li])
            c_prev = c
        if li < len(chans) - 1:
            total += 2 * (res_c // 2) ** 2 * 9 * c * c
            res_c //= 2
    # mid: 2 res blocks + depth-10 transformer at the bottleneck res
    total += 2 * 2 * res_c ** 2 * 9 * c_prev * c_prev
    total += xformer_flops(res_c ** 2, c_prev, cfg.transformer_depth[-1])
    # up path mirrors down with one extra res block per level and skip
    # concat inputs (~2x the down-path conv cost)
    return b * total * 2.4


def _diffusion_cell(arch: cfgbase.ArchSpec, shape: cfgbase.ShapeSpec) -> Cell:
    cfg = arch.config
    vae = 8
    lat = shape.img_res // vae
    b = shape.batch
    if cfg.latent_res != lat:
        cfg = dataclasses.replace(cfg, latent_res=lat)
    is_flux = isinstance(cfg, diff_mod.MMDiTConfig)
    init = diff_mod.mmdit_init if is_flux else diff_mod.unet_init
    params_abs = _eval_params(lambda: init(jax.random.PRNGKey(0), cfg))
    param_specs = shd.spec_tree(params_abs, shd.diffusion_param_rules())
    fwd = _diffusion_flops(cfg, lat, b)
    ch = cfg.latent_ch

    common = {"latents": Sds((b, lat, lat, ch), cfg.compute_dtype),
              "ctx": Sds((b, cfg.n_ctx_tokens,
                          cfg.d_ctx if is_flux else cfg.ctx_dim),
                         cfg.compute_dtype)}
    if is_flux:
        extras = {"pooled": Sds((b, cfg.d_pooled), cfg.compute_dtype),
                  "guidance": Sds((b,), jnp.float32)}
    else:
        extras = {"add_emb": Sds((b, cfg.d_add), cfg.compute_dtype)}

    batch_spec = shd.diffusion_batch_specs(cfg)
    if shape.kind == "train":
        step = steps_mod.diffusion_train_step(cfg, _OPT)
        batch_abs = {**common, **extras, "seed": Sds((), jnp.int32)}
        spec = {k: batch_spec[k] for k in common | extras} | {"seed": P()}
        args = (_state_abstract(params_abs), batch_abs)
        specs = (_state_specs(param_specs), spec)
        flops = 3.0 * fwd
        comment = "3x analytic forward (fwd+bwd); one denoise step"
    else:
        step = steps_mod.diffusion_denoise_step(cfg)
        t_extra = ({"t": Sds((b,), jnp.float32), "dt": Sds((b,), jnp.float32)}
                   if is_flux else
                   {"t": Sds((b,), jnp.float32), "t_prev": Sds((b,), jnp.float32)})
        batch_abs = {**common, **extras, **t_extra}
        spec = {k: batch_spec[k] for k in batch_abs}
        args = (params_abs, batch_abs)
        specs = (param_specs, spec)
        flops = fwd
        comment = (f"ONE denoise step; full sample = {shape.steps} steps "
                   f"(sampler loop in benchmarks)")
    return Cell(arch.arch_id, shape.name, shape.kind, step, args, specs,
                flops, comment)


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str) -> Cell:
    arch = cfgbase.get_arch(arch_id)
    if shape_name in arch.skip:
        raise ValueError(f"{arch_id}/{shape_name}: {arch.skip[shape_name]}")
    shape = arch.shapes[shape_name]
    if arch.family == "lm":
        return _lm_cell(arch, shape)
    if arch.family == "vision":
        return _vision_cell(arch, shape)
    if arch.family == "diffusion":
        return _diffusion_cell(arch, shape)
    raise ValueError(arch.family)


def input_specs(arch_id: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every input of the cell's step."""
    return build_cell(arch_id, shape_name).abstract_args


def iter_cells(include_skipped: bool = True):
    """Yield (arch_id, shape_name, skip_reason|None) for all 40 cells."""
    for arch_id in cfgbase.list_archs():
        arch = cfgbase.get_arch(arch_id)
        for shape_name in arch.shapes:
            yield arch_id, shape_name, arch.skip.get(shape_name)

"""Replay driver: ``python -m repro.launch.replay <cmd> [...]``.

The CLI surface of the deterministic replay harness
(``repro.serving.replay``) over the structured telemetry event log
(``repro.serving.telemetry``):

  * ``record``  — serve the standard seeded oracle corpus with
    telemetry, writing a self-contained JSONL log (leads with the
    rebuildable ``corpus_spec``, ends with the ``run_stats``
    fingerprint)::

        PYTHONPATH=src python -m repro.launch.replay record \\
            --out corpus.jsonl --streams 8 --policy async

        PYTHONPATH=src python -m repro.launch.replay record \\
            --out open.jsonl --streams 8 --open-loop --fps 1.0 \\
            --slo 2.0 --admission slo

  * ``check``   — re-drive a log under its recorded policy and demand
    BIT-IDENTICAL ``ServeStats`` and per-frame detection digests;
    exits 1 with the drift list otherwise (the replay-determinism CI
    lane)::

        PYTHONPATH=src python -m repro.launch.replay check corpus.jsonl

  * ``diff``    — re-drive a log under a DIFFERENT schedule/admission
    policy and print the apples-to-apples metric table (same seeded
    content, same arrival trace — only the policy moved)::

        PYTHONPATH=src python -m repro.launch.replay diff corpus.jsonl \\
            --policy deadline

  * ``report``  — the offline timeline summary from a log alone
    (``format_timeline_report``): per-group utilisation, queueing-
    delay histogram, admission-verdict breakdown::

        PYTHONPATH=src python -m repro.launch.replay report open.jsonl
"""

from __future__ import annotations

import argparse
import sys

from repro.serving.replay import (CorpusSpec, format_policy_diff, record,
                                  replay)
from repro.serving.telemetry import (JsonlSink, format_timeline_report,
                                     read_events)


def _add_record(sub) -> None:
    ap = sub.add_parser(
        "record", help="serve the seeded oracle corpus, writing the log")
    ap.add_argument("--out", required=True, metavar="PATH",
                    help="JSONL event-log path to write")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--frames", type=int, default=8,
                    help="closed-loop tick count (open-loop: video floor)")
    ap.add_argument("--budget", type=float, default=1.8)
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual replica-group device slots "
                         "(0 = single-device pod)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--policy", choices=("sync", "deadline", "async"),
                    default="sync")
    ap.add_argument("--pod-allocate", action="store_true")
    ap.add_argument("--open-loop", action="store_true",
                    help="record arrival-clocked open-loop traffic "
                         "instead of the closed-loop frame barrier")
    ap.add_argument("--fps", type=float, default=0.5)
    ap.add_argument("--jitter", type=float, default=0.2)
    ap.add_argument("--horizon", type=float, default=20.0)
    ap.add_argument("--slo", type=float, default=None)
    ap.add_argument("--admission", choices=("admit-all", "slo"),
                    default=None)
    ap.add_argument("--pods", type=int, default=0,
                    help="fleet size (requires --open-loop; 0 = the "
                         "single-pod server)")
    ap.add_argument("--routing", choices=("least-loaded", "affinity"),
                    default="least-loaded",
                    help="fleet stream-routing policy (with --pods)")
    ap.add_argument("--tasks", choices=("detection", "action", "mixed"),
                    default="detection",
                    help="analytics task mix for the corpus "
                         "(repro.serving.tasks registry; mixed "
                         "alternates detection / action recognition)")


def _cmd_record(args) -> int:
    if args.pods and not args.open_loop:
        print("--pods requires --open-loop (the fleet tier serves "
              "arrival-clocked traffic)", file=sys.stderr)
        return 2
    tasks = ()
    if args.tasks != "detection":
        from repro.serving.tasks import stream_tasks_for

        tasks = tuple(stream_tasks_for(args.tasks, args.streams))
    spec = CorpusSpec(
        mode="open" if args.open_loop else "closed",
        n_streams=args.streams, frames=args.frames, budget_s=args.budget,
        devices=args.devices, max_batch=args.max_batch, policy=args.policy,
        pod_allocate=args.pod_allocate, admission=args.admission,
        slo_s=args.slo, fps=args.fps, jitter=args.jitter,
        horizon_s=args.horizon, pods=args.pods, routing=args.routing,
        tasks=tasks)
    stats = record(spec, JsonlSink(args.out))
    fleet = f", {spec.pods} pods ({spec.routing} routing)" if spec.pods \
        else ""
    print(f"recorded {stats.frames} frames / {stats.dispatches} dispatches "
          f"[{spec.policy} policy, {spec.mode}-loop, {spec.n_streams} "
          f"streams{fleet}] -> {args.out}")
    return 0


def _cmd_check(args) -> int:
    result = replay(args.log)
    for line in format_policy_diff(result):
        print(line)
    return 0 if result.identical else 1


def _cmd_diff(args) -> int:
    from repro.serving.runtime import make_policy

    if args.policy is None and args.admission is None:
        print("diff needs --policy and/or --admission (otherwise use "
              "'check')", file=sys.stderr)
        return 2
    policy = admission = None
    if args.policy is not None:
        policy = make_policy(args.policy, admission=args.admission)
    else:
        admission = args.admission
    result = replay(args.log, policy=policy, admission=admission)
    for line in format_policy_diff(result):
        print(line)
    return 0


def _cmd_report(args) -> int:
    for line in format_timeline_report(read_events(args.log)):
        print(line)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.replay",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    _add_record(sub)
    for name, help_ in (("check", "replay under the recorded policy; "
                                  "exit 1 on any bit-level drift"),
                        ("diff", "replay under a different policy; print "
                                 "the side-by-side metric table"),
                        ("report", "offline timeline summary from the "
                                   "log alone")):
        p = sub.add_parser(name, help=help_)
        p.add_argument("log", help="JSONL event log path")
        if name == "diff":
            p.add_argument("--policy",
                           choices=("sync", "deadline", "async"),
                           default=None)
            p.add_argument("--admission", choices=("admit-all", "slo"),
                           default=None)
    args = ap.parse_args(argv)
    return {"record": _cmd_record, "check": _cmd_check,
            "diff": _cmd_diff, "report": _cmd_report}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())

"""mixtral-8x22b: MoE 8 experts top-2 with sliding-window attention
[arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 (per expert) vocab=32768.
SWA (window 4096 per the Mistral lineage) -> long_500k RUNS: decode
with a window-bounded ring KV cache.
"""

import jax.numpy as jnp

from repro.configs.base import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP: dict = {}  # SWA makes long_500k feasible

WINDOW = 4096


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x22b",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=0,
        vocab_size=32768,
        moe=True,
        n_experts=8,
        moe_top_k=2,
        d_ff_expert=16384,
        window=WINDOW,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        attention_impl="chunked",
        attn_chunk=1024,
        ce_chunk=256,
        remat=True,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=0,
        vocab_size=128,
        moe=True,
        n_experts=4,
        moe_top_k=2,
        d_ff_expert=96,
        window=16,
        attention_impl="chunked",
        attn_chunk=16,
        ce_chunk=16,
        remat=False,
    )

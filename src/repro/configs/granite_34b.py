"""granite-34b: dense llama-arch code model [arXiv:2405.04324; hf].

88L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
Pure full attention -> long_500k is skipped per instructions.
"""

import jax.numpy as jnp

from repro.configs.base import FULL_ATTENTION_SKIP, LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = FULL_ATTENTION_SKIP


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-34b",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_head=128,
        d_ff=24576,
        vocab_size=49152,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        attention_impl="chunked",
        attn_chunk=1024,
        ce_chunk=256,
        remat=True,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=256,
        vocab_size=128,
        attention_impl="chunked",
        attn_chunk=32,
        ce_chunk=16,
        remat=False,
    )

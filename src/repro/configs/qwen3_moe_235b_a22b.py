"""qwen3-moe-235b-a22b: MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936.
Pure full attention -> long_500k is skipped per instructions.
"""

import jax.numpy as jnp

from repro.configs.base import FULL_ATTENTION_SKIP, LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = FULL_ATTENTION_SKIP


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_head=128,
        d_ff=0,
        vocab_size=151936,
        moe=True,
        n_experts=128,
        moe_top_k=8,
        d_ff_expert=1536,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        attention_impl="chunked",
        attn_chunk=1024,
        ce_chunk=256,
        remat=True,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=4,
        d_head=8,
        d_ff=0,
        vocab_size=256,
        moe=True,
        n_experts=8,
        moe_top_k=2,
        d_ff_expert=48,
        attention_impl="chunked",
        attn_chunk=16,
        ce_chunk=16,
        remat=False,
    )

"""Config registry: every assigned architecture is a selectable config.

Each ``repro/configs/<arch_id>.py`` module exports:
  * ``FAMILY``       — "lm" | "diffusion" | "vision"
  * ``full_config()``  — the exact assigned configuration
  * ``smoke_config()`` — a reduced same-family config for CPU tests
  * ``SHAPES``       — the arch's assigned input-shape set
  * ``SKIP``         — dict shape_name -> reason, for cells that are
    skipped by instruction (e.g. long_500k on pure full-attention LMs)

``get_arch(arch_id)`` returns an ``ArchSpec`` bundling these.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

ARCH_IDS = (
    "granite_34b",
    "smollm_135m",
    "mixtral_8x22b",
    "qwen3_moe_235b_a22b",
    "flux_dev",
    "unet_sdxl",
    "convnext_b",
    "resnet_152",
    "resnet_50",
    "vit_b16",
)

# canonical hyphenated ids (CLI spelling) -> module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | generate | serve
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # vision / diffusion fields
    img_res: int = 0
    batch: int = 0
    steps: int = 0


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str
    config: Any
    smoke: Any
    shapes: dict[str, ShapeSpec]
    skip: dict[str, str]


def get_arch(arch_id: str) -> ArchSpec:
    mod_name = ALIASES.get(arch_id, arch_id)
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return ArchSpec(
        arch_id=mod_name,
        family=mod.FAMILY,
        config=mod.full_config(),
        smoke=mod.smoke_config(),
        shapes=mod.SHAPES,
        skip=getattr(mod, "SKIP", {}),
    )


def list_archs() -> list[str]:
    return list(ARCH_IDS)


# shared per-family shape sets -------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

DIFFUSION_SHAPES = {
    "train_256": ShapeSpec("train_256", "train", img_res=256, batch=256, steps=1000),
    "gen_1024": ShapeSpec("gen_1024", "generate", img_res=1024, batch=4, steps=50),
    "gen_fast": ShapeSpec("gen_fast", "generate", img_res=512, batch=16, steps=4),
    "train_1024": ShapeSpec("train_1024", "train", img_res=1024, batch=32, steps=1000),
}

VISION_SHAPES = {
    "cls_224": ShapeSpec("cls_224", "train", img_res=224, batch=256),
    "cls_384": ShapeSpec("cls_384", "train", img_res=384, batch=64),
    "serve_b1": ShapeSpec("serve_b1", "serve", img_res=224, batch=1),
    "serve_b128": ShapeSpec("serve_b128", "serve", img_res=224, batch=128),
}

FULL_ATTENTION_SKIP = {
    "long_500k": "SKIP(full-attention): 524k-token decode needs "
                 "sub-quadratic attention; this arch has no sliding window "
                 "(see DESIGN.md section 4)."
}

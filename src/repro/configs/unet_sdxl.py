"""unet-sdxl: SDXL UNet backbone [arXiv:2307.01952; paper].

img_res=1024 latent_res=128 ch=320 ch_mult=(1,2,4) n_res_blocks=2
transformer_depth=(1,2,10) ctx_dim=2048.  Level 0 is attention-free
(DownBlock2D semantics, matching the reference SDXL config); text
conditioning is a precomputed-embedding stub.
"""

import jax.numpy as jnp

from repro.configs.base import DIFFUSION_SHAPES
from repro.models.diffusion import UNetConfig

FAMILY = "diffusion"
SHAPES = DIFFUSION_SHAPES
SKIP: dict = {}

VAE_FACTOR = 8


def full_config() -> UNetConfig:
    return UNetConfig(
        name="unet-sdxl",
        latent_res=128,
        latent_ch=4,
        ch=320,
        ch_mult=(1, 2, 4),
        n_res_blocks=2,
        transformer_depth=(1, 2, 10),
        ctx_dim=2048,
        n_ctx_tokens=77,
        d_add=2816,
        head_dim=64,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        remat=True,
    )


def smoke_config() -> UNetConfig:
    return UNetConfig(
        name="sdxl-smoke",
        latent_res=16,
        latent_ch=4,
        ch=32,
        ch_mult=(1, 2, 4),
        n_res_blocks=2,
        transformer_depth=(1, 1, 2),
        ctx_dim=24,
        n_ctx_tokens=7,
        d_add=20,
        head_dim=16,
        remat=False,
    )

"""vit-b16 [arXiv:2010.11929; paper].

img_res=224 patch=16 n_layers=12 d_model=768 n_heads=12 d_ff=3072.
cls_384 keeps patch 16 (576 + 1 tokens); position embeddings sized for
the largest grid and sliced per resolution would be the deployment
choice — here each shape builds its own table (dry-run lowers per
shape anyway).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import VISION_SHAPES
from repro.models.vision import ViTConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES
SKIP: dict = {}


def full_config() -> ViTConfig:
    return ViTConfig(
        name="vit-b16",
        img_res=224,
        patch=16,
        n_layers=12,
        d_model=768,
        n_heads=12,
        d_ff=3072,
        n_classes=1000,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        remat=True,
    )


def config_for_res(res: int) -> ViTConfig:
    return dataclasses.replace(full_config(), img_res=res)


def smoke_config() -> ViTConfig:
    return ViTConfig(
        name="vit-smoke",
        img_res=64,
        patch=16,
        n_layers=2,
        d_model=32,
        n_heads=4,
        d_ff=64,
        n_classes=10,
        remat=False,
    )

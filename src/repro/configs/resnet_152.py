"""resnet-152 [arXiv:1512.03385; paper].

img_res=224 depths=(3,8,36,3) width=64 bottleneck blocks.
"""

import jax.numpy as jnp

from repro.configs.base import VISION_SHAPES
from repro.models.vision import ResNetConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES
SKIP: dict = {}


def full_config() -> ResNetConfig:
    return ResNetConfig(
        name="resnet-152",
        img_res=224,
        depths=(3, 8, 36, 3),
        width=64,
        n_classes=1000,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        remat=True,
    )


def smoke_config() -> ResNetConfig:
    return ResNetConfig(
        name="resnet152-smoke",
        img_res=64,
        depths=(2, 2, 3, 2),
        width=16,
        n_classes=10,
        remat=False,
    )

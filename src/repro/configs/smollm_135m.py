"""smollm-135m: dense llama-arch small model
[hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
Pure full attention -> long_500k is skipped per instructions.
"""

import jax.numpy as jnp

from repro.configs.base import FULL_ATTENTION_SKIP, LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = FULL_ATTENTION_SKIP


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="smollm-135m",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_head=64,
        d_ff=1536,
        vocab_size=49152,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        attention_impl="chunked",
        attn_chunk=1024,
        ce_chunk=512,
        remat=True,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="smollm-smoke",
        n_layers=3,
        d_model=48,
        n_heads=3,
        n_kv_heads=3,
        d_head=16,
        d_ff=96,
        vocab_size=128,
        attention_impl="chunked",
        attn_chunk=32,
        ce_chunk=16,
        remat=False,
    )

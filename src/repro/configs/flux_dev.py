"""flux-dev: MMDiT rectified-flow image model [BFL tech report; unverified].

img_res=1024 latent_res=128 n_double_blocks=19 n_single_blocks=38
d_model=3072 n_heads=24 (~12B params).  Latents are 8x-downsampled VAE
codes with 16 channels; text conditioning arrives as precomputed T5/CLIP
embeddings (frontend stub per assignment).
"""

import jax.numpy as jnp

from repro.configs.base import DIFFUSION_SHAPES
from repro.models.diffusion import MMDiTConfig

FAMILY = "diffusion"
SHAPES = DIFFUSION_SHAPES
SKIP: dict = {}

VAE_FACTOR = 8


def full_config() -> MMDiTConfig:
    return MMDiTConfig(
        name="flux-dev",
        latent_res=128,
        latent_ch=16,
        patch=2,
        d_model=3072,
        n_heads=24,
        n_double_blocks=19,
        n_single_blocks=38,
        d_ctx=4096,
        n_ctx_tokens=512,
        d_pooled=768,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        remat=True,
    )


def smoke_config() -> MMDiTConfig:
    return MMDiTConfig(
        name="flux-smoke",
        latent_res=8,
        latent_ch=4,
        patch=2,
        d_model=64,
        n_heads=4,
        n_double_blocks=2,
        n_single_blocks=3,
        d_ctx=32,
        n_ctx_tokens=8,
        d_pooled=16,
        remat=False,
    )

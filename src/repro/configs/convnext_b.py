"""convnext-b [arXiv:2201.03545; paper].

img_res=224 depths=(3,3,27,3) dims=(128,256,512,1024).
"""

import jax.numpy as jnp

from repro.configs.base import VISION_SHAPES
from repro.models.vision import ConvNeXtConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES
SKIP: dict = {}


def full_config() -> ConvNeXtConfig:
    return ConvNeXtConfig(
        name="convnext-b",
        img_res=224,
        depths=(3, 3, 27, 3),
        dims=(128, 256, 512, 1024),
        n_classes=1000,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        remat=True,
    )


def smoke_config() -> ConvNeXtConfig:
    return ConvNeXtConfig(
        name="convnext-smoke",
        img_res=64,
        depths=(2, 2, 3, 2),
        dims=(16, 32, 64, 128),
        n_classes=10,
        remat=False,
    )

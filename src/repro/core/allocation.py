"""Algorithm 2 — latency-constrained model allocation.

Solves the pipelined multiple-choice knapsack of paper section IV-C:
assign exactly one model (or "skip", model index 0) to each predicted
SRoI so that the summed weighted accuracy is maximised while the
*pipelined* analysis latency stays within the budget T.

The pipelined latency recurrence (paper Fig. 6): if the previous SRoIs
finish preprocessing at t^P and finish inference at t, choosing model i
for the next SRoI gives

    cur_t  = max(t^P + d_{i,j},  t + d^I_{i,j})      (d = d^P + d^I)
    cur_tP = t^P + d^P_{i,j}

The DP keeps, per prefix length j, the set of *non-dominated* feasible
plans (v, t^P, t, m_list); a plan dominates another iff v >= v',
t^P <= t'^P and t <= t' (eq. 4), so dominated plans can never become
part of an optimum and are pruned.

``allocate`` is exact for a fixed SRoI processing order; the paper
approximates the global optimum by running it on one (random) order —
our serving loop does the same, and ``tests/test_allocation.py``
verifies exactness against brute force on small instances.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

import numpy as np

# (i, j, d_pre_ij, d_inf_ij) -> (d_pre_ij, d_inf_ij): reprices model i
# on SRoI j before the DP sees it.  The pod-level allocator
# (repro.serving.pod_allocation) injects tick-coupled batched costs
# through this; with no hook the solver is byte-for-byte the legacy
# per-stream knapsack.
CostHook = Callable[[int, int, float, float], tuple[float, float]]


def apply_cost_hook(
    hook: CostHook, d_pre: np.ndarray, d_inf: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Materialise the hooked (d_pre, d_inf) matrices.

    Shared by :func:`allocate` / :func:`allocate_bruteforce` and by
    callers that need the same repriced matrices outside the DP (e.g.
    to re-price an incumbent plan via :func:`plan_latency`), so the
    hook semantics cannot drift between them.
    """
    m, r = d_pre.shape
    out_pre = np.empty_like(d_pre, dtype=np.float64)
    out_inf = np.empty_like(d_inf, dtype=np.float64)
    for i in range(m):
        for j in range(r):
            out_pre[i, j], out_inf[i, j] = hook(
                i, j, float(d_pre[i, j]), float(d_inf[i, j]))
    return out_pre, out_inf


@dataclasses.dataclass(frozen=True)
class Plan:
    """One feasible execution plan (the DP quaternion)."""

    value: float  # cumulative weighted accuracy v
    t_pre: float  # preprocessing completion time t^P
    t_done: float  # processing completion time t
    models: tuple[int, ...]  # allocated model index per SRoI (0 = skip)


def _prune_dominated(plans: list[Plan]) -> list[Plan]:
    """Remove plans dominated per eq. (4).

    Sort by (-value, t_pre, t_done); sweep keeping the Pareto frontier
    over (t_pre, t_done) among plans with >= value.  O(n log n + n*k)
    with k = frontier size, fine for the handfuls of SRoIs per frame.
    """
    plans.sort(key=lambda p: (-p.value, p.t_pre, p.t_done))
    kept: list[Plan] = []
    for p in plans:
        dominated = False
        for q in kept:
            if q.value >= p.value and q.t_pre <= p.t_pre and q.t_done <= p.t_done:
                dominated = True
                break
        if not dominated:
            kept.append(p)
    return kept


def allocate(
    acc: np.ndarray,
    d_pre: np.ndarray,
    d_inf: np.ndarray,
    budget: float,
    *,
    cost_hook: CostHook | None = None,
) -> Plan | None:
    """Algorithm 2.

    ``acc``:   (M, R) weighted accuracies A_{i,j}; row 0 must be "skip".
    ``d_pre``: (M, R) preprocessing delays d^P_{i,j} (skip row = 0).
    ``d_inf``: (M, R) inference delays d^I_{i,j} (skip row = 0).
    ``budget``: analysis latency budget T (seconds).
    ``cost_hook``: optional :data:`CostHook` repricing each (model,
    SRoI) delay pair before the DP runs (the pod-level coupling entry
    point); with ``None`` the input matrices are used untouched, so
    legacy plans stay bit-identical.

    Returns the best feasible plan for SRoIs processed in column order,
    or ``None`` when even skipping everything violates the budget
    (cannot happen with zero-cost skip, but kept for defensiveness).
    """
    m, r = acc.shape
    if r == 0:
        return Plan(0.0, 0.0, 0.0, ())
    if cost_hook is not None:
        d_pre, d_inf = apply_cost_hook(cost_hook, d_pre, d_inf)
    d_tot = d_pre + d_inf

    frontier: list[Plan] = []
    for i in range(m):
        if d_tot[i, 0] <= budget:
            frontier.append(Plan(float(acc[i, 0]), float(d_pre[i, 0]), float(d_tot[i, 0]), (i,)))
    frontier = _prune_dominated(frontier)

    for j in range(1, r):
        nxt: list[Plan] = []
        for p in frontier:
            for i in range(m):
                cur_t = max(p.t_pre + d_tot[i, j], p.t_done + d_inf[i, j])
                if cur_t <= budget:
                    nxt.append(
                        Plan(
                            p.value + float(acc[i, j]),
                            p.t_pre + float(d_pre[i, j]),
                            cur_t,
                            p.models + (i,),
                        )
                    )
        frontier = _prune_dominated(nxt)
        if not frontier:
            return None

    return max(frontier, key=lambda p: p.value)


def allocate_bruteforce(
    acc: np.ndarray,
    d_pre: np.ndarray,
    d_inf: np.ndarray,
    budget: float,
    *,
    cost_hook: CostHook | None = None,
) -> Plan | None:
    """Exhaustive oracle (M^R enumeration) for tests; same semantics."""
    m, r = acc.shape
    if cost_hook is not None:
        d_pre, d_inf = apply_cost_hook(cost_hook, d_pre, d_inf)
    d_tot = d_pre + d_inf
    best: Plan | None = None
    for models in itertools.product(range(m), repeat=r):
        t_pre = 0.0
        t_done = 0.0
        value = 0.0
        feasible = True
        for j, i in enumerate(models):
            t_done = max(t_pre + d_tot[i, j], t_done + d_inf[i, j])
            t_pre += d_pre[i, j]
            value += float(acc[i, j])
            if t_done > budget:
                feasible = False
                break
        if feasible and (best is None or value > best.value):
            best = Plan(value, t_pre, t_done, tuple(models))
    return best


def plan_latency(
    models: tuple[int, ...], d_pre: np.ndarray, d_inf: np.ndarray
) -> float:
    """Pipelined analysis latency L(X) of a fixed plan (paper eq. 3)."""
    t_pre = 0.0
    t_done = 0.0
    for j, i in enumerate(models):
        t_done = max(t_pre + d_pre[i, j] + d_inf[i, j], t_done + d_inf[i, j])
        t_pre += d_pre[i, j]
    return t_done

"""Spherical object discovery (paper section IV-A, last paragraph).

Relying solely on historical detections can cascade: a tight budget
forces cheap models -> fewer detections -> fewer predicted SRoIs ->
even fewer detections.  The discovery mechanism breaks the circle by
opportunistically spending *underutilised* budget on a full-ERP
inference at the server; its detections are converted to SphBBs and
appended to the history used for the next frame's SRoI prediction.

Trigger: the number of predicted SRoIs has been below ``min_srois``
for ``patience`` consecutive frames AND the current plan leaves at
least ``min_slack`` of the budget unused (or the frame has no SRoIs at
all — e.g. the very first frame).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class DiscoveryState:
    min_srois: int = 2
    patience: int = 3
    min_slack: float = 0.15  # fraction of budget that must be free
    low_fraction: float = 0.6  # "consistently low" = below this x peak
    low_streak: int = 0
    peak_srois: int = 0
    cooldown: int = 0  # frames to wait after a discovery pass
    cooldown_frames: int = 5

    def observe(self, n_srois: int) -> None:
        self.peak_srois = max(self.peak_srois, n_srois)
        # "consistently low" is relative to what the stream usually
        # yields: an absolute floor plus a fraction of the peak (moving
        # cameras lose regions permanently without re-exploration).
        threshold = max(self.min_srois, self.low_fraction * self.peak_srois)
        if n_srois < threshold:
            self.low_streak += 1
        else:
            self.low_streak = 0
        if self.cooldown > 0:
            self.cooldown -= 1

    def should_discover(self, budget: float, plan_latency: float) -> bool:
        if self.cooldown > 0:
            return False
        slack_ok = (budget - plan_latency) >= self.min_slack * budget
        trigger = self.low_streak >= self.patience or plan_latency == 0.0
        if trigger and slack_ok:
            self.cooldown = self.cooldown_frames
            self.low_streak = 0
            return True
        return False

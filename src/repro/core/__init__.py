"""OmniSense core: spherical geometry, SRoI prediction, accuracy
estimation, latency-constrained allocation, and the per-frame loop."""

from repro.core import accuracy, allocation, discovery, projection, sphere, sroi
from repro.core.omnisense import FrameResult, OmniSenseLoop

__all__ = [
    "accuracy",
    "allocation",
    "discovery",
    "projection",
    "sphere",
    "sroi",
    "FrameResult",
    "OmniSenseLoop",
]

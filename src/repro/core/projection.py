"""Sphere <-> plane projections: gnomonic (perspective), ERP, Cubemap.

The OmniSense inference scheduler extracts one perspective image (PI)
per SRoI from the input ERP frame via gnomonic projection, at exactly
the input size of the allocated model.  This module provides:

  * :func:`gnomonic_coords` — the (u, v) ERP source coordinates for
    every output pixel of a PI (the "sampling map").
  * :func:`sample_erp_bilinear` — pure-jnp bilinear resampler (oracle
    for the Pallas kernel in ``repro.kernels.gnomonic``).
  * :func:`project_sroi` — end-to-end SRoI -> PI extraction with a
    ``use_kernel`` switch between the jnp path and the Pallas path.
  * :func:`cubemap_faces` — the six 90x90-degree cube-face PIs used by
    the CubeMap baseline of the paper.
  * :func:`erp_resize_coords` — plain ERP downsampling map (the "ERP"
    baseline feeds a resized whole frame to the detector).

Conventions: ERP frames are channel-last ``(H, W, C)`` float arrays;
angles are radians; PI pixel (0, 0) is the top-left corner.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import sphere

Array = jax.Array


# --------------------------------------------------------------------------
# Sampling maps
# --------------------------------------------------------------------------


def gnomonic_coords(
    center_theta: Array,
    center_phi: Array,
    fov: tuple[float, float],
    out_size: tuple[int, int],
    erp_size: tuple[int, int],
) -> tuple[Array, Array]:
    """ERP source coordinates for a gnomonic PI.

    Returns ``(u, v)`` float arrays of shape ``out_size`` giving, for
    each output pixel, the (sub-pixel) ERP location to sample.

    ``fov``: (horizontal, vertical) in radians; ``out_size``: (H, W) of
    the PI; ``erp_size``: (H, W) of the source ERP frame.
    """
    out_h, out_w = out_size
    erp_h, erp_w = erp_size
    half_x = jnp.tan(fov[0] / 2.0)
    half_y = jnp.tan(fov[1] / 2.0)

    # pixel centres
    xs = (jnp.arange(out_w) + 0.5) / out_w  # [0, 1)
    ys = (jnp.arange(out_h) + 0.5) / out_h
    x = (xs - 0.5) * 2.0 * half_x  # tangent-plane coords
    y = (0.5 - ys) * 2.0 * half_y
    xg, yg = jnp.meshgrid(x, y)  # (H, W)

    d = jnp.stack([jnp.ones_like(xg), xg, yg], axis=-1)
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    r = sphere.rotation_from_origin(center_theta, center_phi)
    world = jnp.einsum("ij,hwj->hwi", r, d)
    theta, phi = sphere.cart_to_sph(world)
    u, v = sphere.sph_to_erp(theta, phi, erp_w, erp_h)
    # u wraps horizontally; v is clamped at the poles by the sampler
    return u, v


def erp_resize_coords(
    out_size: tuple[int, int], erp_size: tuple[int, int]
) -> tuple[Array, Array]:
    """Plain bilinear-resize sampling map (ERP baseline)."""
    out_h, out_w = out_size
    erp_h, erp_w = erp_size
    u = (jnp.arange(out_w) + 0.5) * (erp_w / out_w) - 0.5
    v = (jnp.arange(out_h) + 0.5) * (erp_h / out_h) - 0.5
    ug, vg = jnp.meshgrid(u, v)
    return ug, vg


CUBE_FACE_CENTERS = (
    # (name, theta, phi) of the six cube-face centres
    ("front", 0.0, 0.0),
    ("right", jnp.pi / 2, 0.0),
    ("back", jnp.pi, 0.0),
    ("left", -jnp.pi / 2, 0.0),
    ("top", 0.0, jnp.pi / 2),
    ("bottom", 0.0, -jnp.pi / 2),
)


def cubemap_faces(
    erp: Array, face_size: int
) -> tuple[Array, tuple[tuple[str, float, float], ...]]:
    """Project an ERP frame onto the six 90x90-degree cube faces.

    Returns ``(faces, centers)`` where ``faces`` is
    ``(6, face_size, face_size, C)``.  Used by the CubeMap baseline.
    """
    fov = (jnp.pi / 2, jnp.pi / 2)
    faces = []
    for _, th, ph in CUBE_FACE_CENTERS:
        u, v = gnomonic_coords(
            jnp.asarray(th), jnp.asarray(ph), fov, (face_size, face_size), erp.shape[:2]
        )
        faces.append(sample_erp_bilinear(erp, u, v))
    return jnp.stack(faces), CUBE_FACE_CENTERS


# --------------------------------------------------------------------------
# Bilinear sampling (jnp oracle; the Pallas kernel mirrors this exactly)
# --------------------------------------------------------------------------


def sample_erp_bilinear(erp: Array, u: Array, v: Array) -> Array:
    """Sample an ERP frame at float coords with horizontal wrap.

    ``erp``: (H, W, C); ``u``/``v``: (h, w) float source coords in ERP
    pixel space (pixel-centre convention: integer coords hit texel
    centres).  Horizontal coordinate wraps (the ERP seam is periodic);
    vertical clamps at the poles.
    """
    erp_h, erp_w = erp.shape[0], erp.shape[1]
    u0 = jnp.floor(u)
    v0 = jnp.floor(v)
    fu = u - u0
    fv = v - v0

    u0i = jnp.mod(u0.astype(jnp.int32), erp_w)
    u1i = jnp.mod(u0i + 1, erp_w)
    v0i = jnp.clip(v0.astype(jnp.int32), 0, erp_h - 1)
    v1i = jnp.clip(v0i + 1, 0, erp_h - 1)

    p00 = erp[v0i, u0i]
    p01 = erp[v0i, u1i]
    p10 = erp[v1i, u0i]
    p11 = erp[v1i, u1i]

    fu = fu[..., None]
    fv = fv[..., None]
    top = p00 * (1.0 - fu) + p01 * fu
    bot = p10 * (1.0 - fu) + p11 * fu
    return top * (1.0 - fv) + bot * fv


@functools.partial(jax.jit, static_argnames=("fov", "out_size", "use_kernel"))
def project_sroi(
    erp: Array,
    center_theta: Array,
    center_phi: Array,
    fov: tuple[float, float],
    out_size: tuple[int, int],
    use_kernel: bool = False,
) -> Array:
    """Extract the PI of one SRoI from an ERP frame.

    ``use_kernel=True`` dispatches to the Pallas gnomonic resampler
    (``repro.kernels.gnomonic.ops``); otherwise the pure-jnp path runs.
    Both produce identical results (the kernel is tested against this
    path in ``tests/test_kernels_gnomonic.py``).
    """
    u, v = gnomonic_coords(center_theta, center_phi, fov, out_size, erp.shape[:2])
    if use_kernel:
        from repro.kernels.gnomonic import ops as gno_ops

        return gno_ops.gnomonic_sample(erp, u, v)
    return sample_erp_bilinear(erp, u, v)

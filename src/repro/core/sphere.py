"""Spherical geometry primitives for 360-degree video analytics.

Implements the spherical criteria of Zhao et al. (AAAI'20) used by the
OmniSense paper:

  * ``SphBB`` — a spherical bounding box ``(theta, phi, dtheta, dphi)``
    where ``theta`` is the longitude of the box centre in ``[-pi, pi]``,
    ``phi`` the latitude in ``[-pi/2, pi/2]`` and ``dtheta``/``dphi``
    the horizontal/vertical field-of-view occupied by the object,
    *defined in the box's own tangent frame* (i.e. the box is the
    rotation of an equator-centred spherical rectangle).
  * ``sph_area`` — the area of a SphBB on the unit sphere,
    ``2 * dtheta * sin(dphi / 2)`` (rotation invariant; paper footnote 1).
  * ``sph_iou`` — pairwise spherical IoU.  Box A's centre is rotated to
    the equator origin and box B's centre is expressed exactly in that
    rotated frame; the intersection is then evaluated as the
    lat/long-interval overlap of two equator-centred rectangles (the
    fast approximation of the AAAI'20 spherical criteria).
  * ``sph_nms`` — greedy spherical non-maximum suppression (paper
    default threshold 0.6): the single-row (B=1) entry point of
    ``sph_nms_batch``.  ``sph_nms_lax`` keeps the original
    jit-compatible ``lax.fori_loop`` form as an independent oracle, and
    ``sph_nms_host`` the fast NumPy form used by the online serving
    loop.
  * ``sph_nms_batch`` — the batched NMS subsystem used by the pod
    serving loop (design note below).

Batched-NMS design note
-----------------------
At pod scale (``repro.serving.server.PodServer``) hundreds of streams
finish a frame per scheduler tick, and running greedy NMS as one
Python loop per stream makes post-processing scale with the Python
interpreter instead of with the mesh.  ``sph_nms_batch`` therefore
takes *padded* ``(B, N, 4)`` box stacks — one row per stream/frame,
rows padded to a common N with a boolean validity ``mask`` — and:

  1. computes the per-row ``(B, N, N)`` SphIoU matrices in ONE
     dispatch, via the batched Pallas kernel
     (``repro.kernels.sphiou.ops.sphiou_matrix_batch``) on device, or
     the vectorised NumPy path on host;
  2. runs greedy suppression for all rows simultaneously as a
     ``lax.while_loop`` (device) / NumPy loop (host) whose iteration
     count is the *maximum number of survivors over rows*, not N: each
     step keeps every row's best remaining box and suppresses its
     overlaps, which is exactly sequential greedy NMS because the best
     remaining box can never be overlapped by an earlier kept one.

Padded entries carry zero-area FoVs (IoU 0 against everything) and are
masked out of the candidate set, so they are never kept.  The greedy
order is descending score with lowest-index-first tie-breaking in every
implementation, keeping the lax, host and batched paths bit-identical.

All functions are vectorised over leading axes and safe to ``jax.jit``.
Angles are radians everywhere; degrees only at config boundaries.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array

# --------------------------------------------------------------------------
# Coordinate transforms
# --------------------------------------------------------------------------


def sph_to_cart(theta: Array, phi: Array) -> Array:
    """(lon, lat) -> unit vector, shape (..., 3).

    x axis points at (theta=0, phi=0); z is the north pole.
    """
    cp = jnp.cos(phi)
    return jnp.stack([cp * jnp.cos(theta), cp * jnp.sin(theta), jnp.sin(phi)], axis=-1)


def cart_to_sph(v: Array) -> tuple[Array, Array]:
    """Unit vector (..., 3) -> (lon, lat)."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    theta = jnp.arctan2(y, x)
    phi = jnp.arcsin(jnp.clip(z, -1.0, 1.0))
    return theta, phi


def wrap_angle(a: Array) -> Array:
    """Wrap angle(s) to [-pi, pi)."""
    return (a + jnp.pi) % (2.0 * jnp.pi) - jnp.pi


def rotation_to_origin(theta: Array, phi: Array) -> Array:
    """Rotation matrix R (.., 3, 3) with R @ dir(theta, phi) == (1, 0, 0).

    Composition: first undo longitude (rotate about z by -theta), then undo
    latitude (rotate about y by +phi).
    """
    ct, st = jnp.cos(theta), jnp.sin(theta)
    cp, sp = jnp.cos(phi), jnp.sin(phi)
    zero = jnp.zeros_like(ct)
    one = jnp.ones_like(ct)
    # Rz(-theta)
    rz = jnp.stack(
        [
            jnp.stack([ct, st, zero], axis=-1),
            jnp.stack([-st, ct, zero], axis=-1),
            jnp.stack([zero, zero, one], axis=-1),
        ],
        axis=-2,
    )
    # Ry(phi): rotates the +x axis toward +z by -phi... chosen so that
    # Ry @ (cos(phi), 0, sin(phi)) = (1, 0, 0).
    ry = jnp.stack(
        [
            jnp.stack([cp, zero, sp], axis=-1),
            jnp.stack([zero, one, zero], axis=-1),
            jnp.stack([-sp, zero, cp], axis=-1),
        ],
        axis=-2,
    )
    return ry @ rz


def rotation_from_origin(theta: Array, phi: Array) -> Array:
    """Inverse of :func:`rotation_to_origin` (transpose)."""
    r = rotation_to_origin(theta, phi)
    return jnp.swapaxes(r, -1, -2)


# --------------------------------------------------------------------------
# SphBB area / IoU
# --------------------------------------------------------------------------


def sph_area(boxes: Array) -> Array:
    """Area on the unit sphere of SphBBs (..., 4) -> (...).

    ``area = 2 * dtheta * sin(dphi / 2)`` (paper footnote 1).  Rotation
    invariant because the box is defined in its own tangent frame.
    """
    dtheta = boxes[..., 2]
    dphi = boxes[..., 3]
    return 2.0 * dtheta * jnp.sin(dphi / 2.0)


def _interval_overlap(lo1: Array, hi1: Array, lo2: Array, hi2: Array) -> tuple[Array, Array]:
    lo = jnp.maximum(lo1, lo2)
    hi = jnp.minimum(hi1, hi2)
    return lo, hi


def sph_intersection(boxes_a: Array, boxes_b: Array) -> Array:
    """Pairwise intersection area between two broadcastable SphBB arrays.

    ``boxes_a``: (..., 4) and ``boxes_b``: (..., 4), already broadcast
    against each other (callers usually expand dims to form an N x M
    grid).  Box A is rotated to the origin; box B's centre is expressed
    exactly in A's frame; both are then treated as equator-centred
    lat/long rectangles (AAAI'20 fast criteria).
    """
    ta, pa = boxes_a[..., 0], boxes_a[..., 1]
    tb, pb = boxes_b[..., 0], boxes_b[..., 1]
    # exact position of B's centre in A's frame
    r = rotation_to_origin(ta, pa)
    db = sph_to_cart(tb, pb)
    db_in_a = jnp.einsum("...ij,...j->...i", r, db)
    dlon, dlat = cart_to_sph(db_in_a)

    half_ta, half_pa = boxes_a[..., 2] / 2.0, boxes_a[..., 3] / 2.0
    half_tb, half_pb = boxes_b[..., 2] / 2.0, boxes_b[..., 3] / 2.0

    lon_lo, lon_hi = _interval_overlap(-half_ta, half_ta, dlon - half_tb, dlon + half_tb)
    lat_lo, lat_hi = _interval_overlap(-half_pa, half_pa, dlat - half_pb, dlat + half_pb)

    lon_w = jnp.maximum(lon_hi - lon_lo, 0.0)
    # exact area element in latitude: integral of cos(phi) d(phi)
    lat_w = jnp.maximum(jnp.sin(lat_hi) - jnp.sin(lat_lo), 0.0)
    lat_w = jnp.where(lat_hi > lat_lo, lat_w, 0.0)
    return lon_w * lat_w


def sph_iou(boxes_a: Array, boxes_b: Array) -> Array:
    """Pairwise SphIoU of broadcastable SphBB arrays -> (...).

    The single-direction fast approximation is slightly asymmetric for
    large boxes at different latitudes (whichever box is rotated to the
    origin sees less distortion); we symmetrise by averaging the two
    directions, which restores IoU(a, b) == IoU(b, a) exactly.
    """
    inter = 0.5 * (sph_intersection(boxes_a, boxes_b)
                   + sph_intersection(boxes_b, boxes_a))
    union = sph_area(boxes_a) + sph_area(boxes_b) - inter
    return inter / jnp.maximum(union, 1e-12)


def sph_iou_matrix(boxes_a: Array, boxes_b: Array) -> Array:
    """(N, 4) x (M, 4) -> (N, M) SphIoU matrix (pure jnp reference).

    The Pallas kernel in ``repro.kernels.sphiou`` computes the same
    matrix tile-by-tile; this function is its oracle.
    """
    return sph_iou(boxes_a[:, None, :], boxes_b[None, :, :])


# --------------------------------------------------------------------------
# Spherical NMS
# --------------------------------------------------------------------------


def sph_nms(
    boxes: Array,
    scores: Array,
    iou_threshold: float = 0.6,
    max_out: int | None = None,
) -> np.ndarray:
    """Greedy spherical NMS for one frame's boxes -> (N,) keep-mask.

    The single-row entry point of the batched subsystem: dispatches to
    ``sph_nms_batch(boxes[None], ...)`` (ROADMAP fold — the while-loop
    path has soaked, so the B=1 case no longer carries a private
    implementation).  The original jit-compatible ``lax.fori_loop``
    form lives on as :func:`sph_nms_lax`, kept as an INDEPENDENT oracle
    for the equivalence suite; trace-time callers should use it
    directly.
    """
    keep = sph_nms_batch(np.asarray(boxes)[None], np.asarray(scores)[None],
                         None, iou_threshold, max_out=max_out)
    return keep[0]


def sph_nms_lax(
    boxes: Array,
    scores: Array,
    iou_threshold: float = 0.6,
    max_out: int | None = None,
) -> Array:
    """Greedy spherical NMS, jit-compatible (``lax.fori_loop``).

    Returns a boolean keep-mask of shape (N,).  Suppression follows the
    paper's default SphIoU threshold of 0.6.  ``max_out`` bounds the
    number of survivors (useful for fixed-shape serving buffers).
    Deliberately NOT expressed via ``sph_nms_batch``: this is the
    independent oracle the batched implementations are tested against.
    """
    n = boxes.shape[0]
    order = jnp.argsort(-scores)
    boxes_sorted = boxes[order]
    iou = sph_iou_matrix(boxes_sorted, boxes_sorted)

    def body(i, keep):
        # i is suppressed if any higher-scoring kept box overlaps it
        mask_higher = (jnp.arange(n) < i) & keep
        overlapped = jnp.any(jnp.where(mask_higher, iou[:, i] > iou_threshold, False))
        return keep.at[i].set(~overlapped)

    keep_sorted = jax.lax.fori_loop(0, n, body, jnp.ones((n,), dtype=bool))
    if max_out is not None:
        rank = jnp.cumsum(keep_sorted.astype(jnp.int32)) - 1
        keep_sorted = keep_sorted & (rank < max_out)
    # un-sort
    keep = jnp.zeros((n,), dtype=bool).at[order].set(keep_sorted)
    return keep


def _sph_intersection_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`sph_intersection` for (..., N, 4) x (..., M, 4)
    grids; leading axes are batch dims shared by ``a`` and ``b``."""
    ta, pa = a[..., :, None, 0], a[..., :, None, 1]
    ha, va = a[..., :, None, 2] / 2, a[..., :, None, 3] / 2
    tb, pb = b[..., None, :, 0], b[..., None, :, 1]
    hb, vb = b[..., None, :, 2] / 2, b[..., None, :, 3] / 2
    dt = tb - ta
    cpa, spa = np.cos(pa), np.sin(pa)
    cpb, spb = np.cos(pb), np.sin(pb)
    cdt = np.cos(dt)
    x = cpa * cpb * cdt + spa * spb
    y = cpb * np.sin(dt)
    z = -spa * cpb * cdt + cpa * spb
    dlon = np.arctan2(y, x)
    dlat = np.arcsin(np.clip(z, -1.0, 1.0))
    lon_w = np.maximum(np.minimum(ha, dlon + hb) - np.maximum(-ha, dlon - hb), 0)
    lat_hi = np.minimum(va, dlat + vb)
    lat_lo = np.maximum(-va, dlat - vb)
    lat_w = np.where(lat_hi > lat_lo, np.sin(lat_hi) - np.sin(lat_lo), 0.0)
    return lon_w * np.maximum(lat_w, 0.0)


def sph_iou_matrix_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pure-NumPy (..., N, M) SphIoU — the host serving path (no jax
    dispatch overhead per frame; identical math to
    :func:`sph_iou_matrix`).  Leading axes of ``a``/``b`` are batch
    dims, so a padded (B, N, 4) stack yields (B, N, N) in one call."""
    inter_ba = np.swapaxes(_sph_intersection_np(b, a), -1, -2)
    inter = 0.5 * (_sph_intersection_np(a, b) + inter_ba)
    area_a = 2.0 * a[..., :, 2] * np.sin(a[..., :, 3] / 2.0)
    area_b = 2.0 * b[..., :, 2] * np.sin(b[..., :, 3] / 2.0)
    union = area_a[..., :, None] + area_b[..., None, :] - inter
    return inter / np.maximum(union, 1e-12)


def sph_nms_host(
    boxes: np.ndarray,
    scores: np.ndarray,
    iou_threshold: float = 0.6,
) -> np.ndarray:
    """NumPy greedy spherical NMS for the host-side serving loop.

    Same semantics as :func:`sph_nms`; avoids a device round-trip for
    the handful of boxes the online loop handles per frame.
    """
    n = len(scores)
    if n == 0:
        return np.zeros((0,), dtype=bool)
    order = np.argsort(-np.asarray(scores), kind="stable")
    iou = sph_iou_matrix_np(np.asarray(boxes, np.float64),
                            np.asarray(boxes, np.float64))
    iou_sorted = iou[np.ix_(order, order)]
    # Vectorised greedy: each iteration keeps the best remaining box and
    # suppresses all its overlaps at once, so the loop runs once per
    # SURVIVOR (not once per box as the old per-index loop did).
    keep_sorted = np.zeros((n,), dtype=bool)
    active = np.ones((n,), dtype=bool)
    while True:
        idx = int(np.argmax(active))  # first still-active in score order
        if not active[idx]:
            break
        keep_sorted[idx] = True
        active &= iou_sorted[idx] <= iou_threshold
        active[idx] = False
    keep = np.zeros((n,), dtype=bool)
    keep[order] = keep_sorted
    return keep


# --------------------------------------------------------------------------
# Batched spherical NMS (the pod-tick subsystem; see module docstring)
# --------------------------------------------------------------------------

# Row-chunk caps: bound the (chunk, N, N) IoU tensor so huge rows
# (bench N=4096) stay within memory — ~32M float64 elements on host,
# ~128M float32 on device.
_HOST_CHUNK_ELEMS = 1 << 25
_DEVICE_CHUNK_ELEMS = 1 << 27
# "auto" picks the jitted device path (TPU) only at B*N >= this; below
# it, per-shape retracing would dominate the handful of boxes involved.
_AUTO_DEVICE_MIN_ELEMS = 512


def _greedy_suppress_rows_np(
    iou: np.ndarray,       # (B, N, N)
    scores: np.ndarray,    # (B, N)
    active: np.ndarray,    # (B, N) bool, consumed
    iou_threshold: float,
) -> np.ndarray:
    """Batched greedy suppression; iterations = max survivors over rows."""
    b, n = scores.shape
    keep = np.zeros((b, n), dtype=bool)
    cols = np.arange(n)[None, :]
    while active.any():
        masked = np.where(active, scores, -np.inf)
        best = np.argmax(masked, axis=1)                     # (B,)
        has = active.any(axis=1)                             # (B,)
        sel = (cols == best[:, None]) & has[:, None]
        keep |= sel
        iou_best = np.take_along_axis(iou, best[:, None, None], axis=1)[:, 0, :]
        active &= ~((iou_best > iou_threshold) & has[:, None]) & ~sel
    return keep


def _sph_nms_batch_host(
    boxes: np.ndarray, scores: np.ndarray, mask: np.ndarray,
    iou_threshold: float,
) -> np.ndarray:
    b, n, _ = boxes.shape
    keep = np.zeros((b, n), dtype=bool)
    chunk = max(1, _HOST_CHUNK_ELEMS // max(n * n, 1))
    for lo in range(0, b, chunk):
        hi = min(lo + chunk, b)
        iou = sph_iou_matrix_np(boxes[lo:hi].astype(np.float64),
                                boxes[lo:hi].astype(np.float64))
        keep[lo:hi] = _greedy_suppress_rows_np(
            iou, scores[lo:hi], mask[lo:hi].copy(), iou_threshold)
    return keep


# incremented at TRACE time of the jitted device/jit NMS path — the
# regression pin for shape bucketing (a serving run's retrace count
# stays bounded by the (B, N) ladder, mirroring JaxDetectorBackend's
# `trace_count`).
_NMS_DEVICE_TRACES = [0]


def nms_device_trace_count() -> int:
    """How many distinct (B, N) shapes the device NMS path has traced."""
    return _NMS_DEVICE_TRACES[0]


def nms_auto_backend(b: int, n: int) -> str:
    """The backend ``sph_nms_batch(backend="auto")`` picks for (B, N).

    Device only for genuinely batched work on TPU: the jitted path
    retraces per (B, N) shape, so the small single-row calls the
    per-frame serving loop makes stay on host everywhere.  Exposed so
    callers (``PodServer._suppress_tick``) can decide whether ladder
    padding buys bounded compile shapes or just wastes host-path work.
    """
    pod_scale = b * n >= _AUTO_DEVICE_MIN_ELEMS
    return ("device" if jax.default_backend() == "tpu" and pod_scale
            else "host")


@functools.partial(
    jax.jit, static_argnames=("interpret", "use_pallas", "iou_dtype"))
def _sph_nms_batch_device(
    boxes: Array, scores: Array, mask: Array, iou_threshold: Array,
    *, interpret: bool = False, use_pallas: bool = True,
    iou_dtype=None,
) -> Array:
    """(B, N) keep-mask: batched SphIoU + on-device greedy loop.

    The whole pod tick is one dispatch: the ``lax.while_loop`` keeps
    every row's best remaining candidate and suppresses its overlaps,
    terminating after max-survivors-per-row iterations.  The IoU block
    is the batched Pallas kernel (``use_pallas``, the TPU path) or the
    vmapped jnp oracle (XLA-fused; the fast compiled path on CPU where
    Pallas would run in interpret mode).  ``iou_dtype`` (e.g.
    ``jnp.bfloat16``) selects the IoU compute precision — cheaper VPU
    work at the cost of keep flips for near-threshold pairs (bound
    measured in the kernel bench and gated nightly).
    """
    _NMS_DEVICE_TRACES[0] += 1  # runs at trace time only
    b, n, _ = boxes.shape
    if use_pallas:
        from repro.kernels.sphiou.ops import sphiou_matrix_batch

        iou = sphiou_matrix_batch(boxes, boxes, interpret=interpret,
                                  dtype=iou_dtype or jnp.float32)
    elif iou_dtype is not None:
        iou = jax.vmap(sph_iou_matrix)(
            boxes.astype(iou_dtype), boxes.astype(iou_dtype)
        ).astype(jnp.float32)
    else:
        iou = jax.vmap(sph_iou_matrix)(boxes, boxes)
    cols = jnp.arange(n)[None, :]

    def cond(state):
        _, active = state
        return jnp.any(active)

    def body(state):
        keep, active = state
        masked = jnp.where(active, scores, -jnp.inf)
        best = jnp.argmax(masked, axis=1)                    # (B,)
        has = jnp.any(active, axis=1)                        # (B,)
        sel = (cols == best[:, None]) & has[:, None]
        keep = keep | sel
        iou_best = jnp.take_along_axis(
            iou, best[:, None, None], axis=1)[:, 0, :]       # (B, N)
        active = active & ~((iou_best > iou_threshold) & has[:, None]) & ~sel
        return keep, active

    keep, _ = jax.lax.while_loop(
        cond, body,
        (jnp.zeros((b, n), dtype=bool), mask.astype(bool)),
    )
    return keep


def _apply_max_out_np(
    keep: np.ndarray, scores: np.ndarray, max_out: int
) -> np.ndarray:
    order = np.argsort(-scores, axis=1, kind="stable")
    keep_sorted = np.take_along_axis(keep, order, axis=1)
    rank = np.cumsum(keep_sorted.astype(np.int64), axis=1) - 1
    keep_sorted &= rank < max_out
    out = np.zeros_like(keep)
    np.put_along_axis(out, order, keep_sorted, axis=1)
    return out


def sph_nms_batch(
    boxes: np.ndarray | Array,        # (B, N, 4) padded SphBB stack
    scores: np.ndarray | Array,       # (B, N)
    mask: np.ndarray | Array | None = None,  # (B, N) bool; False = padding
    iou_threshold: float = 0.6,
    max_out: int | None = None,
    *,
    backend: str = "auto",
    iou_dtype=None,
) -> np.ndarray:
    """Batched greedy spherical NMS over padded rows -> (B, N) bool.

    One row per stream/frame; rows are suppressed independently but in a
    single dispatch (see the module docstring's design note).  Padded
    entries (``mask == False``) are never kept.

    ``backend``:
      * ``"auto"``   — ``"device"`` on TPU for pod-scale batches
        (``B * N`` past a small floor), ``"host"`` otherwise: the
        Pallas kernel runs in slow interpret mode off-TPU, and for the
        small frame-level rows the serving loop sees, NumPy beats a
        per-shape XLA recompile even on TPU hosts;
      * ``"device"`` — batched Pallas SphIoU + ``lax.while_loop``
        (interpret mode off-TPU, which is also the CI correctness
        harness for the kernel);
      * ``"jit"``    — same ``lax.while_loop`` with the XLA-fused jnp
        IoU instead of Pallas: the fast COMPILED path on CPU for big
        recurring shapes (benchmarks, bulk re-scoring);
      * ``"host"``   — vectorised NumPy (float64 IoU, same greedy).

    Rows are independent, so the device/jit paths process very large
    batches in row chunks to bound the (chunk, N, N) IoU tensor.

    Inputs keep their dtype on the host path (the float64 serving
    boxes/scores are compared at full precision, exactly like
    ``sph_nms_host``); only the device/jit dispatch casts to float32.

    ``iou_dtype`` (device/jit backends only) lowers the IoU compute
    precision — ``jnp.bfloat16`` halves the VPU element width on TPU.
    Near-threshold pairs can flip their keep decision; the flip rate is
    measured in ``benchmarks/kernels_bench.py`` and gated nightly.
    """
    boxes = np.asarray(boxes)
    scores = np.asarray(scores)
    b, n = scores.shape
    if mask is None:
        mask = np.ones((b, n), dtype=bool)
    else:
        mask = np.asarray(mask, dtype=bool)
    if n == 0:
        return np.zeros((b, 0), dtype=bool)

    if backend == "auto":
        backend = nms_auto_backend(b, n)
    if backend == "host":
        if iou_dtype is not None:
            raise ValueError("iou_dtype needs the device or jit backend")
        keep = _sph_nms_batch_host(boxes, scores, mask, iou_threshold)
    elif backend in ("device", "jit"):
        chunk = max(1, _DEVICE_CHUNK_ELEMS // max(n * n, 1))
        parts = []
        for lo in range(0, b, chunk):
            hi = min(lo + chunk, b)
            parts.append(np.asarray(_sph_nms_batch_device(
                jnp.asarray(boxes[lo:hi], jnp.float32),
                jnp.asarray(scores[lo:hi], jnp.float32),
                jnp.asarray(mask[lo:hi]),
                jnp.asarray(iou_threshold, jnp.float32),
                interpret=jax.default_backend() != "tpu",
                use_pallas=backend == "device",
                iou_dtype=iou_dtype,
            )))
        keep = np.concatenate(parts, axis=0)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    if max_out is not None:
        keep = _apply_max_out_np(keep, scores, max_out)
    return keep


def pad_detection_rows(rows, pad_n=None, total_rows: int | None = None
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad per-row detection lists into ``sph_nms_batch`` inputs.

    ``rows`` is a sequence of detection lists (anything with a ``box``
    (4,) array and a ``score``), one per stream/frame.  Returns
    ``(boxes (B, N, 4), scores (B, N), mask (B, N))`` padded to the
    longest row, float64 so the host path keeps full precision.

    ``pad_n`` bounds the device path's compile shapes: a callable
    (e.g. ``ShapeBuckets.pad_nms_rows``) snapping the longest row up to
    a bucket ladder, so the jitted (B, N) program compiles once per
    ladder rung instead of once per distinct detection count.
    ``total_rows`` pads B with all-masked rows up to a fixed row count
    (the pod's stream count) for the same reason; masked padding can
    never be kept, so the keep-masks of the real rows are unchanged.
    """
    b = max(len(rows), total_rows or 0)
    n_max = max((len(r) for r in rows), default=0)
    if pad_n is not None:
        n_max = pad_n(n_max)
    boxes = np.zeros((b, n_max, 4), np.float64)
    scores = np.zeros((b, n_max), np.float64)
    mask = np.zeros((b, n_max), bool)
    for r, dets in enumerate(rows):
        k = len(dets)
        if k:
            boxes[r, :k] = np.stack([d.box for d in dets])
            scores[r, :k] = [d.score for d in dets]
            mask[r, :k] = True
    return boxes, scores, mask


class IncrementalNms:
    """Cross-tick batched NMS that recomputes only the changed rows.

    Consecutive ticks of a mostly-static scene re-suppress near-identical
    per-stream detection rows; since :func:`sph_nms_batch` rows are
    independent, a row whose (boxes, scores) are *exactly* the ones it
    was suppressed with last tick can reuse last tick's keep-mask and
    skip its (N, N) SphIoU block entirely.  Changed rows batch into one
    ``sph_nms_batch`` call over the changed subset, so the result is
    bit-identical to a full recompute by construction (pinned by the
    fused-tick property tests).

    Rows are addressed by a caller-stable ``key`` (the serving tier uses
    the per-stream loop identity); padding does not participate in the
    comparison, so reuse survives tick-to-tick changes of the padded N.
    """

    def __init__(self, iou_threshold: float = 0.6, *, backend: str = "auto",
                 iou_dtype=None, capacity: int = 4096):
        self.iou_threshold = iou_threshold
        self.backend = backend
        self.iou_dtype = iou_dtype
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._rows: dict = {}  # key -> (k, boxes bytes, scores bytes, keep)

    def clear(self) -> None:
        self._rows.clear()

    @staticmethod
    def _canon(boxes_r: np.ndarray, scores_r: np.ndarray, mask_r: np.ndarray
               ) -> tuple[int, bytes, bytes]:
        k = int(mask_r.sum())
        return (k, np.ascontiguousarray(boxes_r[:k]).tobytes(),
                np.ascontiguousarray(scores_r[:k]).tobytes())

    def suppress(
        self,
        keys,                 # length-B sequence of stable row keys
        boxes: np.ndarray,    # (B, N, 4) padded (mask prefix-contiguous)
        scores: np.ndarray,   # (B, N)
        mask: np.ndarray | None = None,
        *,
        max_out: int | None = None,
    ) -> np.ndarray:
        boxes = np.asarray(boxes)
        scores = np.asarray(scores)
        b, n = scores.shape
        if mask is None:
            mask = np.ones((b, n), dtype=bool)
        else:
            mask = np.asarray(mask, dtype=bool)
        keep = np.zeros((b, n), dtype=bool)
        canon = [self._canon(boxes[r], scores[r], mask[r]) for r in range(b)]
        changed = []
        for r, key in enumerate(keys):
            ent = self._rows.get(key)
            if ent is not None and ent[:3] == canon[r]:
                self.hits += 1
                k, kept = ent[0], ent[3]
                keep[r, :k] = kept
            else:
                self.misses += 1
                changed.append(r)
        if changed:
            sub = np.asarray(changed)
            sub_keep = sph_nms_batch(
                boxes[sub], scores[sub], mask[sub],
                iou_threshold=self.iou_threshold, backend=self.backend,
                iou_dtype=self.iou_dtype)
            keep[sub] = sub_keep
            for r in changed:
                if len(self._rows) >= self.capacity:
                    self._rows.pop(next(iter(self._rows)))
                k = canon[r][0]
                self._rows[keys[r]] = canon[r] + (keep[r, :k].copy(),)
        if max_out is not None:
            keep = _apply_max_out_np(keep, scores, max_out)
        return keep


# --------------------------------------------------------------------------
# ERP pixel <-> sphere
# --------------------------------------------------------------------------


def erp_to_sph(u: Array, v: Array, width: int, height: int) -> tuple[Array, Array]:
    """ERP pixel coords (u right, v down; origin top-left) -> (lon, lat)."""
    theta = (u / width - 0.5) * 2.0 * jnp.pi
    phi = (0.5 - v / height) * jnp.pi
    return theta, phi


def sph_to_erp(theta: Array, phi: Array, width: int, height: int) -> tuple[Array, Array]:
    """(lon, lat) -> ERP pixel coords (float)."""
    u = (theta / (2.0 * jnp.pi) + 0.5) * width
    v = (0.5 - phi / jnp.pi) * height
    return u, v


# --------------------------------------------------------------------------
# PI detections -> SphBBs
# --------------------------------------------------------------------------


def pi_box_to_sphbb(
    rect: Array,
    center_theta: Array,
    center_phi: Array,
    fov: tuple[float, float],
    pi_size: tuple[int, int],
) -> Array:
    """Back-project rectangular detections on a PI into SphBBs.

    ``rect``: (..., 4) boxes as (x0, y0, x1, y1) in PI pixel coords.
    ``fov``: (horizontal, vertical) field of view of the PI in radians.
    ``pi_size``: (width, height) of the PI in pixels.

    The PI is tangent at (center_theta, center_phi) (gnomonic).  Each
    corner is lifted to a direction on the sphere; the detection's own
    centre direction defines its tangent frame, and dtheta/dphi are the
    angular extents of the corners in that frame — the "spherical
    coordinate transformation" of paper section III-A.
    """
    w, h = pi_size
    half_x = jnp.tan(fov[0] / 2.0)
    half_y = jnp.tan(fov[1] / 2.0)

    def lift(px, py):
        # pixel -> tangent-plane coords
        x = (px / w - 0.5) * 2.0 * half_x
        y = (0.5 - py / h) * 2.0 * half_y
        d = jnp.stack([jnp.ones_like(x), x, y], axis=-1)
        d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
        r = rotation_from_origin(center_theta, center_phi)
        return jnp.einsum("...ij,...j->...i", r, d)

    x0, y0, x1, y1 = rect[..., 0], rect[..., 1], rect[..., 2], rect[..., 3]
    cx, cy = (x0 + x1) / 2.0, (y0 + y1) / 2.0
    center_dir = lift(cx, cy)
    ct, cp = cart_to_sph(center_dir)

    corners = jnp.stack(
        [lift(x0, y0), lift(x1, y0), lift(x0, y1), lift(x1, y1)], axis=-2
    )  # (..., 4, 3)
    r_inv = rotation_to_origin(ct, cp)
    local = jnp.einsum("...ij,...kj->...ki", r_inv, corners)
    lon, lat = cart_to_sph(local)
    dtheta = jnp.max(lon, axis=-1) - jnp.min(lon, axis=-1)
    dphi = jnp.max(lat, axis=-1) - jnp.min(lat, axis=-1)
    return jnp.stack([ct, cp, dtheta, dphi], axis=-1)


def normalized_object_area(boxes: Array) -> Array:
    """NOA: SphBB area normalised by the sphere's surface area (4*pi)."""
    return sph_area(boxes) / (4.0 * jnp.pi)

"""Spherical geometry primitives for 360-degree video analytics.

Implements the spherical criteria of Zhao et al. (AAAI'20) used by the
OmniSense paper:

  * ``SphBB`` — a spherical bounding box ``(theta, phi, dtheta, dphi)``
    where ``theta`` is the longitude of the box centre in ``[-pi, pi]``,
    ``phi`` the latitude in ``[-pi/2, pi/2]`` and ``dtheta``/``dphi``
    the horizontal/vertical field-of-view occupied by the object,
    *defined in the box's own tangent frame* (i.e. the box is the
    rotation of an equator-centred spherical rectangle).
  * ``sph_area`` — the area of a SphBB on the unit sphere,
    ``2 * dtheta * sin(dphi / 2)`` (rotation invariant; paper footnote 1).
  * ``sph_iou`` — pairwise spherical IoU.  Box A's centre is rotated to
    the equator origin and box B's centre is expressed exactly in that
    rotated frame; the intersection is then evaluated as the
    lat/long-interval overlap of two equator-centred rectangles (the
    fast approximation of the AAAI'20 spherical criteria).
  * ``sph_nms`` — greedy spherical non-maximum suppression (paper
    default threshold 0.6), in both a jit-compatible ``lax`` form and a
    fast host/NumPy form used by the online serving loop.

All functions are vectorised over leading axes and safe to ``jax.jit``.
Angles are radians everywhere; degrees only at config boundaries.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array

# --------------------------------------------------------------------------
# Coordinate transforms
# --------------------------------------------------------------------------


def sph_to_cart(theta: Array, phi: Array) -> Array:
    """(lon, lat) -> unit vector, shape (..., 3).

    x axis points at (theta=0, phi=0); z is the north pole.
    """
    cp = jnp.cos(phi)
    return jnp.stack([cp * jnp.cos(theta), cp * jnp.sin(theta), jnp.sin(phi)], axis=-1)


def cart_to_sph(v: Array) -> tuple[Array, Array]:
    """Unit vector (..., 3) -> (lon, lat)."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    theta = jnp.arctan2(y, x)
    phi = jnp.arcsin(jnp.clip(z, -1.0, 1.0))
    return theta, phi


def wrap_angle(a: Array) -> Array:
    """Wrap angle(s) to [-pi, pi)."""
    return (a + jnp.pi) % (2.0 * jnp.pi) - jnp.pi


def rotation_to_origin(theta: Array, phi: Array) -> Array:
    """Rotation matrix R (.., 3, 3) with R @ dir(theta, phi) == (1, 0, 0).

    Composition: first undo longitude (rotate about z by -theta), then undo
    latitude (rotate about y by +phi).
    """
    ct, st = jnp.cos(theta), jnp.sin(theta)
    cp, sp = jnp.cos(phi), jnp.sin(phi)
    zero = jnp.zeros_like(ct)
    one = jnp.ones_like(ct)
    # Rz(-theta)
    rz = jnp.stack(
        [
            jnp.stack([ct, st, zero], axis=-1),
            jnp.stack([-st, ct, zero], axis=-1),
            jnp.stack([zero, zero, one], axis=-1),
        ],
        axis=-2,
    )
    # Ry(phi): rotates the +x axis toward +z by -phi... chosen so that
    # Ry @ (cos(phi), 0, sin(phi)) = (1, 0, 0).
    ry = jnp.stack(
        [
            jnp.stack([cp, zero, sp], axis=-1),
            jnp.stack([zero, one, zero], axis=-1),
            jnp.stack([-sp, zero, cp], axis=-1),
        ],
        axis=-2,
    )
    return ry @ rz


def rotation_from_origin(theta: Array, phi: Array) -> Array:
    """Inverse of :func:`rotation_to_origin` (transpose)."""
    r = rotation_to_origin(theta, phi)
    return jnp.swapaxes(r, -1, -2)


# --------------------------------------------------------------------------
# SphBB area / IoU
# --------------------------------------------------------------------------


def sph_area(boxes: Array) -> Array:
    """Area on the unit sphere of SphBBs (..., 4) -> (...).

    ``area = 2 * dtheta * sin(dphi / 2)`` (paper footnote 1).  Rotation
    invariant because the box is defined in its own tangent frame.
    """
    dtheta = boxes[..., 2]
    dphi = boxes[..., 3]
    return 2.0 * dtheta * jnp.sin(dphi / 2.0)


def _interval_overlap(lo1: Array, hi1: Array, lo2: Array, hi2: Array) -> tuple[Array, Array]:
    lo = jnp.maximum(lo1, lo2)
    hi = jnp.minimum(hi1, hi2)
    return lo, hi


def sph_intersection(boxes_a: Array, boxes_b: Array) -> Array:
    """Pairwise intersection area between two broadcastable SphBB arrays.

    ``boxes_a``: (..., 4) and ``boxes_b``: (..., 4), already broadcast
    against each other (callers usually expand dims to form an N x M
    grid).  Box A is rotated to the origin; box B's centre is expressed
    exactly in A's frame; both are then treated as equator-centred
    lat/long rectangles (AAAI'20 fast criteria).
    """
    ta, pa = boxes_a[..., 0], boxes_a[..., 1]
    tb, pb = boxes_b[..., 0], boxes_b[..., 1]
    # exact position of B's centre in A's frame
    r = rotation_to_origin(ta, pa)
    db = sph_to_cart(tb, pb)
    db_in_a = jnp.einsum("...ij,...j->...i", r, db)
    dlon, dlat = cart_to_sph(db_in_a)

    half_ta, half_pa = boxes_a[..., 2] / 2.0, boxes_a[..., 3] / 2.0
    half_tb, half_pb = boxes_b[..., 2] / 2.0, boxes_b[..., 3] / 2.0

    lon_lo, lon_hi = _interval_overlap(-half_ta, half_ta, dlon - half_tb, dlon + half_tb)
    lat_lo, lat_hi = _interval_overlap(-half_pa, half_pa, dlat - half_pb, dlat + half_pb)

    lon_w = jnp.maximum(lon_hi - lon_lo, 0.0)
    # exact area element in latitude: integral of cos(phi) d(phi)
    lat_w = jnp.maximum(jnp.sin(lat_hi) - jnp.sin(lat_lo), 0.0)
    lat_w = jnp.where(lat_hi > lat_lo, lat_w, 0.0)
    return lon_w * lat_w


def sph_iou(boxes_a: Array, boxes_b: Array) -> Array:
    """Pairwise SphIoU of broadcastable SphBB arrays -> (...).

    The single-direction fast approximation is slightly asymmetric for
    large boxes at different latitudes (whichever box is rotated to the
    origin sees less distortion); we symmetrise by averaging the two
    directions, which restores IoU(a, b) == IoU(b, a) exactly.
    """
    inter = 0.5 * (sph_intersection(boxes_a, boxes_b)
                   + sph_intersection(boxes_b, boxes_a))
    union = sph_area(boxes_a) + sph_area(boxes_b) - inter
    return inter / jnp.maximum(union, 1e-12)


def sph_iou_matrix(boxes_a: Array, boxes_b: Array) -> Array:
    """(N, 4) x (M, 4) -> (N, M) SphIoU matrix (pure jnp reference).

    The Pallas kernel in ``repro.kernels.sphiou`` computes the same
    matrix tile-by-tile; this function is its oracle.
    """
    return sph_iou(boxes_a[:, None, :], boxes_b[None, :, :])


# --------------------------------------------------------------------------
# Spherical NMS
# --------------------------------------------------------------------------


def sph_nms(
    boxes: Array,
    scores: Array,
    iou_threshold: float = 0.6,
    max_out: int | None = None,
) -> Array:
    """Greedy spherical NMS, jit-compatible.

    Returns a boolean keep-mask of shape (N,).  Suppression follows the
    paper's default SphIoU threshold of 0.6.  ``max_out`` bounds the
    number of survivors (useful for fixed-shape serving buffers).
    """
    n = boxes.shape[0]
    order = jnp.argsort(-scores)
    boxes_sorted = boxes[order]
    iou = sph_iou_matrix(boxes_sorted, boxes_sorted)

    def body(i, keep):
        # i is suppressed if any higher-scoring kept box overlaps it
        mask_higher = (jnp.arange(n) < i) & keep
        overlapped = jnp.any(jnp.where(mask_higher, iou[:, i] > iou_threshold, False))
        return keep.at[i].set(~overlapped)

    keep_sorted = jax.lax.fori_loop(0, n, body, jnp.ones((n,), dtype=bool))
    if max_out is not None:
        rank = jnp.cumsum(keep_sorted.astype(jnp.int32)) - 1
        keep_sorted = keep_sorted & (rank < max_out)
    # un-sort
    keep = jnp.zeros((n,), dtype=bool).at[order].set(keep_sorted)
    return keep


def _sph_intersection_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`sph_intersection` for (N,4)x(M,4) grids."""
    ta, pa = a[:, None, 0], a[:, None, 1]
    ha, va = a[:, None, 2] / 2, a[:, None, 3] / 2
    tb, pb = b[None, :, 0], b[None, :, 1]
    hb, vb = b[None, :, 2] / 2, b[None, :, 3] / 2
    dt = tb - ta
    cpa, spa = np.cos(pa), np.sin(pa)
    cpb, spb = np.cos(pb), np.sin(pb)
    cdt = np.cos(dt)
    x = cpa * cpb * cdt + spa * spb
    y = cpb * np.sin(dt)
    z = -spa * cpb * cdt + cpa * spb
    dlon = np.arctan2(y, x)
    dlat = np.arcsin(np.clip(z, -1.0, 1.0))
    lon_w = np.maximum(np.minimum(ha, dlon + hb) - np.maximum(-ha, dlon - hb), 0)
    lat_hi = np.minimum(va, dlat + vb)
    lat_lo = np.maximum(-va, dlat - vb)
    lat_w = np.where(lat_hi > lat_lo, np.sin(lat_hi) - np.sin(lat_lo), 0.0)
    return lon_w * np.maximum(lat_w, 0.0)


def sph_iou_matrix_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pure-NumPy (N, M) SphIoU — the host serving path (no jax dispatch
    overhead per frame; identical math to :func:`sph_iou_matrix`)."""
    inter = 0.5 * (_sph_intersection_np(a, b) + _sph_intersection_np(b, a).T)
    area_a = 2.0 * a[:, 2] * np.sin(a[:, 3] / 2.0)
    area_b = 2.0 * b[:, 2] * np.sin(b[:, 3] / 2.0)
    union = area_a[:, None] + area_b[None, :] - inter
    return inter / np.maximum(union, 1e-12)


def sph_nms_host(
    boxes: np.ndarray,
    scores: np.ndarray,
    iou_threshold: float = 0.6,
) -> np.ndarray:
    """NumPy greedy spherical NMS for the host-side serving loop.

    Same semantics as :func:`sph_nms`; avoids a device round-trip for
    the handful of boxes the online loop handles per frame.
    """
    n = len(scores)
    if n == 0:
        return np.zeros((0,), dtype=bool)
    order = np.argsort(-scores)
    iou = sph_iou_matrix_np(np.asarray(boxes, np.float64),
                            np.asarray(boxes, np.float64))
    keep = np.zeros((n,), dtype=bool)
    suppressed = np.zeros((n,), dtype=bool)
    for idx in order:
        if suppressed[idx]:
            continue
        keep[idx] = True
        overl = iou[idx] > iou_threshold
        overl[idx] = False
        suppressed |= overl
    return keep


# --------------------------------------------------------------------------
# ERP pixel <-> sphere
# --------------------------------------------------------------------------


def erp_to_sph(u: Array, v: Array, width: int, height: int) -> tuple[Array, Array]:
    """ERP pixel coords (u right, v down; origin top-left) -> (lon, lat)."""
    theta = (u / width - 0.5) * 2.0 * jnp.pi
    phi = (0.5 - v / height) * jnp.pi
    return theta, phi


def sph_to_erp(theta: Array, phi: Array, width: int, height: int) -> tuple[Array, Array]:
    """(lon, lat) -> ERP pixel coords (float)."""
    u = (theta / (2.0 * jnp.pi) + 0.5) * width
    v = (0.5 - phi / jnp.pi) * height
    return u, v


# --------------------------------------------------------------------------
# PI detections -> SphBBs
# --------------------------------------------------------------------------


def pi_box_to_sphbb(
    rect: Array,
    center_theta: Array,
    center_phi: Array,
    fov: tuple[float, float],
    pi_size: tuple[int, int],
) -> Array:
    """Back-project rectangular detections on a PI into SphBBs.

    ``rect``: (..., 4) boxes as (x0, y0, x1, y1) in PI pixel coords.
    ``fov``: (horizontal, vertical) field of view of the PI in radians.
    ``pi_size``: (width, height) of the PI in pixels.

    The PI is tangent at (center_theta, center_phi) (gnomonic).  Each
    corner is lifted to a direction on the sphere; the detection's own
    centre direction defines its tangent frame, and dtheta/dphi are the
    angular extents of the corners in that frame — the "spherical
    coordinate transformation" of paper section III-A.
    """
    w, h = pi_size
    half_x = jnp.tan(fov[0] / 2.0)
    half_y = jnp.tan(fov[1] / 2.0)

    def lift(px, py):
        # pixel -> tangent-plane coords
        x = (px / w - 0.5) * 2.0 * half_x
        y = (0.5 - py / h) * 2.0 * half_y
        d = jnp.stack([jnp.ones_like(x), x, y], axis=-1)
        d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
        r = rotation_from_origin(center_theta, center_phi)
        return jnp.einsum("...ij,...j->...i", r, d)

    x0, y0, x1, y1 = rect[..., 0], rect[..., 1], rect[..., 2], rect[..., 3]
    cx, cy = (x0 + x1) / 2.0, (y0 + y1) / 2.0
    center_dir = lift(cx, cy)
    ct, cp = cart_to_sph(center_dir)

    corners = jnp.stack(
        [lift(x0, y0), lift(x1, y0), lift(x0, y1), lift(x1, y1)], axis=-2
    )  # (..., 4, 3)
    r_inv = rotation_to_origin(ct, cp)
    local = jnp.einsum("...ij,...kj->...ki", r_inv, corners)
    lon, lat = cart_to_sph(local)
    dtheta = jnp.max(lon, axis=-1) - jnp.min(lon, axis=-1)
    dphi = jnp.max(lat, axis=-1) - jnp.min(lat, axis=-1)
    return jnp.stack([ct, cp, dtheta, dphi], axis=-1)


def normalized_object_area(boxes: Array) -> Array:
    """NOA: SphBB area normalised by the sphere's surface area (4*pi)."""
    return sph_area(boxes) / (4.0 * jnp.pi)

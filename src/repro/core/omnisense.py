"""The per-frame OmniSense loop (paper Fig. 5) tying the core together.

    frame -> SRoI predictor -> resource allocator -> inference scheduler
          -> spherical NMS -> results (fed back to the predictor)

This module is substrate-agnostic: the detector, the latency model and
the execution backend are injected, so the same loop drives

  * the CPU prototype used in tests/examples (real small detectors),
  * the reproduction benchmark (paper-regime latency tables), and
  * the pod serving runtime in ``repro.serving.server``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Protocol, Sequence

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import allocation, discovery, sroi
from repro.core.sphere import sph_nms_batch


class LatencyModel(Protocol):
    """Provides the allocator's delay terms for a frame's SRoIs."""

    def delays(
        self, srois: Sequence[sroi.SRoI], variants: Sequence[acc_mod.ModelProfile]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Return (d_pre, d_inf), each (1 + n_variants, n_srois); row 0
        is the zero-cost "skip" pseudo-model."""
        ...


class InferenceBackend(Protocol):
    """Executes one SRoI with one variant; returns spherical detections."""

    def infer_sroi(
        self, frame: np.ndarray, region: sroi.SRoI, variant: acc_mod.ModelProfile
    ) -> list[sroi.Detection]:
        ...

    def infer_erp(
        self, frame: np.ndarray, variant: acc_mod.ModelProfile
    ) -> list[sroi.Detection]:
        """Full-ERP inference used by the discovery mechanism."""
        ...


@dataclasses.dataclass
class FrameResult:
    detections: list[sroi.Detection]
    srois: list[sroi.SRoI]
    plan: allocation.Plan | None
    planned_latency: float
    overhead_s: float  # SRoI prediction + allocation + post-processing
    discovered: bool


class OmniSenseLoop:
    """Stateful per-stream analytics session."""

    def __init__(
        self,
        variants: Sequence[acc_mod.ModelProfile],
        latency_model: LatencyModel,
        backend: InferenceBackend,
        budget_s: float,
        f_deg: float = 60.0,
        gamma: float = 1.1,
        delta: int = 2,
        nms_threshold: float = 0.6,
        n_categories: int = acc_mod.N_CATEGORIES,
        explore_every: int = 6,
        explore_costs: list[float] | None = None,
        on_plan: Callable[[allocation.Plan, list[sroi.SRoI]], None] | None = None,
    ) -> None:
        self.variants = list(variants)
        self.latency_model = latency_model
        self.backend = backend
        self.budget_s = budget_s
        self.f = math.radians(f_deg)
        self.gamma = gamma
        self.delta = delta
        self.nms_threshold = nms_threshold
        self.n_categories = n_categories
        # periodic spherical-object discovery: every `explore_every`
        # frames the loop reserves the full-ERP pass cost from the
        # allocator's budget and spends it on exploration (the paper's
        # discovery mechanism, run on a cadence so moving cameras keep
        # finding regions the history has never seen).
        self.explore_every = explore_every
        # per-variant full-ERP pass cost; exploration picks the largest
        # model affordable within ~60% of the budget, so tight budgets
        # explore with cheap models instead of starving the SRoI plan.
        self.explore_costs = explore_costs or [0.0] * len(self.variants)
        self._frame_idx = 0
        self.on_plan = on_plan
        # detection history: most recent `delta` frames
        self._history: list[list[sroi.Detection]] = []
        self._discovery = discovery.DiscoveryState()

    # -- helpers ----------------------------------------------------------

    def _flat_history(self) -> list[sroi.Detection]:
        out: list[sroi.Detection] = []
        for frame_dets in self._history[-self.delta :]:
            out.extend(frame_dets)
        return out

    def _weighted_acc_matrix(self, srois: Sequence[sroi.SRoI]) -> np.ndarray:
        """(1 + M, R): row 0 = skip (zero accuracy)."""
        m, r = len(self.variants), len(srois)
        out = np.zeros((1 + m, r), dtype=np.float64)
        for j, s in enumerate(srois):
            for i, var in enumerate(self.variants):
                out[1 + i, j] = acc_mod.weighted_accuracy(var.gav, s.ccv, s.alpha)
        return out

    # -- main entry --------------------------------------------------------

    def process_frame(self, frame: np.ndarray, *,
                      defer_nms: bool = False) -> FrameResult:
        """Run one frame.  With ``defer_nms=True`` the returned result
        holds the RAW (pre-NMS) detections and the history is NOT yet
        updated; the caller owns suppression and must hand the keep-mask
        back via :meth:`finalize_detections` before the next frame.
        ``PodServer`` uses this to suppress all streams finishing in a
        tick with one batched ``sph_nms_batch`` dispatch."""
        t0 = time.perf_counter()
        self._frame_idx += 1
        explore_frame = (self.explore_every > 0
                         and self._frame_idx % self.explore_every == 0)
        affordable = [i for i, c in enumerate(self.explore_costs)
                      if c <= 0.6 * self.budget_s]
        explore_idx = max(affordable) if affordable else             int(np.argmin(self.explore_costs))
        explore_cost = self.explore_costs[explore_idx]
        budget = self.budget_s
        if explore_frame:
            budget = max(0.0, budget - explore_cost)
        srois = sroi.predict_srois(
            self._flat_history(),
            f=self.f,
            gamma=self.gamma,
            n_categories=self.n_categories,
        )

        plan = None
        planned_latency = 0.0
        detections: list[sroi.Detection] = []
        if srois:
            acc = self._weighted_acc_matrix(srois)
            d_pre, d_inf = self.latency_model.delays(srois, self.variants)
            plan = allocation.allocate(acc, d_pre, d_inf, budget)
            if plan is not None:
                planned_latency = plan.t_done
                if self.on_plan is not None:
                    self.on_plan(plan, list(srois))
        overhead_alloc = time.perf_counter() - t0

        # ---- execute the plan (inference is NOT overhead) ----
        if plan is not None:
            for j, model_idx in enumerate(plan.models):
                if model_idx == 0:
                    continue  # skipped SRoI
                var = self.variants[model_idx - 1]
                dets = self.backend.infer_sroi(frame, srois[j], var)
                # special SRoIs keep only their largest detection
                if srois[j].special and dets:
                    dets = [max(dets, key=lambda d: d.noa())]
                detections.extend(dets)

        # ---- spherical object discovery ----
        self._discovery.observe(len(srois))
        discovered = False
        if explore_frame or self._discovery.should_discover(
                self.budget_s, planned_latency):
            detections.extend(self.backend.infer_erp(
                frame, self.variants[explore_idx]))
            discovered = True
            planned_latency = min(self.budget_s,
                                  planned_latency + explore_cost)

        result = FrameResult(
            detections=detections,
            srois=srois,
            plan=plan,
            planned_latency=planned_latency,
            overhead_s=overhead_alloc,
            discovered=discovered,
        )
        if defer_nms:
            return result

        # ---- post-processing: spherical NMS (single-row fast path of
        # the batched subsystem) ----
        t1 = time.perf_counter()
        self.finalize_detections(result, self.nms_keep(detections))
        result.overhead_s += time.perf_counter() - t1
        return result

    def nms_keep(self, detections: list[sroi.Detection]) -> np.ndarray | None:
        """Keep-mask for one frame's detections at this stream's
        threshold — the single-row fast path of ``sph_nms_batch``
        (also used by ``PodServer`` when streams disagree on the
        threshold and cannot share one padded batch)."""
        if not detections:
            return None
        boxes = np.stack([d.box for d in detections])
        scores = np.array([d.score for d in detections])
        return sph_nms_batch(
            boxes[None], scores[None], iou_threshold=self.nms_threshold)[0]

    def finalize_detections(self, result: FrameResult,
                            keep: np.ndarray | None) -> FrameResult:
        """Apply an externally computed NMS keep-mask and commit the
        surviving detections to the SRoI-prediction history.

        ``keep`` is a (n_detections,) bool mask (``None`` means "no
        detections this frame").  Must be called exactly once per
        ``process_frame(..., defer_nms=True)`` result, in frame order,
        so the detection feedback the predictor sees is identical to
        the inline path."""
        if keep is not None:
            result.detections = [
                d for d, k in zip(result.detections, keep) if k]
        self._history.append(result.detections)
        if len(self._history) > self.delta:
            self._history = self._history[-self.delta :]
        return result

    def seed_history(self, detections: list[sroi.Detection]) -> None:
        """Bootstrap the history (e.g. from an initial full-ERP pass)."""
        self._history.append(list(detections))

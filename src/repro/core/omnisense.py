"""The per-frame OmniSense loop (paper Fig. 5) tying the core together.

    frame -> SRoI predictor -> resource allocator -> inference scheduler
          -> spherical NMS -> results (fed back to the predictor)

This module is substrate-agnostic: the detector, the latency model and
the execution backend are injected, so the same loop drives

  * the CPU prototype used in tests/examples (real small detectors),
  * the reproduction benchmark (paper-regime latency tables), and
  * the pod serving runtime in ``repro.serving.server``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Protocol, Sequence

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import allocation, discovery, sroi
from repro.core.sphere import sph_nms_batch


class LatencyModel(Protocol):
    """Provides the allocator's delay terms for a frame's SRoIs."""

    def delays(
        self, srois: Sequence[sroi.SRoI], variants: Sequence[acc_mod.ModelProfile]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Return (d_pre, d_inf), each (1 + n_variants, n_srois); row 0
        is the zero-cost "skip" pseudo-model."""
        ...


class InferenceBackend(Protocol):
    """Executes one SRoI with one variant; returns spherical detections."""

    def infer_sroi(
        self, frame: np.ndarray, region: sroi.SRoI, variant: acc_mod.ModelProfile
    ) -> list[sroi.Detection]:
        ...

    def infer_erp(
        self, frame: np.ndarray, variant: acc_mod.ModelProfile
    ) -> list[sroi.Detection]:
        """Full-ERP inference used by the discovery mechanism."""
        ...


@dataclasses.dataclass
class FrameResult:
    detections: list[sroi.Detection]
    srois: list[sroi.SRoI]
    plan: allocation.Plan | None
    planned_latency: float
    overhead_s: float  # SRoI prediction + allocation + post-processing
    discovered: bool


@dataclasses.dataclass
class InferenceRequest:
    """One planned SRoI inference, emitted by :meth:`OmniSenseLoop.begin_frame`.

    The pod server parks these in per-variant queues and drains each
    tick into batched detector forwards; ``slot`` is the request's
    position in the owning frame's request list so the decoded
    detections scatter back in plan order.
    """

    region: sroi.SRoI
    variant: acc_mod.ModelProfile
    slot: int
    special: bool
    frame: np.ndarray | None = None


@dataclasses.dataclass
class FrameContext:
    """The planning inputs of one frame, before any allocator ran.

    Produced by :meth:`OmniSenseLoop.frame_context` (which advances the
    stream's frame/exploration state); consumed by
    :meth:`OmniSenseLoop.emit_pending` together with a plan.  The pod
    server collects every stream's context first and hands the batch to
    the pod-level allocator (``repro.serving.pod_allocation``), which
    couples the per-stream knapsacks through shared batched costs;
    standalone :meth:`OmniSenseLoop.begin_frame` composes the two
    halves with the per-stream ``allocation.allocate`` in between.

    ``acc``/``d_pre``/``d_inf`` are the (1 + M, R) allocator matrices
    (``None`` when the frame predicted no SRoIs); ``budget`` is the
    frame's latency budget net of any reserved exploration cost.
    """

    frame: np.ndarray | None
    srois: list[sroi.SRoI]
    acc: np.ndarray | None
    d_pre: np.ndarray | None
    d_inf: np.ndarray | None
    budget: float
    explore_frame: bool
    explore_idx: int
    explore_cost: float
    t0: float


@dataclasses.dataclass
class PendingFrame:
    """A planned-but-not-executed frame (emission half of the loop).

    Produced by :meth:`OmniSenseLoop.begin_frame`; holds everything
    :meth:`OmniSenseLoop.finish_frame` needs to ingest the batched
    inference results and complete the frame exactly like the inline
    path.
    """

    frame: np.ndarray | None
    srois: list[sroi.SRoI]
    plan: allocation.Plan | None
    planned_latency: float
    overhead_s: float
    explore_frame: bool
    explore_idx: int
    explore_cost: float
    requests: list[InferenceRequest]


class OmniSenseLoop:
    """Stateful per-stream analytics session."""

    def __init__(
        self,
        variants: Sequence[acc_mod.ModelProfile],
        latency_model: LatencyModel,
        backend: InferenceBackend,
        budget_s: float,
        f_deg: float = 60.0,
        gamma: float = 1.1,
        delta: int = 2,
        nms_threshold: float = 0.6,
        n_categories: int = acc_mod.N_CATEGORIES,
        explore_every: int = 6,
        explore_costs: list[float] | None = None,
        on_plan: Callable[[allocation.Plan, list[sroi.SRoI]], None] | None = None,
    ) -> None:
        self.variants = list(variants)
        self.latency_model = latency_model
        self.backend = backend
        self.budget_s = budget_s
        self.f = math.radians(f_deg)
        self.gamma = gamma
        self.delta = delta
        self.nms_threshold = nms_threshold
        self.n_categories = n_categories
        # periodic spherical-object discovery: every `explore_every`
        # frames the loop reserves the full-ERP pass cost from the
        # allocator's budget and spends it on exploration (the paper's
        # discovery mechanism, run on a cadence so moving cameras keep
        # finding regions the history has never seen).
        self.explore_every = explore_every
        # per-variant full-ERP pass cost; exploration picks the largest
        # model affordable within ~60% of the budget, so tight budgets
        # explore with cheap models instead of starving the SRoI plan.
        self.explore_costs = explore_costs or [0.0] * len(self.variants)
        self._frame_idx = 0
        self.on_plan = on_plan
        # detection history: most recent `delta` frames
        self._history: list[list[sroi.Detection]] = []
        self._discovery = discovery.DiscoveryState()

    # -- helpers ----------------------------------------------------------

    def _flat_history(self) -> list[sroi.Detection]:
        out: list[sroi.Detection] = []
        for frame_dets in self._history[-self.delta :]:
            out.extend(frame_dets)
        return out

    def _weighted_acc_matrix(self, srois: Sequence[sroi.SRoI]) -> np.ndarray:
        """(1 + M, R): row 0 = skip (zero accuracy)."""
        m, r = len(self.variants), len(srois)
        out = np.zeros((1 + m, r), dtype=np.float64)
        for j, s in enumerate(srois):
            for i, var in enumerate(self.variants):
                out[1 + i, j] = acc_mod.weighted_accuracy(var.gav, s.ccv, s.alpha)
        return out

    # -- main entry --------------------------------------------------------

    def frame_context(self, frame: np.ndarray) -> FrameContext:
        """First half of the emission: advance the frame/exploration
        state, predict SRoIs and build the allocator's input matrices —
        WITHOUT choosing a plan.  Callers that allocate per stream go
        through :meth:`begin_frame`; the pod server instead collects
        every stream's context and solves the coupled pod-level
        allocation before handing each plan to :meth:`emit_pending`."""
        t0 = time.perf_counter()
        self._frame_idx += 1
        explore_frame = (self.explore_every > 0
                         and self._frame_idx % self.explore_every == 0)
        affordable = [i for i, c in enumerate(self.explore_costs)
                      if c <= 0.6 * self.budget_s]
        explore_idx = max(affordable) if affordable else             int(np.argmin(self.explore_costs))
        explore_cost = self.explore_costs[explore_idx]
        budget = self.budget_s
        if explore_frame:
            budget = max(0.0, budget - explore_cost)
        srois = sroi.predict_srois(
            self._flat_history(),
            f=self.f,
            gamma=self.gamma,
            n_categories=self.n_categories,
        )
        acc = d_pre = d_inf = None
        if srois:
            acc = self._weighted_acc_matrix(srois)
            d_pre, d_inf = self.latency_model.delays(srois, self.variants)
        return FrameContext(
            frame=frame,
            srois=srois,
            acc=acc,
            d_pre=d_pre,
            d_inf=d_inf,
            budget=budget,
            explore_frame=explore_frame,
            explore_idx=explore_idx,
            explore_cost=explore_cost,
            t0=t0,
        )

    def emit_pending(self, ctx: FrameContext,
                     plan: allocation.Plan | None) -> PendingFrame:
        """Second half of the emission: turn a (possibly pod-coupled)
        plan for ``ctx`` into the frame's :class:`InferenceRequest`
        list.  ``plan.models`` must index ``ctx.srois`` column-wise
        exactly like a per-stream ``allocation.allocate`` result."""
        planned_latency = 0.0
        if plan is not None:
            planned_latency = plan.t_done
            if self.on_plan is not None:
                self.on_plan(plan, list(ctx.srois))

        requests: list[InferenceRequest] = []
        if plan is not None:
            for j, model_idx in enumerate(plan.models):
                if model_idx == 0:
                    continue  # skipped SRoI
                requests.append(InferenceRequest(
                    region=ctx.srois[j],
                    variant=self.variants[model_idx - 1],
                    slot=len(requests),
                    special=ctx.srois[j].special,
                    frame=ctx.frame,
                ))
        return PendingFrame(
            frame=ctx.frame,
            srois=ctx.srois,
            plan=plan,
            planned_latency=planned_latency,
            overhead_s=time.perf_counter() - ctx.t0,
            explore_frame=ctx.explore_frame,
            explore_idx=ctx.explore_idx,
            explore_cost=ctx.explore_cost,
            requests=requests,
        )

    def begin_frame(self, frame: np.ndarray) -> PendingFrame:
        """Emission half of the frame: predict SRoIs, allocate models
        and emit one :class:`InferenceRequest` per non-skipped SRoI —
        WITHOUT executing any inference.  The pod server parks the
        requests in per-variant queues and drains them into batched
        detector forwards; standalone use goes through
        :meth:`process_frame`, which executes the requests inline.
        (Composition of :meth:`frame_context` + per-stream
        ``allocation.allocate`` + :meth:`emit_pending`; the pod-level
        allocator replaces only the middle step.)"""
        ctx = self.frame_context(frame)
        plan = None
        if ctx.srois:
            plan = allocation.allocate(ctx.acc, ctx.d_pre, ctx.d_inf,
                                       ctx.budget)
        return self.emit_pending(ctx, plan)

    def finish_frame(self, pending: PendingFrame,
                     request_detections: Sequence[list[sroi.Detection]], *,
                     defer_nms: bool = False) -> FrameResult:
        """Ingestion half: take the per-request detection lists (in
        ``pending.requests`` slot order), run the discovery pass, and
        complete the frame exactly like the inline path.  ``defer_nms``
        has the same contract as :meth:`process_frame`."""
        assert len(request_detections) == len(pending.requests)
        detections: list[sroi.Detection] = []
        for req, dets in zip(pending.requests, request_detections):
            # special SRoIs keep only their largest detection
            if req.special and dets:
                dets = [max(dets, key=lambda d: d.noa())]
            detections.extend(dets)

        # ---- spherical object discovery ----
        planned_latency = pending.planned_latency
        self._discovery.observe(len(pending.srois))
        discovered = False
        if pending.explore_frame or self._discovery.should_discover(
                self.budget_s, planned_latency):
            detections.extend(self.backend.infer_erp(
                pending.frame, self.variants[pending.explore_idx]))
            discovered = True
            planned_latency = min(self.budget_s,
                                  planned_latency + pending.explore_cost)

        result = FrameResult(
            detections=detections,
            srois=pending.srois,
            plan=pending.plan,
            planned_latency=planned_latency,
            overhead_s=pending.overhead_s,
            discovered=discovered,
        )
        if defer_nms:
            return result

        # ---- post-processing: spherical NMS (single-row fast path of
        # the batched subsystem) ----
        t1 = time.perf_counter()
        self.finalize_detections(result, self.nms_keep(detections))
        result.overhead_s += time.perf_counter() - t1
        return result

    def process_frame(self, frame: np.ndarray, *,
                      defer_nms: bool = False) -> FrameResult:
        """Run one frame inline (the per-request execution path):
        emission, per-request backend inference in plan order, then
        ingestion.  With ``defer_nms=True`` the returned result holds
        the RAW (pre-NMS) detections and the history is NOT yet
        updated; the caller owns suppression and must hand the
        keep-mask back via :meth:`finalize_detections` before the next
        frame.  ``PodServer`` instead splits the frame into
        :meth:`begin_frame` / :meth:`finish_frame` so inference batches
        across streams and suppression batches across the tick."""
        pending = self.begin_frame(frame)
        # ---- execute the plan (inference is NOT overhead) ----
        request_detections = [
            self.backend.infer_sroi(frame, req.region, req.variant)
            for req in pending.requests]
        return self.finish_frame(pending, request_detections,
                                 defer_nms=defer_nms)

    def nms_keep(self, detections: list[sroi.Detection]) -> np.ndarray | None:
        """Keep-mask for one frame's detections at this stream's
        threshold — the single-row fast path of ``sph_nms_batch``
        (also used by ``PodServer`` when streams disagree on the
        threshold and cannot share one padded batch)."""
        if not detections:
            return None
        boxes = np.stack([d.box for d in detections])
        scores = np.array([d.score for d in detections])
        return sph_nms_batch(
            boxes[None], scores[None], iou_threshold=self.nms_threshold)[0]

    def finalize_detections(self, result: FrameResult,
                            keep: np.ndarray | None) -> FrameResult:
        """Apply an externally computed NMS keep-mask and commit the
        surviving detections to the SRoI-prediction history.

        ``keep`` is a (n_detections,) bool mask (``None`` means "no
        detections this frame").  Must be called exactly once per
        ``process_frame(..., defer_nms=True)`` result, in frame order,
        so the detection feedback the predictor sees is identical to
        the inline path."""
        if keep is not None:
            result.detections = [
                d for d, k in zip(result.detections, keep) if k]
        self._history.append(result.detections)
        if len(self._history) > self.delta:
            self._history = self._history[-self.delta :]
        return result

    def seed_history(self, detections: list[sroi.Detection]) -> None:
        """Bootstrap the history (e.g. from an initial full-ERP pass)."""
        self._history.append(list(detections))

"""Content-specific model performance estimation (paper section IV-B).

Defines the *general accuracy vector* (gav, eq. 1) per model and the
machinery that dots it with each SRoI's *content characteristics
vector* (ccv, eq. 2), weighted by the SRoI object mass alpha, to give
the weighted accuracy A_{i,j} = alpha_j * (A_i . P_j) that drives the
model-allocation DP.

The gav for a real deployment is profiled offline on a labelled dataset
(the paper uses COCO's 80 categories with NOA size-level thresholds at
COCO's 33.33/66.66 NOA percentiles: 0.0044 and 0.0354).  This container
has no COCO, so :func:`synthetic_gav_table` constructs a ladder with
the same *ordering* as paper Table II (tiny-416 < csp-512 < csp-640 <
p5-896 < p6-1280, with the gap widest for small objects) — see
DESIGN.md section 7 (honesty ledger).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# COCO NOA size-level thresholds from the paper (section IV-B).
SMALL_NOA = 0.0044
MEDIUM_NOA = 0.0354
N_CATEGORIES = 80


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Offline profile of one detector variant (paper Table II row)."""

    name: str
    index: int  # 1-based paper index; 0 is reserved for "skip"
    input_size: int  # square input resolution in pixels
    location: str  # "device" | "edge"
    gav: np.ndarray  # (3 * N_CATEGORIES,)
    # offline-profiled latencies (seconds); see serving/profiles.py
    infer_s: float
    model_bytes: int


def estimated_accuracy(gav: np.ndarray, ccv: np.ndarray) -> float:
    """A_i . P_j — the expected detection accuracy of a model on an SRoI."""
    return float(np.dot(gav, ccv))


def weighted_accuracy(gav: np.ndarray, ccv: np.ndarray, alpha: float) -> float:
    """A_{i,j} = alpha_j * A_i . P_j (section IV-C)."""
    return alpha * estimated_accuracy(gav, ccv)


def synthetic_gav_table(
    n_models: int = 5,
    n_categories: int = N_CATEGORIES,
    seed: int = 0,
) -> list[np.ndarray]:
    """Construct a plausible gav ladder for ``n_models`` variants.

    Properties enforced (all consistent with the paper's Table II and
    the scaled-YOLOv4 COCO results it cites):
      * accuracy increases monotonically with model index for every
        (size, category) entry;
      * small objects benefit the most from larger input sizes;
      * per-category variation exists (training-set bias).
    """
    rng = np.random.default_rng(seed)
    cat_bias = rng.uniform(0.7, 1.0, size=n_categories)
    # base accuracies per size level for the weakest model
    base = np.array([0.08, 0.30, 0.45])  # small, medium, large
    # headroom gained per rung, biggest for small objects
    gain = np.array([0.14, 0.08, 0.05])
    tables = []
    for i in range(n_models):
        levels = np.clip(base + gain * i, 0.0, 0.95)
        gav = np.concatenate([levels[k] * cat_bias for k in range(3)])
        tables.append(gav)
    return tables


def size_level(noa: float) -> int:
    """0 = small, 1 = medium, 2 = large (paper thresholds)."""
    if noa <= SMALL_NOA:
        return 0
    if noa <= MEDIUM_NOA:
        return 1
    return 2

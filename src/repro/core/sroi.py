"""Algorithm 1 — lightweight SRoI prediction.

Host-side (NumPy) implementation: the paper runs this on the mobile
CPU and reports <2.5 % overhead; it is deliberately not jitted.  The
algorithm merges the detections of the most recent ``delta`` frames
into a set of ``f x f``-FoV spherical regions of interest, creating
*special* SRoIs (scaled by ``gamma``) for objects too large to fit.

Inputs and outputs use plain NumPy; the ccv/alpha fields feed the
content-specific accuracy estimation of ``repro.core.accuracy``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

TWO_PI = 2.0 * math.pi


@dataclasses.dataclass
class Detection:
    """One detected object on the sphere."""

    box: np.ndarray  # (4,) = (theta, phi, dtheta, dphi), radians
    category: int
    score: float = 1.0

    @property
    def center(self) -> tuple[float, float]:
        return float(self.box[0]), float(self.box[1])

    @property
    def fov(self) -> tuple[float, float]:
        return float(self.box[2]), float(self.box[3])

    def noa(self) -> float:
        """Normalised object area (fraction of the sphere)."""
        return float(2.0 * self.box[2] * math.sin(self.box[3] / 2.0) / (4.0 * math.pi))


@dataclasses.dataclass
class SRoI:
    """A spherical region of interest (theta, phi, dtheta, dphi)."""

    center: tuple[float, float]
    fov: tuple[float, float]
    objects: list[Detection] = dataclasses.field(default_factory=list)
    ccv: np.ndarray | None = None  # (3 * n_categories,)
    alpha: float = 0.0
    special: bool = False

    def as_tuple(self) -> tuple[float, float, float, float]:
        return (self.center[0], self.center[1], self.fov[0], self.fov[1])


def _wrap(a: float) -> float:
    """Wrap angle to [-pi, pi)."""
    return (a + math.pi) % TWO_PI - math.pi


def _merged_extents(objects: list[Detection]) -> tuple[float, float, float, float]:
    """Merged (hFoV, vFoV, center_theta, center_phi) covering all objects.

    Longitudes are unwrapped around the first object's centre so the
    ERP seam does not split a cluster.  Latitude extents are plain
    intervals.  This mirrors line 7 of Algorithm 1: the merged FoV is
    the smallest lat/long-aligned spherical rectangle enclosing every
    member object's own extent.
    """
    ref = objects[0].box[0]
    lo_t, hi_t = math.inf, -math.inf
    lo_p, hi_p = math.inf, -math.inf
    for o in objects:
        t = ref + _wrap(float(o.box[0]) - ref)
        half_t, half_p = float(o.box[2]) / 2.0, float(o.box[3]) / 2.0
        lo_t = min(lo_t, t - half_t)
        hi_t = max(hi_t, t + half_t)
        lo_p = min(lo_p, float(o.box[1]) - half_p)
        hi_p = max(hi_p, float(o.box[1]) + half_p)
    h_fov = hi_t - lo_t
    v_fov = hi_p - lo_p
    return h_fov, v_fov, _wrap((lo_t + hi_t) / 2.0), (lo_p + hi_p) / 2.0


def region_solid_angle(fov_h: float, fov_v: float) -> float:
    """Solid angle (sr) of an (fov_h x fov_v) spherical rectangle."""
    return 2.0 * fov_h * math.sin(fov_v / 2.0)


def image_noa(obj_area_sr: float, ref_sr: float) -> float:
    """NOA of an object *in the image it is analysed in*.

    The gav is indexed by COCO image NOA (fraction of the picture).
    When a PI covers only an (f x f) region, an object's share of that
    picture is its solid angle over the REGION's solid angle — this is
    the effective-resolution gain that makes SRoI pruning improve
    accuracy (paper section III-B: downsampled whole frames make tiny
    objects undetectable).
    """
    return float(min(1.0, obj_area_sr / max(ref_sr, 1e-9)))


def size_level_in(o: Detection, ref_sr: float,
                  small_thresh: float, medium_thresh: float) -> int:
    area = 2.0 * float(o.box[2]) * math.sin(float(o.box[3]) / 2.0)
    noa = image_noa(area, ref_sr)
    if noa <= small_thresh:
        return 0
    if noa <= medium_thresh:
        return 1
    return 2


def compute_ccv(
    objects: list[Detection],
    n_categories: int,
    small_thresh: float,
    medium_thresh: float,
    ref_sr: float = 4.0 * math.pi,
) -> np.ndarray:
    """Content characteristics vector P_j (eq. 2): occurrence
    probabilities per (size level x category) among the SRoI's objects.
    Layout matches the gav (eq. 1): [s1..sn, m1..mn, l1..ln].
    Size levels are measured relative to ``ref_sr`` (the solid angle of
    the image the objects will be analysed in — see ``image_noa``).
    """
    ccv = np.zeros(3 * n_categories, dtype=np.float64)
    if not objects:
        return ccv
    for o in objects:
        level = size_level_in(o, ref_sr, small_thresh, medium_thresh)
        ccv[level * n_categories + (o.category % n_categories)] += 1.0
    ccv /= len(objects)
    return ccv


def predict_srois(
    history: list[Detection],
    f: float = math.radians(60.0),
    gamma: float = 1.1,
    n_categories: int = 80,
    small_thresh: float = 0.0044,
    medium_thresh: float = 0.0354,
) -> list[SRoI]:
    """Algorithm 1: predict SRoIs from historical detections.

    ``history`` is O — the detected objects of the most recent ``delta``
    frames (the caller maintains the window).  Returns R = S' | S with
    per-SRoI ccv and alpha populated.
    """
    regular: list[SRoI] = []
    special: list[SRoI] = []
    n_total = len(history)
    if n_total == 0:
        return []

    for o in history:
        o_h, o_v = o.fov
        if o_h <= f and o_v <= f:
            merged = False
            for s in regular:
                h_fov, v_fov, _, _ = _merged_extents(s.objects + [o])
                if h_fov < f and v_fov < f:
                    s.objects.append(o)
                    s.fov = (h_fov, v_fov)
                    merged = True
                    break
            if not merged:
                regular.append(
                    SRoI(center=o.center, fov=o.fov, objects=[o], special=False)
                )
        else:
            # special SRoI: area scaled by gamma around the large object
            scale = math.sqrt(gamma)
            s = SRoI(
                center=o.center,
                fov=(min(o_h * scale, TWO_PI), min(o_v * scale, math.pi)),
                objects=[o],
                special=True,
            )
            s.ccv = compute_ccv([o], n_categories, small_thresh, medium_thresh,
                                ref_sr=region_solid_angle(*s.fov))
            s.alpha = 1.0 / n_total
            special.append(s)

    for s in regular:
        h_fov, v_fov, ct, cp = _merged_extents(s.objects)
        s.center = (ct, cp)
        s.ccv = compute_ccv(s.objects, n_categories, small_thresh,
                            medium_thresh, ref_sr=region_solid_angle(f, f))
        s.alpha = len(s.objects) / n_total
        s.fov = (f, f)
    return special + regular

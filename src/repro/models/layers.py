"""Shared pure-JAX layers (param-pytree style, no framework deps).

Every layer is a pair of functions: ``init_*(rng, ...) -> params`` and
an apply function taking ``(params, x, ...)``.  Params are plain nested
dicts of jnp arrays so they shard transparently through pjit; the
sharding rules in ``repro.distributed.sharding`` match on dict paths.

dtype policy: params are stored in ``param_dtype`` and matmuls run in
``compute_dtype`` with f32 accumulation (``preferred_element_type``),
which is the MXU-native configuration.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def cast_in(self, x: Array) -> Array:
        return x.astype(self.compute_dtype)


F32 = DtypePolicy()
BF16 = DtypePolicy(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def _uniform_init(rng, shape, scale, dtype):
    return jax.random.uniform(rng, shape, jnp.float32, -scale, scale).astype(dtype)


# --------------------------------------------------------------------------
# Dense / embedding
# --------------------------------------------------------------------------


def init_dense(rng, d_in: int, d_out: int, *, bias: bool = True,
               dtype=jnp.float32, scale: float | None = None) -> Params:
    scale = scale if scale is not None else math.sqrt(1.0 / d_in)
    p = {"w": _uniform_init(rng, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: Array, policy: DtypePolicy = F32) -> Array:
    y = jax.lax.dot_general(
        policy.cast_in(x),
        p["w"].astype(policy.compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(policy.compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(policy.compute_dtype)
    return y


def init_embedding(rng, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"emb": jax.random.normal(rng, (vocab, d), jnp.float32).astype(dtype) * 0.02}


def embedding(p: Params, ids: Array, policy: DtypePolicy = F32) -> Array:
    return p["emb"].astype(policy.compute_dtype)[ids]


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def init_groupnorm(d: int, groups: int = 32, dtype=jnp.float32) -> Params:
    del groups  # group count is a call-time choice (static under jit)
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def num_groups(c: int, preferred: int = 32) -> int:
    """Largest divisor of ``c`` that is <= preferred."""
    g = min(preferred, c)
    while c % g:
        g -= 1
    return g


def groupnorm(p: Params, x: Array, eps: float = 1e-5,
              groups: int | None = None) -> Array:
    """GroupNorm over the channel-last axis of (..., H, W, C)."""
    dt = x.dtype
    c = x.shape[-1]
    g = groups if groups is not None else num_groups(c)
    x32 = x.astype(jnp.float32)
    xg = x32.reshape(x.shape[:-1] + (g, c // g))
    axes = tuple(range(1, x.ndim - 1)) + (x.ndim,)  # spatial + intra-group
    mu = jnp.mean(xg, axis=axes, keepdims=True)
    var = jnp.var(xg, axis=axes, keepdims=True)
    y = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(x.shape)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def init_batchnorm(d: int, dtype=jnp.float32) -> Params:
    return {
        "scale": jnp.ones((d,), dtype),
        "bias": jnp.zeros((d,), dtype),
        "mean": jnp.zeros((d,), jnp.float32),
        "var": jnp.ones((d,), jnp.float32),
    }


def batchnorm(p: Params, x: Array, *, train: bool, eps: float = 1e-5,
              momentum: float = 0.9) -> tuple[Array, Params]:
    """BatchNorm over (N, H, W, C); returns (y, updated running stats)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if train:
        axes = tuple(range(x.ndim - 1))
        mu = jnp.mean(x32, axis=axes)
        var = jnp.var(x32, axis=axes)
        new_stats = {
            **p,
            "mean": momentum * p["mean"] + (1 - momentum) * mu,
            "var": momentum * p["var"] + (1 - momentum) * var,
        }
    else:
        mu, var = p["mean"], p["var"]
        new_stats = p
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt), new_stats


# --------------------------------------------------------------------------
# Convolutions (NHWC)
# --------------------------------------------------------------------------


def init_conv(rng, kh: int, kw: int, c_in: int, c_out: int, *,
              bias: bool = True, dtype=jnp.float32, groups: int = 1) -> Params:
    fan_in = kh * kw * c_in // groups
    scale = math.sqrt(1.0 / fan_in)
    p = {"w": _uniform_init(rng, (kh, kw, c_in // groups, c_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d(p: Params, x: Array, *, stride: int | tuple[int, int] = 1,
           padding: str | Sequence[tuple[int, int]] = "SAME",
           groups: int = 1, policy: DtypePolicy = F32) -> Array:
    if isinstance(stride, int):
        stride = (stride, stride)
    # no preferred_element_type: the conv transpose (grad-wrt-kernel) rule
    # requires matching dtypes; MXU convs accumulate in f32 regardless.
    y = jax.lax.conv_general_dilated(
        policy.cast_in(x),
        p["w"].astype(policy.compute_dtype),
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if "b" in p:
        y = y + p["b"].astype(policy.compute_dtype)
    return y


def max_pool(x: Array, window: int, stride: int, padding: str = "SAME") -> Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1), padding,
    )


def avg_pool_global(x: Array) -> Array:
    return jnp.mean(x, axis=(1, 2))


def upsample_nearest(x: Array, factor: int = 2) -> Array:
    n, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (n, h, factor, w, factor, c))
    return x.reshape(n, h * factor, w * factor, c)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (B, S, H, D) with D even; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Activations / misc
# --------------------------------------------------------------------------


def gelu(x: Array) -> Array:
    return jax.nn.gelu(x, approximate=True)


def silu(x: Array) -> Array:
    return jax.nn.silu(x)


def mish(x: Array) -> Array:
    return x * jnp.tanh(jax.nn.softplus(x))


def split_rngs(rng, n: int):
    return list(jax.random.split(rng, n))


def timestep_embedding(t: Array, dim: int, max_period: float = 10000.0) -> Array:
    """Sinusoidal timestep embedding (diffusion)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)

"""Diffusion backbones: MMDiT (flux-dev) and UNet (SDXL).

Both operate on VAE latents (the VAE itself is out of scope for every
assigned shape — latent_res is given directly).  Text conditioning is a
stub per the assignment: ``input_specs()`` supplies precomputed context
token embeddings and pooled vectors.

flux-dev (MMDiT, rectified flow):
  * 2x2 patchify of the (B, 128, 128, 16) latent -> 4096 image tokens,
    d_model 3072, 24 heads;
  * 19 *double* blocks: separate img/txt streams, AdaLN-Zero modulation
    from (timestep, guidance, pooled) embedding, **joint** attention
    over the concatenated token set, per-stream MLPs;
  * 38 *single* blocks: fused stream, DiT-style parallel attn+MLP;
  * axial 2D sin-cos positions on image tokens (simplification of
    flux's 2D RoPE — same asymptotics, documented in DESIGN.md);
  * v-prediction / rectified-flow loss and Euler sampling step.

unet-sdxl (epsilon-prediction, DDIM sampling):
  * channels 320 x (1, 2, 4), 2 res-blocks per level,
    transformer_depth (1, 2, 10) with level 0 attention-free
    (DownBlock2D semantics, as in the reference SDXL config),
    cross-attention to 2048-d context, GroupNorm(32), SiLU;
  * time + pooled "add" embeddings fused into the res-block shift/scale.

Repeated homogeneous blocks (flux double/single stacks, the depth-10
SDXL transformer) are scanned over stacked params.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, constrain
from repro.models import layers as L

Array = jax.Array
Params = dict


# ==========================================================================
# shared helpers
# ==========================================================================


def _stack(plist):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *plist)


def _mha(q, k, v, n_heads, policy):
    """Full attention, (B, Sq, D) x (B, Skv, D)."""
    b, sq, d = q.shape
    dh = d // n_heads
    qh = q.reshape(b, sq, n_heads, dh)
    kh = k.reshape(b, k.shape[1], n_heads, dh)
    vh = v.reshape(b, v.shape[1], n_heads, dh)
    att = jnp.einsum("bqhd,bkhd->bhqk", qh.astype(jnp.float32),
                     kh.astype(jnp.float32)) * (dh ** -0.5)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, vh.astype(jnp.float32))
    return out.astype(policy.compute_dtype).reshape(b, sq, d)


def axial_2d_sincos(h: int, w: int, d: int) -> Array:
    """(h*w, d) fixed 2D sin-cos position embedding."""
    def one_axis(n, dim):
        pos = jnp.arange(n, dtype=jnp.float32)[:, None]
        freq = jnp.exp(-math.log(10000.0) * jnp.arange(dim // 2, dtype=jnp.float32)
                       / (dim // 2))
        ang = pos * freq[None]
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (n, dim)

    dh = d // 2
    em_h = one_axis(h, dh)  # (h, dh)
    em_w = one_axis(w, d - dh)
    grid = jnp.concatenate(
        [jnp.repeat(em_h, w, axis=0), jnp.tile(em_w, (h, 1))], axis=-1)
    return grid  # (h*w, d)


# ==========================================================================
# MMDiT / flux-dev
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class MMDiTConfig:
    name: str
    latent_res: int
    latent_ch: int = 16
    patch: int = 2
    d_model: int = 3072
    n_heads: int = 24
    n_double_blocks: int = 19
    n_single_blocks: int = 38
    d_ctx: int = 4096
    n_ctx_tokens: int = 512
    d_pooled: int = 768
    mlp_ratio: int = 4
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def n_params(self) -> int:
        d = self.d_model
        f = d * self.mlp_ratio
        dbl = 2 * (4 * d * d + 2 * d * f + 6 * d * d)  # qkv+o, mlp, 6 mods / stream
        sgl = 4 * d * d + 2 * d * f + 3 * d * d
        patch_d = self.patch * self.patch * self.latent_ch
        return (self.n_double_blocks * dbl + self.n_single_blocks * sgl
                + patch_d * d * 2 + self.d_ctx * d + self.d_pooled * d + 256 * d)


def _adaln_init(rng, d: int, n_mods: int, dt) -> Params:
    return {"w": jnp.zeros((d, n_mods * d), dt), "b": jnp.zeros((n_mods * d,), dt)}


def mmdit_init(rng, cfg: MMDiTConfig) -> Params:
    dt = cfg.param_dtype
    d = cfg.d_model
    f = d * cfg.mlp_ratio
    rngs = jax.random.split(rng, 16)
    s = (1.0 / d) ** 0.5
    patch_d = cfg.patch * cfg.patch * cfg.latent_ch

    def su(key, shape, scale):
        return jax.random.uniform(key, shape, jnp.float32, -scale, scale).astype(dt)

    def dbl_block(key):
        r = jax.random.split(key, 10)
        def stream(off):
            return {
                "mod": _adaln_init(r[off], d, 6, dt),
                "wqkv": su(r[off + 1], (d, 3 * d), s),
                "wo": su(r[off + 2], (d, d), s),
                "w1": su(r[off + 3], (d, f), s),
                "w2": su(r[off + 4], (f, d), (1.0 / f) ** 0.5),
            }
        return {"img": stream(0), "txt": stream(5)}

    def sgl_block(key):
        r = jax.random.split(key, 5)
        return {
            "mod": _adaln_init(r[0], d, 3, dt),
            "wqkv": su(r[1], (d, 3 * d), s),
            "w1": su(r[2], (d, f), s),
            "wo2": su(r[3], (d + f, d), (1.0 / (d + f)) ** 0.5),
        }

    dbl_keys = jax.random.split(rngs[0], cfg.n_double_blocks)
    sgl_keys = jax.random.split(rngs[1], cfg.n_single_blocks)
    return {
        "img_in": L.init_dense(rngs[2], patch_d, d, dtype=dt),
        "txt_in": L.init_dense(rngs[3], cfg.d_ctx, d, dtype=dt),
        "time_mlp1": L.init_dense(rngs[4], 256, d, dtype=dt),
        "time_mlp2": L.init_dense(rngs[5], d, d, dtype=dt),
        "pooled_in": L.init_dense(rngs[6], cfg.d_pooled, d, dtype=dt),
        "guidance_mlp": L.init_dense(rngs[7], 256, d, dtype=dt),
        "double": _stack([dbl_block(k) for k in dbl_keys]),
        "single": _stack([sgl_block(k) for k in sgl_keys]),
        "final_mod": _adaln_init(rngs[8], d, 2, dt),
        "img_out": L.init_dense(rngs[9], d, patch_d, dtype=dt),
    }


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def mmdit_apply(params: Params, latents: Array, t: Array, ctx: Array,
                pooled: Array, guidance: Array, cfg: MMDiTConfig) -> Array:
    """Predict the rectified-flow velocity field.

    latents: (B, R, R, C); t/guidance: (B,); ctx: (B, T, d_ctx);
    pooled: (B, d_pooled).  Returns (B, R, R, C).
    """
    pol = cfg.policy
    b, r, _, c = latents.shape
    p = cfg.patch
    hp = r // p
    d = cfg.d_model

    # patchify
    x = latents.reshape(b, hp, p, hp, p, c).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, hp * hp, p * p * c)
    img = L.dense(params["img_in"], x, pol)
    img = constrain(img + axial_2d_sincos(hp, hp, d)[None].astype(pol.compute_dtype),
                    BATCH, None, None)
    txt = constrain(L.dense(params["txt_in"], ctx, pol), BATCH, None, None)

    # modulation vector
    temb = L.timestep_embedding(t * 1000.0, 256)
    vec = L.dense(params["time_mlp2"],
                  L.silu(L.dense(params["time_mlp1"], temb.astype(pol.compute_dtype), pol)), pol)
    vec = vec + L.dense(params["pooled_in"], pooled.astype(pol.compute_dtype), pol)
    gemb = L.timestep_embedding(guidance * 1000.0, 256)
    vec = vec + L.dense(params["guidance_mlp"], gemb.astype(pol.compute_dtype), pol)
    vec = L.silu(vec)

    n_img, n_txt = img.shape[1], txt.shape[1]

    def double_block(carry, lp):
        img, txt = carry

        def stream_qkv(sp, x):
            mods = L.dense(sp["mod"], vec, pol).reshape(b, 6, d)
            h = _modulate(L.rmsnorm({"scale": jnp.ones((d,), x.dtype)}, x),
                          mods[:, 0], mods[:, 1])
            qkv = L.dense({"w": sp["wqkv"]}, h, pol)
            return qkv, mods

        qkv_i, mod_i = stream_qkv(lp["img"], img)
        qkv_t, mod_t = stream_qkv(lp["txt"], txt)
        qkv = jnp.concatenate([qkv_t, qkv_i], axis=1)  # txt first (flux order)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        att = _mha(q, k, v, cfg.n_heads, pol)
        att_t, att_i = att[:, :n_txt], att[:, n_txt:]

        def stream_out(sp, x, att, mods):
            x = x + mods[:, 2][:, None] * L.dense({"w": sp["wo"]}, att, pol)
            h = _modulate(L.rmsnorm({"scale": jnp.ones((d,), x.dtype)}, x),
                          mods[:, 3], mods[:, 4])
            h = constrain(L.gelu(L.dense({"w": sp["w1"]}, h, pol)),
                          BATCH, None, "model")
            h = L.dense({"w": sp["w2"]}, h, pol)
            return constrain(x + mods[:, 5][:, None] * h, BATCH, None, None)

        img = stream_out(lp["img"], img, att_i, mod_i)
        txt = stream_out(lp["txt"], txt, att_t, mod_t)
        return (img, txt), None

    def single_block(x, lp):
        mods = L.dense(lp["mod"], vec, pol).reshape(b, 3, d)
        h = _modulate(L.rmsnorm({"scale": jnp.ones((d,), x.dtype)}, x),
                      mods[:, 0], mods[:, 1])
        qkv = L.dense({"w": lp["wqkv"]}, h, pol)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        att = _mha(q, k, v, cfg.n_heads, pol)
        mlp_h = constrain(L.gelu(L.dense({"w": lp["w1"]}, h, pol)),
                          BATCH, None, "model")
        fused = jnp.concatenate([att, mlp_h], axis=-1)
        out = x + mods[:, 2][:, None] * L.dense({"w": lp["wo2"]}, fused, pol)
        return constrain(out, BATCH, None, None), None

    dbl = jax.checkpoint(double_block, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else double_block
    sgl = jax.checkpoint(single_block, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else single_block

    (img, txt), _ = jax.lax.scan(dbl, (img, txt), params["double"])
    fused = jnp.concatenate([txt, img], axis=1)
    fused, _ = jax.lax.scan(sgl, fused, params["single"])
    img = fused[:, n_txt:]

    mods = L.dense(params["final_mod"], vec, pol).reshape(b, 2, d)
    img = _modulate(L.rmsnorm({"scale": jnp.ones((d,), img.dtype)}, img),
                    mods[:, 0], mods[:, 1])
    out = L.dense(params["img_out"], img, pol)
    out = out.reshape(b, hp, hp, p, p, c).transpose(0, 1, 3, 2, 4, 5)
    return out.reshape(b, r, r, c).astype(jnp.float32)


def flux_rf_loss(params: Params, batch: dict, cfg: MMDiTConfig, rng) -> Array:
    """Rectified-flow training loss: x_t = (1-t) x0 + t eps, v* = eps - x0."""
    x0 = batch["latents"]
    r1, r2 = jax.random.split(rng)
    b = x0.shape[0]
    t = jax.random.uniform(r1, (b,))
    eps = jax.random.normal(r2, x0.shape, x0.dtype)
    xt = (1.0 - t[:, None, None, None]) * x0 + t[:, None, None, None] * eps
    v = mmdit_apply(params, xt, t, batch["ctx"], batch["pooled"],
                    batch.get("guidance", jnp.zeros((b,))), cfg)
    return jnp.mean((v - (eps - x0).astype(jnp.float32)) ** 2)


def flux_euler_step(params: Params, xt: Array, t: Array, dt: Array, ctx: Array,
                    pooled: Array, guidance: Array, cfg: MMDiTConfig) -> Array:
    """One Euler step of the rectified-flow ODE (a ``steps``-step sampler
    calls this ``steps`` times)."""
    v = mmdit_apply(params, xt, t, ctx, pooled, guidance, cfg)
    return xt - dt[:, None, None, None] * v.astype(xt.dtype)


# ==========================================================================
# UNet / SDXL
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    latent_res: int
    latent_ch: int = 4
    ch: int = 320
    ch_mult: Sequence[int] = (1, 2, 4)
    n_res_blocks: int = 2
    transformer_depth: Sequence[int] = (1, 2, 10)
    ctx_dim: int = 2048
    n_ctx_tokens: int = 77
    d_add: int = 2816  # pooled text (1280) + 6 x 256 size conds
    head_dim: int = 64
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def time_dim(self) -> int:
        return self.ch * 4

    @property
    def n_params(self) -> int:
        # close-form count is messy; computed from the real tree at init.
        return -1


def _resblock_init(rng, c_in, c_out, time_dim, dt):
    r = jax.random.split(rng, 4)
    p = {
        "gn1": L.init_groupnorm(c_in, dtype=dt),
        "conv1": L.init_conv(r[0], 3, 3, c_in, c_out, dtype=dt),
        "emb": L.init_dense(r[1], time_dim, 2 * c_out, dtype=dt),
        "gn2": L.init_groupnorm(c_out, dtype=dt),
        "conv2": L.init_conv(r[2], 3, 3, c_out, c_out, dtype=dt),
    }
    if c_in != c_out:
        p["skip"] = L.init_conv(r[3], 1, 1, c_in, c_out, dtype=dt)
    return p


def _resblock_apply(p, x, emb, pol):
    h = L.conv2d(p["conv1"], L.silu(L.groupnorm(p["gn1"], x)), policy=pol)
    scale_shift = L.dense(p["emb"], L.silu(emb), pol)[:, None, None, :]
    scale, shift = jnp.split(scale_shift, 2, axis=-1)
    h = L.groupnorm(p["gn2"], h) * (1 + scale) + shift
    h = L.conv2d(p["conv2"], L.silu(h), policy=pol)
    skip = L.conv2d(p["skip"], x, policy=pol) if "skip" in p else x
    return constrain(skip + h, BATCH, None, None, "model")


def _xformer_block_init(rng, d, ctx_dim, dt):
    r = jax.random.split(rng, 8)
    s = (1.0 / d) ** 0.5
    return {
        "ln1": L.init_layernorm(d, dt),
        "wq1": {"w": jax.random.uniform(r[0], (d, d), jnp.float32, -s, s).astype(dt)},
        "wkv1": {"w": jax.random.uniform(r[1], (d, 2 * d), jnp.float32, -s, s).astype(dt)},
        "wo1": {"w": jax.random.uniform(r[2], (d, d), jnp.float32, -s, s).astype(dt)},
        "ln2": L.init_layernorm(d, dt),
        "wq2": {"w": jax.random.uniform(r[3], (d, d), jnp.float32, -s, s).astype(dt)},
        "wkv2": {"w": jax.random.uniform(r[4], (ctx_dim, 2 * d), jnp.float32,
                                         -(1.0 / ctx_dim) ** 0.5,
                                         (1.0 / ctx_dim) ** 0.5).astype(dt)},
        "wo2": {"w": jax.random.uniform(r[5], (d, d), jnp.float32, -s, s).astype(dt)},
        "ln3": L.init_layernorm(d, dt),
        "ff1": L.init_dense(r[6], d, 8 * d, dtype=dt),  # GEGLU: 2 x 4d
        "ff2": L.init_dense(r[7], 4 * d, d, dtype=dt),
    }


def _xformer_block_apply(p, x, ctx, n_heads, pol):
    h = L.layernorm(p["ln1"], x)
    q = L.dense(p["wq1"], h, pol)
    k, v = jnp.split(L.dense(p["wkv1"], h, pol), 2, axis=-1)
    x = x + L.dense(p["wo1"], _mha(q, k, v, n_heads, pol), pol)
    h = L.layernorm(p["ln2"], x)
    q = L.dense(p["wq2"], h, pol)
    k, v = jnp.split(L.dense(p["wkv2"], ctx, pol), 2, axis=-1)
    x = x + L.dense(p["wo2"], _mha(q, k, v, n_heads, pol), pol)
    h = L.layernorm(p["ln3"], x)
    a, g = jnp.split(L.dense(p["ff1"], h, pol), 2, axis=-1)
    return x + L.dense(p["ff2"], a * L.gelu(g), pol)


def _spatial_xformer_init(rng, c, ctx_dim, depth, dt):
    r = jax.random.split(rng, depth + 2)
    return {
        "gn": L.init_groupnorm(c, dtype=dt),
        "proj_in": L.init_dense(r[0], c, c, dtype=dt),
        "blocks": _stack([_xformer_block_init(r[1 + i], c, ctx_dim, dt)
                          for i in range(depth)]),
        "proj_out": L.init_dense(r[depth + 1], c, c, dtype=dt),
    }


def _spatial_xformer_apply(p, x, ctx, cfg, pol):
    b, h, w, c = x.shape
    n_heads = c // cfg.head_dim
    res = x
    y = L.groupnorm(p["gn"], x).reshape(b, h * w, c)
    y = L.dense(p["proj_in"], y, pol)

    def body(y, bp):
        return _xformer_block_apply(bp, y, ctx, n_heads, pol), None

    body_ = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else body
    y, _ = jax.lax.scan(body_, y, p["blocks"])
    y = L.dense(p["proj_out"], y, pol)
    return res + y.reshape(b, h, w, c)


def unet_init(rng, cfg: UNetConfig) -> Params:
    dt = cfg.param_dtype
    td = cfg.time_dim
    rngs = iter(jax.random.split(rng, 128))
    nxt = lambda: next(rngs)

    chans = [cfg.ch * m for m in cfg.ch_mult]
    p: Params = {
        "conv_in": L.init_conv(nxt(), 3, 3, cfg.latent_ch, cfg.ch, dtype=dt),
        "time1": L.init_dense(nxt(), cfg.ch, td, dtype=dt),
        "time2": L.init_dense(nxt(), td, td, dtype=dt),
        "add1": L.init_dense(nxt(), cfg.d_add, td, dtype=dt),
        "add2": L.init_dense(nxt(), td, td, dtype=dt),
        "down": [], "up": [],
    }
    # --- down path ---
    c_prev = cfg.ch
    skips = [cfg.ch]
    for li, c in enumerate(chans):
        level = {"res": [], "attn": [], "down": None}
        for _ in range(cfg.n_res_blocks):
            level["res"].append(_resblock_init(nxt(), c_prev, c, td, dt))
            level["attn"].append(
                _spatial_xformer_init(nxt(), c, cfg.ctx_dim,
                                      cfg.transformer_depth[li], dt)
                if li > 0 else None)
            c_prev = c
            skips.append(c)
        if li < len(chans) - 1:
            level["down"] = L.init_conv(nxt(), 3, 3, c, c, dtype=dt)
            skips.append(c)
        p["down"].append(level)
    # --- mid ---
    p["mid"] = {
        "res1": _resblock_init(nxt(), c_prev, c_prev, td, dt),
        "attn": _spatial_xformer_init(nxt(), c_prev, cfg.ctx_dim,
                                      cfg.transformer_depth[-1], dt),
        "res2": _resblock_init(nxt(), c_prev, c_prev, td, dt),
    }
    # --- up path ---
    for li in reversed(range(len(chans))):
        c = chans[li]
        level = {"res": [], "attn": [], "up": None}
        for _ in range(cfg.n_res_blocks + 1):
            c_skip = skips.pop()
            level["res"].append(_resblock_init(nxt(), c_prev + c_skip, c, td, dt))
            level["attn"].append(
                _spatial_xformer_init(nxt(), c, cfg.ctx_dim,
                                      cfg.transformer_depth[li], dt)
                if li > 0 else None)
            c_prev = c
        if li > 0:
            level["up"] = L.init_conv(nxt(), 3, 3, c, c, dtype=dt)
        p["up"].append(level)
    p["gn_out"] = L.init_groupnorm(cfg.ch, dtype=dt)
    p["conv_out"] = L.init_conv(nxt(), 3, 3, cfg.ch, cfg.latent_ch, dtype=dt)
    return p


def unet_apply(params: Params, latents: Array, t: Array, ctx: Array,
               add_emb: Array, cfg: UNetConfig) -> Array:
    """Predict epsilon.  latents: (B, R, R, C); t: (B,) in [0, 1000);
    ctx: (B, 77, 2048); add_emb: (B, d_add)."""
    pol = cfg.policy
    temb = L.timestep_embedding(t, cfg.ch).astype(pol.compute_dtype)
    emb = L.dense(params["time2"], L.silu(L.dense(params["time1"], temb, pol)), pol)
    emb = emb + L.dense(params["add2"],
                        L.silu(L.dense(params["add1"],
                                       add_emb.astype(pol.compute_dtype), pol)), pol)

    x = L.conv2d(params["conv_in"], latents, policy=pol)
    skips = [x]
    for li, level in enumerate(params["down"]):
        for rb, at in zip(level["res"], level["attn"]):
            x = _resblock_apply(rb, x, emb, pol)
            if at is not None:
                x = _spatial_xformer_apply(at, x, ctx, cfg, pol)
            skips.append(x)
        if level["down"] is not None:
            x = L.conv2d(level["down"], x, stride=2, policy=pol)
            skips.append(x)

    x = _resblock_apply(params["mid"]["res1"], x, emb, pol)
    x = _spatial_xformer_apply(params["mid"]["attn"], x, ctx, cfg, pol)
    x = _resblock_apply(params["mid"]["res2"], x, emb, pol)

    for li, level in enumerate(params["up"]):
        for rb, at in zip(level["res"], level["attn"]):
            x = jnp.concatenate([x, skips.pop()], axis=-1)
            x = _resblock_apply(rb, x, emb, pol)
            if at is not None:
                x = _spatial_xformer_apply(at, x, ctx, cfg, pol)
        if level["up"] is not None:
            x = L.upsample_nearest(x, 2)
            x = L.conv2d(level["up"], x, policy=pol)

    x = L.silu(L.groupnorm(params["gn_out"], x))
    return L.conv2d(params["conv_out"], x, policy=pol).astype(jnp.float32)


def unet_eps_loss(params: Params, batch: dict, cfg: UNetConfig, rng) -> Array:
    """DDPM epsilon-prediction MSE with a cosine schedule."""
    x0 = batch["latents"]
    r1, r2 = jax.random.split(rng)
    b = x0.shape[0]
    t = jax.random.uniform(r1, (b,)) * 999.0
    abar = jnp.cos((t / 1000.0 + 0.008) / 1.008 * (math.pi / 2)) ** 2
    eps = jax.random.normal(r2, x0.shape, x0.dtype)
    sq_a = jnp.sqrt(abar)[:, None, None, None]
    sq_1a = jnp.sqrt(1.0 - abar)[:, None, None, None]
    xt = sq_a * x0 + sq_1a * eps
    pred = unet_apply(params, xt, t, batch["ctx"], batch["add_emb"], cfg)
    return jnp.mean((pred - eps.astype(jnp.float32)) ** 2)


def unet_ddim_step(params: Params, xt: Array, t: Array, t_prev: Array,
                   ctx: Array, add_emb: Array, cfg: UNetConfig) -> Array:
    """One DDIM step (eta = 0)."""
    abar = lambda tt: jnp.cos((tt / 1000.0 + 0.008) / 1.008 * (math.pi / 2)) ** 2
    a_t = abar(t)[:, None, None, None]
    a_p = abar(t_prev)[:, None, None, None]
    eps = unet_apply(params, xt, t, ctx, add_emb, cfg).astype(xt.dtype)
    x0 = (xt - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_p) * x0 + jnp.sqrt(1 - a_p) * eps

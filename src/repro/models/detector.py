"""CSP-style one-shot detector ladder (the paper's scaled-YOLOv4 proxy).

The paper's Table II uses five scaled-YOLOv4 variants (Tiny-416,
CSP-512, CSP-640, P5-896, P6-1280).  No pretrained weights exist in
this offline container, so the ladder is reproduced *structurally*: a
CSP backbone + FPN neck + anchor-free dense head, with width/depth
multipliers and input sizes chosen to match the paper's resource
ordering.  The reproduction benchmark uses the gav accuracy tables for
detection quality (see DESIGN.md section 7); this model family proves
the end-to-end substrate (init/train/infer) and feeds the roofline
cells for the OmniSense serving pipeline.

Head: anchor-free (YOLOv8-style): per cell predicts (dx, dy, dw, dh,
objectness, class logits) at 3 scales (strides 8/16/32; P6 adds 64).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import BATCH, constrain
from repro.models import layers as L

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    name: str
    input_size: int  # square input resolution
    width_mult: float = 1.0
    depth_mult: float = 1.0
    n_classes: int = 80
    p6: bool = False  # extra stride-64 stage (YOLOv4-P6)
    base_width: int = 64
    base_depth: int = 3
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    def width(self, mult: int) -> int:
        return max(16, int(self.base_width * self.width_mult * mult) // 16 * 16)

    @property
    def depth(self) -> int:
        return max(1, round(self.base_depth * self.depth_mult))

    @property
    def strides(self) -> tuple[int, ...]:
        return (8, 16, 32, 64) if self.p6 else (8, 16, 32)


# paper Table II ladder ------------------------------------------------------

PAPER_LADDER = (
    DetectorConfig("yolo-tiny-416", 416, width_mult=0.25, depth_mult=0.34),
    DetectorConfig("yolo-csp-512", 512, width_mult=0.50, depth_mult=0.50),
    DetectorConfig("yolo-csp-640", 640, width_mult=0.50, depth_mult=0.50),
    DetectorConfig("yolo-p5-896", 896, width_mult=1.00, depth_mult=0.67),
    DetectorConfig("yolo-p6-1280", 1280, width_mult=1.00, depth_mult=1.00, p6=True),
)


def _conv_bn_init(rng, k, c_in, c_out, dt):
    return {"conv": L.init_conv(rng, k, k, c_in, c_out, bias=False, dtype=dt),
            "gn": L.init_groupnorm(c_out, dtype=dt)}


def _conv_bn(p, x, pol, stride=1):
    x = L.conv2d(p["conv"], x, stride=stride, policy=pol)
    return constrain(L.mish(L.groupnorm(p["gn"], x)),
                     BATCH, None, None, "model")


def _csp_block_init(rng, c, n, dt):
    r = jax.random.split(rng, 2 * n + 3)
    half = c // 2
    return {
        "split1": _conv_bn_init(r[0], 1, c, half, dt),
        "split2": _conv_bn_init(r[1], 1, c, half, dt),
        "bottlenecks": [
            {"c1": _conv_bn_init(r[2 + 2 * i], 1, half, half, dt),
             "c2": _conv_bn_init(r[3 + 2 * i], 3, half, half, dt)}
            for i in range(n)
        ],
        "fuse": _conv_bn_init(r[2 * n + 2], 1, c, c, dt),
    }


def _csp_block(p, x, pol):
    a = _conv_bn(p["split1"], x, pol)
    b = _conv_bn(p["split2"], x, pol)
    for bp in p["bottlenecks"]:
        b = b + _conv_bn(bp["c2"], _conv_bn(bp["c1"], b, pol), pol)
    return _conv_bn(p["fuse"], jnp.concatenate([a, b], axis=-1), pol)


def init_params(rng, cfg: DetectorConfig) -> Params:
    dt = cfg.param_dtype
    rngs = iter(jax.random.split(rng, 64))
    nxt = lambda: next(rngs)
    w = cfg.width
    n_scales = len(cfg.strides)
    chans = [w(2 ** (i + 1)) for i in range(n_scales)]  # e.g. 128/256/512(/1024)

    p: Params = {
        "stem": _conv_bn_init(nxt(), 3, 3, w(1), dt),
        "stem2": _conv_bn_init(nxt(), 3, w(1), chans[0] // 2, dt),
        "stages": [], "laterals": [], "fpn": [], "heads": [],
    }
    c_prev = chans[0] // 2
    for c in chans:
        p["stages"].append({
            "down": _conv_bn_init(nxt(), 3, c_prev, c, dt),
            "csp": _csp_block_init(nxt(), c, cfg.depth, dt),
        })
        c_prev = c
    # FPN top-down: lateral 1x1 on upper, merge with lower
    for i in range(n_scales - 1):
        c_hi, c_lo = chans[i + 1], chans[i]
        p["laterals"].append(_conv_bn_init(nxt(), 1, c_hi, c_lo, dt))
        p["fpn"].append(_csp_block_init(nxt(), c_lo, max(1, cfg.depth // 2), dt))
    # heads (one per scale)
    out_d = 5 + cfg.n_classes
    for c in chans:
        p["heads"].append({
            "conv": _conv_bn_init(nxt(), 3, c, c, dt),
            "out": L.init_conv(nxt(), 1, 1, c, out_d, dtype=dt),
        })
    return p


def apply(params: Params, images: Array, cfg: DetectorConfig) -> list[Array]:
    """images: (B, S, S, 3) -> list of per-scale raw heads
    (B, S/stride, S/stride, 5 + n_classes), finest first."""
    pol = cfg.policy
    x = _conv_bn(params["stem"], images, pol, stride=2)
    x = _conv_bn(params["stem2"], x, pol, stride=2)
    feats = []
    for st in params["stages"]:
        x = _conv_bn(st["down"], x, pol, stride=2)
        x = _csp_block(st["csp"], x, pol)
        feats.append(x)
    # top-down FPN
    for i in reversed(range(len(feats) - 1)):
        up = L.upsample_nearest(
            _conv_bn(params["laterals"][i], feats[i + 1], pol), 2)
        feats[i] = _csp_block(params["fpn"][i],
                              feats[i] + up, pol)
    outs = []
    for f, hp in zip(feats, params["heads"]):
        h = _conv_bn(hp["conv"], f, pol)
        outs.append(L.conv2d(hp["out"], h, policy=pol).astype(jnp.float32))
    return outs


# --------------------------------------------------------------------------
# decode + loss
# --------------------------------------------------------------------------


def decode(outs: list[Array], cfg: DetectorConfig,
           conf_threshold: float = 0.3, max_det: int = 128,
           valid: Array | None = None):
    """Raw heads -> (boxes_xyxy (B, N, 4) in pixels, scores (B, N),
    classes (B, N)); N = max_det, padded with score 0.

    ``valid`` is an optional (B,) bool mask for shape-bucketed batched
    inference: rows padded onto the batch (``valid == False``) decode
    with every score forced to 0, so padding can never emit detections
    while the batch keeps its bucketed static shape.
    """
    all_boxes, all_scores, all_cls = [], [], []
    for out, stride in zip(outs, cfg.strides):
        b, gh, gw, _ = out.shape
        xy = jax.nn.sigmoid(out[..., 0:2])  # offset within cell
        wh = jnp.exp(jnp.clip(out[..., 2:4], -6, 6)) * stride
        obj = jax.nn.sigmoid(out[..., 4])
        cls_logit = out[..., 5:]
        gy, gx = jnp.meshgrid(jnp.arange(gh), jnp.arange(gw), indexing="ij")
        cx = (gx[None] + xy[..., 0]) * stride
        cy = (gy[None] + xy[..., 1]) * stride
        boxes = jnp.stack([cx - wh[..., 0] / 2, cy - wh[..., 1] / 2,
                           cx + wh[..., 0] / 2, cy + wh[..., 1] / 2], axis=-1)
        cls_prob = jax.nn.softmax(cls_logit, axis=-1)
        score = obj * jnp.max(cls_prob, axis=-1)
        cls_id = jnp.argmax(cls_logit, axis=-1)
        all_boxes.append(boxes.reshape(b, -1, 4))
        all_scores.append(score.reshape(b, -1))
        all_cls.append(cls_id.reshape(b, -1))
    boxes = jnp.concatenate(all_boxes, axis=1)
    scores = jnp.concatenate(all_scores, axis=1)
    cls = jnp.concatenate(all_cls, axis=1)
    scores = jnp.where(scores >= conf_threshold, scores, 0.0)
    if valid is not None:
        scores = jnp.where(valid[:, None], scores, 0.0)
    top_scores, idx = jax.lax.top_k(scores, min(max_det, scores.shape[1]))
    top_boxes = jnp.take_along_axis(boxes, idx[..., None], axis=1)
    top_cls = jnp.take_along_axis(cls, idx, axis=1)
    return top_boxes, top_scores, top_cls


def detection_loss(params: Params, batch: dict, cfg: DetectorConfig) -> Array:
    """Dense detection loss against per-scale target maps.

    ``batch``: images (B,S,S,3) and, per scale s, targets
    (B, S/stride, S/stride, 5 + n_classes) with [dx, dy, log w, log h,
    obj, one-hot class] — produced by ``repro.data.synthetic.rasterize``.
    """
    outs = apply(params, batch["images"], cfg)
    total = 0.0
    for i, out in enumerate(outs):
        tgt = batch[f"targets_{i}"]
        obj_t = tgt[..., 4]
        obj_logit = out[..., 4]
        obj_loss = jnp.mean(
            jnp.maximum(obj_logit, 0) - obj_logit * obj_t
            + jnp.log1p(jnp.exp(-jnp.abs(obj_logit))))
        pos = obj_t > 0.5
        box_err = jnp.abs(out[..., 0:4] - tgt[..., 0:4]).sum(-1)
        box_loss = jnp.sum(jnp.where(pos, box_err, 0.0)) / jnp.maximum(
            jnp.sum(pos), 1.0)
        cls_ll = jax.nn.log_softmax(out[..., 5:], axis=-1)
        cls_loss = -jnp.sum(jnp.where(pos[..., None], tgt[..., 5:] * cls_ll, 0.0)) \
            / jnp.maximum(jnp.sum(pos), 1.0)
        total = total + obj_loss + 0.5 * box_loss + 0.5 * cls_loss
    return total / len(outs)


def flops_per_image(cfg: DetectorConfig) -> float:
    """Analytic MAC estimate (x2 = FLOPs) used by the latency profiles."""
    s = cfg.input_size
    total = 0.0
    # stem
    total += (s / 2) ** 2 * 9 * 3 * cfg.width(1)
    total += (s / 4) ** 2 * 9 * cfg.width(1) * cfg.width(2) // 2
    res = s / 4
    c_prev = cfg.width(2) // 2
    for i in range(len(cfg.strides)):
        c = cfg.width(2 ** (i + 1))
        res /= 2
        total += res ** 2 * 9 * c_prev * c  # downsample
        half = c // 2
        total += res ** 2 * (2 * c * half + c * c)  # csp split+fuse
        total += cfg.depth * res ** 2 * (half * half + 9 * half * half)
        c_prev = c
    return float(total * 2)


# --------------------------------------------------------------------------
# detection heads on the assigned vision backbones (beyond-paper ladder)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackboneDetectorConfig:
    """Anchor-free detection head mounted on a classification backbone.

    Widens the paper's Table II ladder with the assigned vision
    architectures: the backbone's stride-8/16/32 pyramid levels feed
    the same per-scale heads as the CSP detector, so the OmniSense
    allocator sees extra (accuracy, latency) rungs without new
    training infrastructure (DESIGN.md section 2).
    """

    name: str
    backbone_cfg: Any  # vision.ResNetConfig | vision.ConvNeXtConfig
    input_size: int
    n_classes: int = 80
    head_width: int = 128
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def strides(self) -> tuple[int, ...]:
        return (8, 16, 32)


def _backbone_feature_fn(cfg: BackboneDetectorConfig):
    from repro.models import vision as V

    if isinstance(cfg.backbone_cfg, V.ResNetConfig):
        return V.resnet_init, V.resnet_features
    if isinstance(cfg.backbone_cfg, V.ConvNeXtConfig):
        return V.convnext_init, V.convnext_features
    raise TypeError(type(cfg.backbone_cfg))


def backbone_detector_init(rng, cfg: BackboneDetectorConfig) -> Params:
    init_fn, _ = _backbone_feature_fn(cfg)
    r = jax.random.split(rng, 8)
    backbone = init_fn(r[0], cfg.backbone_cfg)
    from repro.models import vision as V

    if isinstance(cfg.backbone_cfg, V.ResNetConfig):
        chans = [cfg.backbone_cfg.width * (2 ** i) * 4 for i in (1, 2, 3)]
    else:
        chans = list(cfg.backbone_cfg.dims[1:])
    dt = cfg.param_dtype
    heads = []
    out_d = 5 + cfg.n_classes
    for i, c in enumerate(chans):
        heads.append({
            "lateral": _conv_bn_init(r[1 + i], 1, c, cfg.head_width, dt),
            "conv": _conv_bn_init(r[4 + i], 3, cfg.head_width,
                                  cfg.head_width, dt),
            "out": L.init_conv(r[7], 1, 1, cfg.head_width, out_d, dtype=dt),
        })
    return {"backbone": backbone, "heads": heads}


def backbone_detector_apply(params: Params, images: Array,
                            cfg: BackboneDetectorConfig) -> list[Array]:
    """images (B, S, S, 3) -> per-scale raw heads at strides 8/16/32."""
    _, feat_fn = _backbone_feature_fn(cfg)
    pol = cfg.policy
    feats, _ = feat_fn(params["backbone"], images, cfg.backbone_cfg,
                       train=False)
    outs = []
    for f, hp in zip(feats[1:], params["heads"]):  # strides 8/16/32
        h = _conv_bn(hp["lateral"], f, pol)
        h = _conv_bn(hp["conv"], h, pol)
        outs.append(L.conv2d(hp["out"], h, policy=pol).astype(jnp.float32))
    return outs

"""Vision backbones: ViT-B/16, ConvNeXt-B, ResNet-50/152.

Assigned-architecture implementations (exact configs live in
``repro.configs``).  Patch-embed / conv stems are part of the model
(vision pool semantics).  Repeated homogeneous blocks are stacked and
scanned so ResNet-152's 36-block stage lowers as one loop.

API per family:
    init_params(rng, cfg) -> params
    apply(params, images, train=False) -> (logits, updated_params)
``updated_params`` carries refreshed BatchNorm running stats (ResNet);
for stat-free models it is ``params`` unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, constrain
from repro.models import layers as L

Array = jax.Array
Params = dict


# ==========================================================================
# ViT
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def n_params(self) -> int:
        d, f = self.d_model, self.d_ff
        per = 4 * d * d + 2 * d * f + 4 * d
        stem = self.patch * self.patch * 3 * d
        seq = (self.img_res // self.patch) ** 2 + 1
        return self.n_layers * per + stem + seq * d + d * self.n_classes


def vit_init(rng, cfg: ViTConfig) -> Params:
    dt = cfg.param_dtype
    rngs = jax.random.split(rng, 8)
    d, lyr = cfg.d_model, cfg.n_layers
    n_tokens = (cfg.img_res // cfg.patch) ** 2 + 1

    def stacked(key, shape, scale):
        return (jax.random.uniform(key, (lyr,) + shape, jnp.float32, -scale, scale)
                .astype(dt))

    s = (1.0 / d) ** 0.5
    sf = (1.0 / cfg.d_ff) ** 0.5
    return {
        "patch": L.init_conv(rngs[0], cfg.patch, cfg.patch, 3, d, dtype=dt),
        "cls": jnp.zeros((1, 1, d), dt),
        "pos": jax.random.normal(rngs[1], (1, n_tokens, d), jnp.float32).astype(dt) * 0.02,
        "layers": {
            "ln1": {"scale": jnp.ones((lyr, d), dt), "bias": jnp.zeros((lyr, d), dt)},
            "wqkv": stacked(rngs[2], (d, 3 * d), s),
            "wo": stacked(rngs[3], (d, d), s),
            "ln2": {"scale": jnp.ones((lyr, d), dt), "bias": jnp.zeros((lyr, d), dt)},
            "w1": stacked(rngs[4], (d, cfg.d_ff), s),
            "b1": jnp.zeros((lyr, cfg.d_ff), dt),
            "w2": stacked(rngs[5], (cfg.d_ff, d), sf),
            "b2": jnp.zeros((lyr, d), dt),
        },
        "ln_f": L.init_layernorm(d, dt),
        "head": L.init_dense(rngs[6], d, cfg.n_classes, dtype=dt),
    }


def _mha_full(x: Array, wqkv: Array, wo: Array, n_heads: int,
              policy: L.DtypePolicy) -> Array:
    b, s, d = x.shape
    dh = d // n_heads
    qkv = L.dense({"w": wqkv}, x, policy).reshape(b, s, 3, n_heads, dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    att = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                     k.astype(jnp.float32)) * (dh ** -0.5)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v.astype(jnp.float32))
    out = out.astype(policy.compute_dtype).reshape(b, s, d)
    return L.dense({"w": wo}, out, policy)


def vit_apply(params: Params, images: Array, cfg: ViTConfig,
              train: bool = False) -> tuple[Array, Params]:
    del train  # no batch stats
    pol = cfg.policy
    x = L.conv2d(params["patch"], images, stride=cfg.patch, padding="VALID",
                 policy=pol)
    b, h, w, d = x.shape
    x = x.reshape(b, h * w, d)
    cls = jnp.broadcast_to(params["cls"].astype(pol.compute_dtype), (b, 1, d))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"].astype(pol.compute_dtype)
    x = constrain(x, BATCH, None, None)

    def body(x, lp):
        h1 = L.layernorm({"scale": lp["ln1"]["scale"], "bias": lp["ln1"]["bias"]}, x)
        x = x + _mha_full(h1, lp["wqkv"], lp["wo"], cfg.n_heads, pol)
        h2 = L.layernorm({"scale": lp["ln2"]["scale"], "bias": lp["ln2"]["bias"]}, x)
        y = constrain(L.gelu(L.dense({"w": lp["w1"], "b": lp["b1"]}, h2, pol)),
                      BATCH, None, "model")
        x = constrain(x + L.dense({"w": lp["w2"], "b": lp["b2"]}, y, pol),
                      BATCH, None, None)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.layernorm(params["ln_f"], x)
    logits = L.dense(params["head"], x[:, 0], pol).astype(jnp.float32)
    return logits, params


# ==========================================================================
# ConvNeXt
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class ConvNeXtConfig:
    name: str
    img_res: int
    depths: Sequence[int] = (3, 3, 27, 3)
    dims: Sequence[int] = (128, 256, 512, 1024)
    n_classes: int = 1000
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def n_params(self) -> int:
        total = 4 * 4 * 3 * self.dims[0]
        prev = self.dims[0]
        for depth, dim in zip(self.depths, self.dims):
            if dim != prev:
                total += 2 * 2 * prev * dim
            total += depth * (7 * 7 * dim + dim * 4 * dim * 2 + 3 * dim)
            prev = dim
        return total + self.dims[-1] * self.n_classes


def _convnext_block_init(rng, dim: int, dt) -> Params:
    r = jax.random.split(rng, 3)
    return {
        "dw": L.init_conv(r[0], 7, 7, dim, dim, dtype=dt, groups=dim),
        "ln": L.init_layernorm(dim, dt),
        "pw1": L.init_dense(r[1], dim, 4 * dim, dtype=dt),
        "pw2": L.init_dense(r[2], 4 * dim, dim, dtype=dt),
        "gamma": jnp.full((dim,), 1e-6, dt),
    }


def _stack_params(plist: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *plist)


def convnext_init(rng, cfg: ConvNeXtConfig) -> Params:
    dt = cfg.param_dtype
    rngs = jax.random.split(rng, 4 + len(cfg.depths) * 2)
    p: Params = {
        "stem": L.init_conv(rngs[0], 4, 4, 3, cfg.dims[0], dtype=dt),
        "stem_ln": L.init_layernorm(cfg.dims[0], dt),
        "stages": [],
        "downsample": [],
        "ln_f": L.init_layernorm(cfg.dims[-1], dt),
        "head": L.init_dense(rngs[1], cfg.dims[-1], cfg.n_classes, dtype=dt),
    }
    prev = cfg.dims[0]
    for si, (depth, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        r = jax.random.split(rngs[2 + si], depth + 1)
        if dim != prev:
            p["downsample"].append({
                "ln": L.init_layernorm(prev, dt),
                "conv": L.init_conv(r[0], 2, 2, prev, dim, dtype=dt),
            })
        else:
            p["downsample"].append(None)
        p["stages"].append(_stack_params(
            [_convnext_block_init(r[1 + i], dim, dt) for i in range(depth)]))
        prev = dim
    return p


def convnext_features(params: Params, images: Array, cfg: ConvNeXtConfig,
                      train: bool = False) -> tuple[list, Params]:
    """Per-stage feature maps (strides 4/8/16/32) for detection heads."""
    del train
    pol = cfg.policy
    x = L.conv2d(params["stem"], images, stride=4, padding="VALID", policy=pol)
    x = L.layernorm(params["stem_ln"], x)

    def block(x, bp):
        h = L.conv2d(bp["dw"], x, groups=x.shape[-1], policy=pol)
        h = L.layernorm(bp["ln"], h)
        h = constrain(L.gelu(L.dense(bp["pw1"], h, pol)),
                      BATCH, None, None, "model")
        h = L.dense(bp["pw2"], h, pol)
        out = x + h * bp["gamma"].astype(pol.compute_dtype)
        return constrain(out, BATCH, None, None, None), None

    body = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else block
    feats = []
    for ds, stage in zip(params["downsample"], params["stages"]):
        if ds is not None:
            x = L.layernorm(ds["ln"], x)
            x = L.conv2d(ds["conv"], x, stride=2, padding="VALID", policy=pol)
        x, _ = jax.lax.scan(body, x, stage)
        feats.append(x)
    return feats, params


def convnext_apply(params: Params, images: Array, cfg: ConvNeXtConfig,
                   train: bool = False) -> tuple[Array, Params]:
    pol = cfg.policy
    feats, _ = convnext_features(params, images, cfg, train)
    x = L.avg_pool_global(feats[-1])
    x = L.layernorm(params["ln_f"], x)
    logits = L.dense(params["head"], x, pol).astype(jnp.float32)
    return logits, params


# ==========================================================================
# ResNet
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    img_res: int
    depths: Sequence[int] = (3, 4, 6, 3)
    width: int = 64
    n_classes: int = 1000
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def n_params(self) -> int:
        total = 7 * 7 * 3 * self.width
        c_in = self.width
        for i, depth in enumerate(self.depths):
            mid = self.width * (2 ** i)
            out = mid * 4
            total += c_in * mid + 3 * 3 * mid * mid + mid * out + c_in * out
            total += (depth - 1) * (out * mid + 3 * 3 * mid * mid + mid * out)
            c_in = out
        return total + c_in * self.n_classes


def _bottleneck_init(rng, c_in: int, mid: int, stride: int, project: bool, dt) -> Params:
    r = jax.random.split(rng, 4)
    out = mid * 4
    p = {
        "conv1": L.init_conv(r[0], 1, 1, c_in, mid, bias=False, dtype=dt),
        "bn1": L.init_batchnorm(mid, dt),
        "conv2": L.init_conv(r[1], 3, 3, mid, mid, bias=False, dtype=dt),
        "bn2": L.init_batchnorm(mid, dt),
        "conv3": L.init_conv(r[2], 1, 1, mid, out, bias=False, dtype=dt),
        "bn3": L.init_batchnorm(out, dt),
    }
    if project:
        p["proj"] = L.init_conv(r[3], 1, 1, c_in, out, bias=False, dtype=dt)
        p["bn_proj"] = L.init_batchnorm(out, dt)
    return p


def resnet_init(rng, cfg: ResNetConfig) -> Params:
    dt = cfg.param_dtype
    rngs = jax.random.split(rng, 3 + len(cfg.depths))
    p: Params = {
        "stem": L.init_conv(rngs[0], 7, 7, 3, cfg.width, bias=False, dtype=dt),
        "bn_stem": L.init_batchnorm(cfg.width, dt),
        "stages": [],
        "head": L.init_dense(rngs[1], cfg.width * 8 * 4, cfg.n_classes, dtype=dt),
    }
    c_in = cfg.width
    for i, depth in enumerate(cfg.depths):
        mid = cfg.width * (2 ** i)
        r = jax.random.split(rngs[2 + i], depth)
        first = _bottleneck_init(r[0], c_in, mid, 2 if i > 0 else 1, True, dt)
        rest = [_bottleneck_init(r[j], mid * 4, mid, 1, False, dt)
                for j in range(1, depth)]
        p["stages"].append({
            "first": first,
            "rest": _stack_params(rest) if rest else None,
        })
        c_in = mid * 4
    return p


def _bottleneck_apply(bp: Params, x: Array, stride: int, train: bool,
                      pol: L.DtypePolicy) -> tuple[Array, Params]:
    new = dict(bp)
    h = L.conv2d(bp["conv1"], x, policy=pol)
    h, new["bn1"] = L.batchnorm(bp["bn1"], h, train=train)
    h = jax.nn.relu(h)
    h = L.conv2d(bp["conv2"], h, stride=stride, policy=pol)
    h, new["bn2"] = L.batchnorm(bp["bn2"], h, train=train)
    h = jax.nn.relu(h)
    h = L.conv2d(bp["conv3"], h, policy=pol)
    h, new["bn3"] = L.batchnorm(bp["bn3"], h, train=train)
    if "proj" in bp:
        sc = L.conv2d(bp["proj"], x, stride=stride, policy=pol)
        sc, new["bn_proj"] = L.batchnorm(bp["bn_proj"], sc, train=train)
    else:
        sc = x
    return constrain(jax.nn.relu(h + sc), BATCH, None, None, "model"), new


def resnet_features(params: Params, images: Array, cfg: ResNetConfig,
                    train: bool = False) -> tuple[list, Params]:
    """Per-stage feature maps (strides 4/8/16/32) for detection heads."""
    pol = cfg.policy
    new_params = dict(params)
    x = L.conv2d(params["stem"], images, stride=2, policy=pol)
    x, new_params["bn_stem"] = L.batchnorm(params["bn_stem"], x, train=train)
    x = jax.nn.relu(x)
    x = L.max_pool(x, 3, 2)

    feats = []
    new_stages = []
    for i, stage in enumerate(params["stages"]):
        stride = 2 if i > 0 else 1
        ns = dict(stage)
        x, ns["first"] = _bottleneck_apply(stage["first"], x, stride, train, pol)

        if stage["rest"] is not None:
            def body(x, bp):
                y, nbp = _bottleneck_apply(bp, x, 1, train, pol)
                return y, nbp

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, ns["rest"] = jax.lax.scan(body, x, stage["rest"])
        new_stages.append(ns)
        feats.append(x)
    new_params["stages"] = new_stages
    return feats, new_params


def resnet_apply(params: Params, images: Array, cfg: ResNetConfig,
                 train: bool = False) -> tuple[Array, Params]:
    pol = cfg.policy
    feats, new_params = resnet_features(params, images, cfg, train)
    x = L.avg_pool_global(feats[-1])
    logits = L.dense(params["head"], x, pol).astype(jnp.float32)
    return logits, new_params

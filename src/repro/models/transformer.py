"""Decoder-only LM family: dense / GQA / MQA / sliding-window / MoE.

Covers the four assigned LM architectures (granite-34b, smollm-135m,
mixtral-8x22b, qwen3-moe-235b-a22b) with one configurable implementation:

  * llama-style blocks: RMSNorm -> attention (+RoPE, GQA) -> residual,
    RMSNorm -> SwiGLU MLP or top-k MoE -> residual;
  * ``jax.lax.scan`` over stacked layer params so HLO size is O(1) in
    depth (88/94-layer configs must stay lowerable on one CPU host);
  * three attention impls: ``naive`` (test oracle), ``chunked``
    (lax.scan online-softmax — the memory-sane default for 4k-32k
    training/prefill), ``pallas`` (the flash kernel, TPU runtime);
  * KV-cache prefill/decode; sliding-window models use a ring-buffer
    cache bounded by the window (this is what makes long_500k decode
    feasible: O(window) memory and compute per token);
  * chunked cross-entropy: the (tokens, vocab) logits matrix is never
    materialised — unembedding + CE run in sequence chunks under remat
    (vocab 152k x 1M tokens would otherwise be ~0.6 PB).

MoE: sort-based grouped dispatch (tokens argsorted by expert id, static
capacity, one grouped einsum per projection) — the standard
compile-friendly TPU formulation; capacity overflow drops tokens
(combine weights renormalised over survivors).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (BATCH, constrain, current_mesh,
                                         mesh_axis_size, shard_map)
from repro.models import layers as L

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # MoE
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # attention
    window: int | None = None  # sliding-window size (tokens), None = full
    rope_theta: float = 10000.0
    attention_impl: str = "chunked"  # naive | chunked | pallas
    attn_chunk: int = 1024
    # loss
    ce_chunk: int = 512
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    norm_eps: float = 1e-6
    remat: bool = True
    # Megatron-style sequence parallelism: residual-stream activations
    # shard their SEQUENCE axis over `model` between the TP regions, so
    # norms/residuals/rotaries touch 1/TP of the bytes and the saved
    # scan carries shrink by TP.  XLA inserts the all-gather at qkv/mlp
    # entry and reduce-scatters after wo/w_down (beyond-paper perf
    # iteration; see EXPERIMENTS.md section Perf).
    sequence_parallel: bool = False
    # Explicit all-to-all expert parallelism (shard_map): every
    # (data, model) rank dispatches its OWN token slice to the expert
    # owners instead of letting SPMD all-reduce full (tokens*k, d)
    # combine buffers across `model`.  Requires sequence_parallel
    # (tokens must be disjoint across model ranks) and
    # n_experts % model_axis == 0.
    moe_a2a: bool = False

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def n_params(self) -> int:
        """Total parameter count (used for 6*N*D roofline bookkeeping)."""
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.moe:
            mlp = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
        else:
            mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        emb = self.vocab_size * d * 2  # untied in/out embeddings
        return self.n_layers * per_layer + emb + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.n_params
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        mlp = self.moe_top_k * 3 * d * self.d_ff_expert + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + self.vocab_size * d * 2 + d


@dataclasses.dataclass
class KVCache:
    k: Array  # (L, B, S_cache, KVH, Dh)
    v: Array  # (L, B, S_cache, KVH, Dh)
    length: Array  # scalar int32: number of tokens already absorbed


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_params(rng, cfg: TransformerConfig) -> Params:
    dt = cfg.param_dtype
    d, dh = cfg.d_model, cfg.d_head
    rngs = jax.random.split(rng, 12)
    lyr = cfg.n_layers

    def stacked(key, shape, scale):
        return (jax.random.uniform(key, (lyr,) + shape, jnp.float32, -scale, scale)
                .astype(dt))

    s_attn = (1.0 / d) ** 0.5
    p_layers = {
        "attn": {
            "wq": stacked(rngs[0], (d, cfg.n_heads * dh), s_attn),
            "wk": stacked(rngs[1], (d, cfg.n_kv_heads * dh), s_attn),
            "wv": stacked(rngs[2], (d, cfg.n_kv_heads * dh), s_attn),
            "wo": stacked(rngs[3], (cfg.n_heads * dh, d), (1.0 / (cfg.n_heads * dh)) ** 0.5),
        },
        "ln1": {"scale": jnp.ones((lyr, d), dt)},
        "ln2": {"scale": jnp.ones((lyr, d), dt)},
    }
    if cfg.moe:
        fe = cfg.d_ff_expert
        s_ff = (1.0 / d) ** 0.5
        p_layers["moe"] = {
            "router": stacked(rngs[4], (d, cfg.n_experts), s_ff),
            "w_gate": stacked(rngs[5], (cfg.n_experts, d, fe), s_ff),
            "w_up": stacked(rngs[6], (cfg.n_experts, d, fe), s_ff),
            "w_down": stacked(rngs[7], (cfg.n_experts, fe, d), (1.0 / fe) ** 0.5),
        }
    else:
        f = cfg.d_ff
        s_ff = (1.0 / d) ** 0.5
        p_layers["mlp"] = {
            "w_gate": stacked(rngs[4], (d, f), s_ff),
            "w_up": stacked(rngs[5], (d, f), s_ff),
            "w_down": stacked(rngs[6], (f, d), (1.0 / f) ** 0.5),
        }
    return {
        "embed": L.init_embedding(rngs[8], cfg.vocab_size, d, dt),
        "layers": p_layers,
        "ln_f": L.init_rmsnorm(d, dt),
        "unembed": L.init_dense(rngs[9], d, cfg.vocab_size, bias=False, dtype=dt),
    }


# --------------------------------------------------------------------------
# attention impls
# --------------------------------------------------------------------------


def _naive_attention(q, k, v, *, causal, window, q_offset, scale):
    # q: (B, Sq, H, Dh); k/v: (B, Skv, H, Dh) (kv heads already repeated)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    sq, skv = q.shape[1], k.shape[1]
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal, window, q_offset, scale, chunk):
    """Online-softmax over KV chunks via lax.scan (flash in pure jnp)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (skv + pad) // chunk
    kc = k.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    q32 = q.astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        idx, kb, vb = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kb.astype(jnp.float32)) * scale
        kpos = idx * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < skv
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, h, sq), -1e30, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, dh), jnp.float32),
    )
    # nested remat: without it autodiff saves the (sq, chunk) score matrix
    # of EVERY chunk — i.e. the full S^2 softmax — defeating the point of
    # chunking for training.  Recompute per chunk in the backward instead.
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, cfg: TransformerConfig, *, causal=True, window=None,
              q_offset=0):
    """Dispatch on cfg.attention_impl. q: (B,Sq,H,Dh); k/v: (B,Skv,KVH,Dh)."""
    scale = cfg.d_head ** -0.5
    if cfg.attention_impl == "pallas":
        from repro.kernels.attention.ops import flash_attention

        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale)
    rep = q.shape[2] // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if cfg.attention_impl == "naive":
        return _naive_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, scale=scale)
    return _chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, scale=scale, chunk=cfg.attn_chunk)


# --------------------------------------------------------------------------
# MoE block
# --------------------------------------------------------------------------


def moe_block(p: Params, x: Array, cfg: TransformerConfig) -> tuple[Array, Array]:
    """Top-k MoE with per-data-shard grouped dispatch.

    ``x``: (T, D) flattened tokens.  Returns (out, aux_loss) where
    aux_loss is the load-balancing term (Switch-style).

    Tokens are reshaped to (G, T/G, D) with G = the data-parallel world
    size, so the argsort / searchsorted dispatch machinery runs *per
    data shard* (vmapped, zero cross-shard communication) — the
    production formulation.  A global sort would force XLA SPMD to
    all-gather 8M routing keys per MoE layer.  Capacity is therefore
    per-shard (ceil(T_local * k / E * cf)), i.e. load balancing is
    enforced shard-locally — the standard behaviour of EP systems.
    Expert placement (EP over `model` vs TP-within-expert) follows the
    weight sharding chosen in ``repro.distributed.sharding``.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    pol = cfg.policy
    import math as _math

    g = _math.gcd(t, mesh_axis_size("pod") * mesh_axis_size("data"))
    tl = t // g
    xg = constrain(x.reshape(g, tl, d), BATCH, None, None)

    logits = L.dense({"w": p["router"]}, xg, pol).astype(jnp.float32)  # (G,TL,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (G, TL, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss (scatter-add counts; no (T, E) one-hot)
    me = jnp.mean(probs, axis=(0, 1))
    counts = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0)
    aux = e * jnp.sum(me * counts / (t * k))

    capacity = max(1, int(-(-tl * k // e) * cfg.capacity_factor))

    fe = expert_ids.reshape(g, tl * k)  # flat expert ids per shard
    ft = jnp.repeat(jnp.arange(tl), k)[None].repeat(g, axis=0)
    fg = gate_vals.reshape(g, tl * k)

    # The whole dispatch runs VMAPPED over the shard axis: XLA SPMD
    # partitions batched (vmapped) gather/scatter on the batch dim with
    # zero collectives, whereas the equivalent fancy-indexed forms get
    # involuntarily replicated (measured: 137 TB/layer of all-reduce on
    # the qwen3 cell).
    def _dispatch(xr, fer, ftr, fgr):
        order = jnp.argsort(fer)
        se, str_, sgr = fer[order], ftr[order], fgr[order]
        start = jnp.searchsorted(se, jnp.arange(e))
        pos = jnp.arange(tl * k) - start[se]
        keepr = pos < capacity
        slotr = jnp.where(keepr, se * capacity + pos, e * capacity)
        gathered = jnp.zeros((e * capacity + 1, d), xr.dtype).at[slotr].set(
            xr[str_])
        return gathered[:-1], slotr, str_, keepr, sgr

    gathered, slot, st, keep, sg = jax.vmap(_dispatch)(xg, fe, ft, fg)
    grouped = gathered.reshape(g, e, capacity, d)
    # expert parallelism when the expert count divides the model axis
    # (qwen3); otherwise TP-within-expert (mixtral) and the grouped
    # tokens stay replicated over `model` while the FFN width shards.
    ep = e % max(mesh_axis_size("model"), 1) == 0
    if ep:
        grouped = constrain(grouped, BATCH, "model", None, None)
    else:
        grouped = constrain(grouped, BATCH, None, None, None)

    gate_h = jnp.einsum("gecd,edf->gecf", pol.cast_in(grouped),
                        p["w_gate"].astype(cfg.compute_dtype),
                        preferred_element_type=jnp.float32)
    up_h = jnp.einsum("gecd,edf->gecf", pol.cast_in(grouped),
                      p["w_up"].astype(cfg.compute_dtype),
                      preferred_element_type=jnp.float32)
    hidden = (L.silu(gate_h) * up_h).astype(cfg.compute_dtype)
    hidden = constrain(hidden, BATCH, "model", None, None) if ep \
        else constrain(hidden, BATCH, None, None, "model")
    expert_out = jnp.einsum("gecf,efd->gecd", hidden,
                            p["w_down"].astype(cfg.compute_dtype),
                            preferred_element_type=jnp.float32)
    if ep:
        expert_out = constrain(expert_out, BATCH, "model", None, None)
    expert_out = expert_out.reshape(g, e * capacity, d)

    # combine runs in compute dtype: the (tl*k, d) gather + scatter-add
    # is pure HBM traffic; bf16 halves it (sum of <= top_k values with
    # renormalised gates — negligible precision impact, measured in
    # EXPERIMENTS.md section Perf).
    cdt = cfg.compute_dtype

    def _combine(eor, slotr, str_, keepr, sgr):
        contrib = jnp.where(
            keepr[:, None],
            eor.astype(cdt)[jnp.minimum(slotr, e * capacity - 1)]
            * sgr[:, None].astype(cdt), jnp.zeros((), cdt))
        return jnp.zeros((tl, d), cdt).at[str_].add(contrib)

    out = jax.vmap(_combine)(expert_out, slot, st, keep, sg)
    return out.reshape(t, d).astype(x.dtype), aux




def _use_moe_a2a(cfg: TransformerConfig) -> bool:
    if not (cfg.moe and cfg.moe_a2a and cfg.sequence_parallel):
        return False
    m = mesh_axis_size("model")
    return m > 1 and cfg.n_experts % m == 0


def moe_block_a2a(p: Params, x: Array, cfg: TransformerConfig
                  ) -> tuple[Array, Array]:
    """Explicit all-to-all expert parallelism (shard_map).

    Under sequence parallelism every (data, model) rank owns a DISJOINT
    slice of the tokens, so the MoE exchange can be the textbook EP
    all-to-all: each rank dispatches its local tokens to the model
    ranks that own their experts and receives them back after the
    expert FFN — total wire volume tokens*k*d / model_ranks per link,
    versus the tokens*k*d all-reduce XLA SPMD emits for the implicit
    formulation (measured 20x reduction on the qwen3 cell, see
    EXPERIMENTS.md section Perf).  Token dropping uses the same
    per-shard capacity rule as :func:`moe_block`, just at per-rank
    granularity; with no drops the two paths agree exactly
    (tests/test_distributed_integration.py).
    """
    mesh = current_mesh()
    e, k = cfg.n_experts, cfg.moe_top_k
    pol = cfg.policy
    t, d = x.shape
    m_size = mesh_axis_size("model")
    e_loc = e // m_size
    flat_axes = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)
    n_ranks = 1
    for a in flat_axes:
        n_ranks *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    tl = t // n_ranks
    capacity = max(1, int(-(-tl * k // e) * cfg.capacity_factor))
    f_dim = cfg.d_ff_expert
    from jax.sharding import PartitionSpec as P

    def kernel(xr, router_w, wg, wu, wd):
        # xr: (tl, d) local tokens; wg/wu/wd: (e_loc, d, f) local experts
        xr = xr.reshape(tl, d)
        logits = jax.lax.dot_general(
            pol.cast_in(xr), router_w.astype(cfg.compute_dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (tl, e)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        counts = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.)
        aux_loc = e * jnp.sum(me * counts / (tl * k))

        fe = expert_ids.reshape(-1)
        ft = jnp.repeat(jnp.arange(tl), k)
        fg = gate_vals.reshape(-1)
        order = jnp.argsort(fe)
        se, st, sg = fe[order], ft[order], fg[order]
        start = jnp.searchsorted(se, jnp.arange(e))
        pos = jnp.arange(tl * k) - start[se]
        keep = pos < capacity
        slot = jnp.where(keep, se * capacity + pos, e * capacity)
        gathered = jnp.zeros((e * capacity + 1, d), xr.dtype).at[slot].set(
            xr[st])[:-1]

        # ---- dispatch: (m_size, e_loc*capacity, d) -> owners ----
        send = gathered.reshape(m_size, e_loc * capacity, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        # recv: (m_size * e_loc * capacity, d) = tokens from every
        # source rank for MY e_loc experts
        grouped = recv.reshape(m_size, e_loc, capacity, d)             .transpose(1, 0, 2, 3).reshape(e_loc, m_size * capacity, d)

        gate_h = jnp.einsum("ecd,edf->ecf", pol.cast_in(grouped),
                            wg.astype(cfg.compute_dtype),
                            preferred_element_type=jnp.float32)
        up_h = jnp.einsum("ecd,edf->ecf", pol.cast_in(grouped),
                          wu.astype(cfg.compute_dtype),
                          preferred_element_type=jnp.float32)
        hidden = (L.silu(gate_h) * up_h).astype(cfg.compute_dtype)
        eo = jnp.einsum("ecf,efd->ecd", hidden,
                        wd.astype(cfg.compute_dtype),
                        preferred_element_type=jnp.float32)
        eo = eo.astype(cfg.compute_dtype)

        # ---- return: reverse all-to-all ----
        back = eo.reshape(e_loc, m_size, capacity, d)             .transpose(1, 0, 2, 3).reshape(m_size, e_loc * capacity, d)
        mine = jax.lax.all_to_all(back, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        mine = mine.reshape(e * capacity, d)

        contrib = jnp.where(
            keep[:, None],
            mine[jnp.minimum(slot, e * capacity - 1)]
            * sg[:, None].astype(cfg.compute_dtype),
            jnp.zeros((), cfg.compute_dtype))
        out = jnp.zeros((tl, d), cfg.compute_dtype).at[st].add(contrib)
        aux = jax.lax.pmean(aux_loc, flat_axes)
        return out, aux

    out, aux = shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(flat_axes, None),          # tokens: disjoint slices
                  P(None, None),               # router replicated
                  P("model", None, None),      # experts over model
                  P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(flat_axes, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out.astype(x.dtype), aux


def dense_mlp(p: Params, x: Array, cfg: TransformerConfig) -> Array:
    pol = cfg.policy
    h = L.silu(L.dense({"w": p["w_gate"]}, x, pol)) * L.dense({"w": p["w_up"]}, x, pol)
    h = constrain(h, BATCH, None, "model")
    return L.dense({"w": p["w_down"]}, h, pol)


# --------------------------------------------------------------------------
# blocks / forward
# --------------------------------------------------------------------------


def _layer(lp: Params, x: Array, cfg: TransformerConfig, positions: Array,
           kv: tuple[Array, Array] | None, q_offset) -> tuple[Array, Array, tuple]:
    """One decoder block.  If ``kv`` is given it is the (k_cache, v_cache)
    to attend over (decode); otherwise self-attention on x (train/prefill).
    Returns (x_out, aux_loss, (k_new, v_new))."""
    b, s, d = x.shape
    pol = cfg.policy
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q = L.dense({"w": lp["attn"]["wq"]}, h, pol).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = L.dense({"w": lp["attn"]["wk"]}, h, pol).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = L.dense({"w": lp["attn"]["wv"]}, h, pol).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = constrain(L.apply_rope(q, positions, cfg.rope_theta),
                  BATCH, None, "model", None)
    k = constrain(L.apply_rope(k, positions, cfg.rope_theta),
                  BATCH, None, "model", None)
    v = constrain(v, BATCH, None, "model", None)

    if kv is None:
        attn_out = attention(q, k, v, cfg, causal=True, window=cfg.window,
                             q_offset=q_offset)
    else:
        kc, vc = kv
        attn_out = attention(q, kc, vc, cfg, causal=True, window=cfg.window,
                             q_offset=q_offset)
    attn_out = constrain(attn_out.reshape(b, s, cfg.n_heads * cfg.d_head),
                         BATCH, None, "model")
    seq_ax = "model" if cfg.sequence_parallel else None
    x = constrain(x + L.dense({"w": lp["attn"]["wo"]}, attn_out, pol),
                  BATCH, seq_ax, None)

    h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.moe:
        blk = moe_block_a2a if _use_moe_a2a(cfg) else moe_block
        out, aux = blk(lp["moe"], h2.reshape(b * s, d), cfg)
        x = x + out.reshape(b, s, d)
    else:
        x = x + dense_mlp(lp["mlp"], h2, cfg)
        aux = jnp.zeros((), jnp.float32)
    x = constrain(x, BATCH, seq_ax, None)
    return x, aux, (k, v)


def forward(params: Params, tokens: Array, cfg: TransformerConfig,
            positions: Array | None = None) -> tuple[Array, Array]:
    """Full forward pass -> (final hidden states (B,S,D), total aux loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)
    x = constrain(L.embedding(params["embed"], tokens, cfg.policy),
                  BATCH, "model" if cfg.sequence_parallel else None, None)

    def body(carry, lp):
        x, aux = carry
        x, a, _ = _layer(lp, x, cfg, positions, None, 0)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, aux


def chunked_ce_loss(hidden: Array, unembed_w: Array, targets: Array,
                    cfg: TransformerConfig) -> Array:
    """Cross-entropy without materialising (T, V) logits: scan over
    sequence chunks, unembed + logsumexp per chunk, under remat."""
    b, s, d = hidden.shape
    chunk = min(cfg.ce_chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(tot, inp):
        h, t = inp
        logits = jax.lax.dot_general(
            h.astype(cfg.compute_dtype), unembed_w.astype(cfg.compute_dtype),
            (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        logits = constrain(logits, BATCH, None, "model")  # vocab-sharded
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(t, 0)[..., None], axis=-1)[..., 0]
        valid = t >= 0
        nll = jnp.where(valid, lse - picked, 0.0)
        return (tot[0] + jnp.sum(nll), tot[1] + jnp.sum(valid)), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (tot_nll, tot_cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, tc))
    return tot_nll / jnp.maximum(tot_cnt, 1.0)


def lm_loss(params: Params, batch: dict, cfg: TransformerConfig) -> Array:
    hidden, aux = forward(params, batch["tokens"], cfg)
    loss = chunked_ce_loss(hidden, params["unembed"]["w"], batch["targets"], cfg)
    return loss + 0.01 * aux


def logits_fn(params: Params, tokens: Array, cfg: TransformerConfig) -> Array:
    """(B, S) -> (B, S, V) logits.  Only for small shapes / sampling."""
    hidden, _ = forward(params, tokens, cfg)
    return L.dense(params["unembed"], hidden, cfg.policy).astype(jnp.float32)


# --------------------------------------------------------------------------
# prefill / decode (serving)
# --------------------------------------------------------------------------


def cache_length(cfg: TransformerConfig, max_len: int) -> int:
    return min(cfg.window, max_len) if cfg.window is not None else max_len


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> KVCache:
    s = cache_length(cfg, max_len)
    dt = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.d_head)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                   jnp.zeros((), jnp.int32))


def prefill(params: Params, tokens: Array, cfg: TransformerConfig,
            max_len: int) -> tuple[Array, KVCache]:
    """Process the prompt; returns (last-token logits, primed cache).

    For windowed models the cache keeps the last ``window`` positions
    (ring layout: slot = pos % window)."""
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = L.embedding(params["embed"], tokens, cfg.policy)
    s_cache = cache_length(cfg, max_len)

    def body(carry, lp):
        x, = carry
        x, _, (k, v) = _layer(lp, x, cfg, positions, None, 0)
        if cfg.window is not None and s > s_cache:
            k_keep, v_keep = k[:, -s_cache:], v[:, -s_cache:]
            # ring layout: absolute position p lives at slot p % window
            slots = (jnp.arange(s - s_cache, s)) % s_cache
            k_cache = jnp.zeros((b, s_cache) + k.shape[2:], k.dtype).at[:, slots].set(k_keep)
            v_cache = jnp.zeros((b, s_cache) + v.shape[2:], v.dtype).at[:, slots].set(v_keep)
        else:
            pad = s_cache - s
            k_cache = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad > 0 else k[:, :s_cache]
            v_cache = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad > 0 else v[:, :s_cache]
        # cache layout: batch over data, head dim over model (see
        # repro.distributed.sharding.lm_batch_specs for the rationale)
        k_cache = constrain(k_cache, BATCH, None, None, "model")
        v_cache = constrain(v_cache, BATCH, None, None, "model")
        return (x,), (k_cache, v_cache)

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x,), (k_all, v_all) = jax.lax.scan(body, (x,), params["layers"])
    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.dense(params["unembed"], x, cfg.policy).astype(jnp.float32)
    return logits[:, 0], KVCache(k_all, v_all, jnp.asarray(s, jnp.int32))


def decode_step(params: Params, token: Array, cache: KVCache,
                cfg: TransformerConfig) -> tuple[Array, KVCache]:
    """One decode step.  ``token``: (B,) int32.  Returns (logits (B, V),
    updated cache).  Windowed models use ring-buffer slots."""
    b = token.shape[0]
    pos = cache.length  # scalar: absolute position of the new token
    s_cache = cache.k.shape[2]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = L.embedding(params["embed"], token[:, None], cfg.policy)

    windowed = cfg.window is not None
    slot = (pos % s_cache) if windowed else jnp.minimum(pos, s_cache - 1)

    def body(x, inp):
        lp, kc, vc = inp
        bsz, _, d = x.shape
        pol = cfg.policy
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q = L.dense({"w": lp["attn"]["wq"]}, h, pol).reshape(bsz, 1, cfg.n_heads, cfg.d_head)
        k = L.dense({"w": lp["attn"]["wk"]}, h, pol).reshape(bsz, 1, cfg.n_kv_heads, cfg.d_head)
        v = L.dense({"w": lp["attn"]["wv"]}, h, pol).reshape(bsz, 1, cfg.n_kv_heads, cfg.d_head)
        # decode keeps everything in the cache layout (head dim over
        # model) so the dynamic-update-slice never needs a reshard.
        q = constrain(L.apply_rope(q, positions, cfg.rope_theta),
                      BATCH, None, None, "model")
        k = constrain(L.apply_rope(k, positions, cfg.rope_theta),
                      BATCH, None, None, "model")
        v = constrain(v, BATCH, None, None, "model")
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
        kc = constrain(kc, BATCH, None, None, "model")
        vc = constrain(vc, BATCH, None, None, "model")

        # absolute position of each cache slot
        slots = jnp.arange(s_cache)
        if windowed:
            # slot holds the latest absolute position p <= pos with p % S == slot
            abs_pos = pos - ((pos - slots) % s_cache)
        else:
            abs_pos = slots
        valid = (abs_pos <= pos) & (abs_pos >= 0)  # >=0: unwritten ring slots
        if windowed:
            valid &= abs_pos > pos - cfg.window

        rep = cfg.n_heads // cfg.n_kv_heads
        kr = jnp.repeat(kc, rep, axis=2) if rep > 1 else kc
        vr = jnp.repeat(vc, rep, axis=2) if rep > 1 else vc
        # RoPE on cached keys was applied at insert time with their own
        # positions; scores need no further correction.
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * (cfg.d_head ** -0.5)
        s_ = jnp.where(valid[None, None, None, :], s_, -1e30)
        p_ = jax.nn.softmax(s_, axis=-1)
        attn_out = jnp.einsum("bhqk,bkhd->bqhd", p_, vr.astype(jnp.float32))
        attn_out = attn_out.astype(cfg.compute_dtype).reshape(bsz, 1, cfg.n_heads * cfg.d_head)
        x = x + L.dense({"w": lp["attn"]["wo"]}, attn_out, pol)
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe:
            out, _ = moe_block(lp["moe"], h2.reshape(bsz, d), cfg)
            x = x + out.reshape(bsz, 1, d)
        else:
            x = x + dense_mlp(lp["mlp"], h2, cfg)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        lambda c, inp: body(c, inp), x, (params["layers"], cache.k, cache.v))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.dense(params["unembed"], x, cfg.policy).astype(jnp.float32)
    return logits[:, 0], KVCache(k_new, v_new, pos + 1)

"""Temporal action-recognition head over per-frame patch embeddings.

The second analytics workload (``repro.serving.tasks``): a tubelet of
``clip_len`` consecutive SRoI crops is embedded frame-by-frame with the
ViT patch stem from ``repro.models.vision`` (patch conv + spatial mean
pool), then a small temporal transformer — ``vision._mha_full`` over
the ``clip_len`` frame embeddings — classifies the action.  The model
is deliberately tiny: the serving claim is about scheduling a second
cost curve, not about action-recognition accuracy.

API (mirrors the vision families):
    init_params(rng, cfg) -> params
    apply(params, clips, cfg) -> (B, n_actions) logits
``clips`` is ``(B, T, S, S, 3)`` with ``T == cfg.clip_len``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.vision import _mha_full

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class ActionConfig:
    name: str
    input_size: int
    clip_len: int
    patch: int = 16
    d_model: int = 64
    n_layers: int = 1
    n_heads: int = 2
    d_ff: int = 128
    n_actions: int = 16
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def policy(self) -> L.DtypePolicy:
        return L.DtypePolicy(self.param_dtype, self.compute_dtype)

    @property
    def n_patches(self) -> int:
        return (self.input_size // self.patch) ** 2

    @property
    def n_params(self) -> int:
        d, f = self.d_model, self.d_ff
        per = 4 * d * d + 2 * d * f + 4 * d
        stem = self.patch * self.patch * 3 * d
        return self.n_layers * per + stem + self.clip_len * d \
            + d * self.n_actions

    @property
    def flops_per_clip(self) -> float:
        """Rough forward FLOPs for one tubelet (profile costing)."""
        d, f, t = self.d_model, self.d_ff, self.clip_len
        stem = 2.0 * t * self.n_patches * self.patch ** 2 * 3 * d
        attn = self.n_layers * (2.0 * t * 4 * d * d + 4.0 * t * t * d)
        mlp = self.n_layers * 4.0 * t * d * f
        return stem + attn + mlp + 2.0 * d * self.n_actions


def init_params(rng, cfg: ActionConfig) -> Params:
    dt = cfg.param_dtype
    rngs = jax.random.split(rng, 8)
    d, lyr = cfg.d_model, cfg.n_layers

    def stacked(key, shape, scale):
        return (jax.random.uniform(key, (lyr,) + shape, jnp.float32,
                                   -scale, scale).astype(dt))

    s = (1.0 / d) ** 0.5
    sf = (1.0 / cfg.d_ff) ** 0.5
    return {
        "patch": L.init_conv(rngs[0], cfg.patch, cfg.patch, 3, d, dtype=dt),
        "tpos": jax.random.normal(rngs[1], (1, cfg.clip_len, d),
                                  jnp.float32).astype(dt) * 0.02,
        "layers": {
            "ln1": {"scale": jnp.ones((lyr, d), dt),
                    "bias": jnp.zeros((lyr, d), dt)},
            "wqkv": stacked(rngs[2], (d, 3 * d), s),
            "wo": stacked(rngs[3], (d, d), s),
            "ln2": {"scale": jnp.ones((lyr, d), dt),
                    "bias": jnp.zeros((lyr, d), dt)},
            "w1": stacked(rngs[4], (d, cfg.d_ff), s),
            "b1": jnp.zeros((lyr, cfg.d_ff), dt),
            "w2": stacked(rngs[5], (cfg.d_ff, d), sf),
            "b2": jnp.zeros((lyr, d), dt),
        },
        "ln_f": L.init_layernorm(d, dt),
        "head": L.init_dense(rngs[6], d, cfg.n_actions, dtype=dt),
    }


def apply(params: Params, clips: Array, cfg: ActionConfig) -> Array:
    """Classify tubelets: ``(B, T, S, S, 3)`` -> ``(B, n_actions)``."""
    pol = cfg.policy
    b, t, s, _, _ = clips.shape
    x = L.conv2d(params["patch"], clips.reshape(b * t, s, s, 3),
                 stride=cfg.patch, padding="VALID", policy=pol)
    # spatial mean pool -> one embedding per frame of the clip
    x = x.mean(axis=(1, 2)).reshape(b, t, cfg.d_model)
    x = x + params["tpos"].astype(pol.compute_dtype)

    def body(x, lp):
        h1 = L.layernorm({"scale": lp["ln1"]["scale"],
                          "bias": lp["ln1"]["bias"]}, x)
        x = x + _mha_full(h1, lp["wqkv"], lp["wo"], cfg.n_heads, pol)
        h2 = L.layernorm({"scale": lp["ln2"]["scale"],
                          "bias": lp["ln2"]["bias"]}, x)
        y = L.gelu(L.dense({"w": lp["w1"], "b": lp["b1"]}, h2, pol))
        x = x + L.dense({"w": lp["w2"], "b": lp["b2"]}, y, pol)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.layernorm(params["ln_f"], x)
    return L.dense(params["head"], x.mean(axis=1), pol).astype(jnp.float32)

"""Jitted public wrapper around the SphIoU Pallas kernel.

Handles padding to block multiples (padded boxes get zero-area FoVs,
whose IoU against anything is 0) and the (N, 4) <-> (4, N) transpose
at the API boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sphiou import sphiou as _s


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sphiou_matrix(
    boxes_a: jax.Array,  # (N, 4)
    boxes_b: jax.Array,  # (M, 4)
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(N, M) SphIoU matrix via the Pallas kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    n, m = boxes_a.shape[0], boxes_b.shape[0]
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(8, m))
    pad_n = (-n) % block_n
    pad_m = (-m) % block_m
    a = jnp.pad(boxes_a.astype(jnp.float32), ((0, pad_n), (0, 0)))
    b = jnp.pad(boxes_b.astype(jnp.float32), ((0, pad_m), (0, 0)))
    out = _s.sphiou_pallas(
        a.T, b.T, block_n=block_n, block_m=block_m, interpret=interpret
    )
    return out[:n, :m]


def sphiou_matrix_batch(
    boxes_a: jax.Array,  # (B, N, 4)
    boxes_b: jax.Array,  # (B, M, 4)
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, N, M) per-row SphIoU matrices via the batched Pallas kernel.

    Rows are independent — row ``r`` of the output is
    ``sphiou_matrix(boxes_a[r], boxes_b[r])``.  Padded boxes (zero FoV)
    score IoU 0 against everything, so callers can pad rows to a common
    N and mask afterwards.
    """
    if interpret is None:
        interpret = not _on_tpu()
    _, n, _ = boxes_a.shape
    m = boxes_b.shape[1]
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(8, m))
    pad_n = (-n) % block_n
    pad_m = (-m) % block_m
    a = jnp.pad(boxes_a.astype(jnp.float32), ((0, 0), (0, pad_n), (0, 0)))
    b = jnp.pad(boxes_b.astype(jnp.float32), ((0, 0), (0, pad_m), (0, 0)))
    out = _s.sphiou_pallas_batch(
        jnp.swapaxes(a, 1, 2), jnp.swapaxes(b, 1, 2),
        block_n=block_n, block_m=block_m, interpret=interpret,
    )
    return out[:, :n, :m]

"""Jitted public wrapper around the SphIoU Pallas kernel.

Handles padding to block multiples (padded boxes get zero-area FoVs,
whose IoU against anything is 0) and the (N, 4) <-> (4, N) transpose
at the API boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sphiou import sphiou as _s


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _clamp_block(block: int, n: int) -> int:
    """Clamp a block size to the problem size, lane-aligned.

    The clamp must stay a multiple of 8 (the f32 sublane width): for
    8 < n < block the naive ``min(block, n)`` yields a non-aligned
    Pallas block (e.g. n=100 -> block 100), which Mosaic rejects on
    real TPUs even though interpret mode happens to accept it.
    """
    return min(block, -(-max(8, n) // 8) * 8)


def sphiou_matrix(
    boxes_a: jax.Array,  # (N, 4)
    boxes_b: jax.Array,  # (M, 4)
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
    dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    """(N, M) SphIoU matrix via the Pallas kernel.

    ``dtype`` selects the in-kernel compute precision: ``jnp.bfloat16``
    halves the VPU element width (2x throughput on TPU) at the cost of
    IoU values that can flip the 0.6 keep decision for near-threshold
    pairs (bound measured in ``benchmarks/kernels_bench.py`` and gated
    in ``check_regression.py``).  Inputs and outputs stay f32.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, m = boxes_a.shape[0], boxes_b.shape[0]
    block_n = _clamp_block(block_n, n)
    block_m = _clamp_block(block_m, m)
    pad_n = (-n) % block_n
    pad_m = (-m) % block_m
    a = jnp.pad(boxes_a.astype(jnp.float32), ((0, pad_n), (0, 0)))
    b = jnp.pad(boxes_b.astype(jnp.float32), ((0, pad_m), (0, 0)))
    out = _s.sphiou_pallas(
        a.T, b.T, block_n=block_n, block_m=block_m, interpret=interpret,
        dtype=dtype,
    )
    return out[:n, :m]


def sphiou_matrix_batch(
    boxes_a: jax.Array,  # (B, N, 4)
    boxes_b: jax.Array,  # (B, M, 4)
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
    dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    """(B, N, M) per-row SphIoU matrices via the batched Pallas kernel.

    Rows are independent — row ``r`` of the output is
    ``sphiou_matrix(boxes_a[r], boxes_b[r])``.  Padded boxes (zero FoV)
    score IoU 0 against everything, so callers can pad rows to a common
    N and mask afterwards.  ``dtype`` selects the in-kernel compute
    precision (see :func:`sphiou_matrix`).
    """
    if interpret is None:
        interpret = not _on_tpu()
    _, n, _ = boxes_a.shape
    m = boxes_b.shape[1]
    block_n = _clamp_block(block_n, n)
    block_m = _clamp_block(block_m, m)
    pad_n = (-n) % block_n
    pad_m = (-m) % block_m
    a = jnp.pad(boxes_a.astype(jnp.float32), ((0, 0), (0, pad_n), (0, 0)))
    b = jnp.pad(boxes_b.astype(jnp.float32), ((0, 0), (0, pad_m), (0, 0)))
    out = _s.sphiou_pallas_batch(
        jnp.swapaxes(a, 1, 2), jnp.swapaxes(b, 1, 2),
        block_n=block_n, block_m=block_m, interpret=interpret,
        dtype=dtype,
    )
    return out[:, :n, :m]

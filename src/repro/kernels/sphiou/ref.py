"""Pure-jnp oracle for the SphIoU kernel: the framework reference
``repro.core.sphere.sph_iou_matrix``."""

from __future__ import annotations

from repro.core.sphere import sph_iou_matrix as sphiou_ref

__all__ = ["sphiou_ref"]

"""Pure-jnp oracles for the SphIoU kernels: the framework reference
``repro.core.sphere.sph_iou_matrix`` and its vmapped batched twin."""

from __future__ import annotations

import jax

from repro.core.sphere import sph_iou_matrix as sphiou_ref

# (B, N, 4) x (B, M, 4) -> (B, N, M); oracle for ``sphiou_pallas_batch``.
sphiou_ref_batch = jax.vmap(sphiou_ref)

__all__ = ["sphiou_ref", "sphiou_ref_batch"]

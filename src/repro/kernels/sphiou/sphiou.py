"""Pallas TPU kernel: pairwise spherical IoU matrix.

Spherical NMS (paper section IV-C, threshold 0.6) needs the N x M
SphIoU matrix; at pod scale the server batches thousands of SphBBs per
scheduling tick, so the O(N*M) trig work is a genuine VPU hot-spot.

Layout: boxes are passed *transposed* as (4, N) / (4, M) so the box
axis lands on the TPU lane dimension (the parameter axis of length 4
would otherwise waste a 128-lane register).  Each program computes one
(BN, BM) IoU tile; the rotation of box B's centre into box A's tangent
frame is expanded into explicit scalar trigonometry (no 3x3 matmuls),
which maps 1:1 onto VPU elementwise ops.

The math mirrors ``repro.core.sphere.sph_iou`` exactly:
  d_in_a = Ry(phi_a) @ Rz(-theta_a) @ dir(theta_b, phi_b)
  dlon, dlat = cart_to_sph(d_in_a)
  intersection = lon-overlap * (sin(lat_hi) - sin(lat_lo))
  area = 2 * dtheta * sin(dphi / 2)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersection(ta, pa, ha, va, tb, pb, hb, vb):
    """Intersection with box A rotated to the origin (one direction)."""
    dt = tb - ta
    cpa, spa = jnp.cos(pa), jnp.sin(pa)
    cpb, spb = jnp.cos(pb), jnp.sin(pb)
    cdt = jnp.cos(dt)

    # B's centre direction expressed in A's tangent frame
    x = cpa * cpb * cdt + spa * spb
    y = cpb * jnp.sin(dt)
    z = -spa * cpb * cdt + cpa * spb
    dlon = jnp.arctan2(y, x)
    dlat = jnp.arcsin(jnp.clip(z, -1.0, 1.0))

    lon_lo = jnp.maximum(-ha, dlon - hb)
    lon_hi = jnp.minimum(ha, dlon + hb)
    lat_lo = jnp.maximum(-va, dlat - vb)
    lat_hi = jnp.minimum(va, dlat + vb)

    lon_w = jnp.maximum(lon_hi - lon_lo, 0.0)
    lat_w = jnp.where(lat_hi > lat_lo, jnp.sin(lat_hi) - jnp.sin(lat_lo), 0.0)
    return lon_w * jnp.maximum(lat_w, 0.0)


def _iou_tile(a, b, dtype=jnp.float32):
    """(4, BN) x (4, BM) -> (BN, BM) SphIoU tile (shared kernel body).

    ``dtype`` is the compute precision: bf16 halves the VPU element
    width for ~2x elementwise throughput.  Inputs arrive f32 (memory
    layout stays sublane-8 aligned); the cast happens in-register and
    the tile is emitted back as f32.
    """
    a = a.astype(dtype)
    b = b.astype(dtype)
    ta, pa = a[0, :], a[1, :]
    ha, va = a[2, :] * 0.5, a[3, :] * 0.5  # half FoVs
    tb, pb = b[0, :], b[1, :]
    hb, vb = b[2, :] * 0.5, b[3, :] * 0.5

    ta, pa, ha, va = (x[:, None] for x in (ta, pa, ha, va))  # (BN, 1)
    tb, pb, hb, vb = (x[None, :] for x in (tb, pb, hb, vb))  # (1, BM)

    # symmetrised intersection (matches repro.core.sphere.sph_iou)
    inter = 0.5 * (_intersection(ta, pa, ha, va, tb, pb, hb, vb)
                   + _intersection(tb, pb, hb, vb, ta, pa, ha, va))

    area_a = 4.0 * ha * jnp.sin(va)  # 2 * dtheta * sin(dphi/2)
    area_b = 4.0 * hb * jnp.sin(vb)
    iou = inter / jnp.maximum(area_a + area_b - inter, 1e-12)
    return iou.astype(jnp.float32)


def _kernel(a_ref, b_ref, out_ref, *, dtype):
    # a_ref: (4, BN), b_ref: (4, BM) -> out_ref: (BN, BM)
    out_ref[...] = _iou_tile(a_ref[...], b_ref[...], dtype=dtype)


def _kernel_batch(a_ref, b_ref, out_ref, *, dtype):
    # a_ref: (1, 4, BN), b_ref: (1, 4, BM) -> out_ref: (1, BN, BM)
    out_ref[0] = _iou_tile(a_ref[0], b_ref[0], dtype=dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret", "dtype"))
def sphiou_pallas(
    boxes_a_t: jax.Array,  # (4, N) f32
    boxes_b_t: jax.Array,  # (4, M) f32
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
    dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    n, m = boxes_a_t.shape[1], boxes_b_t.shape[1]
    grid = (pl.cdiv(n, block_n), pl.cdiv(m, block_m))
    return pl.pallas_call(
        functools.partial(_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, block_n), lambda i, j: (0, i)),
            pl.BlockSpec((4, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(boxes_a_t, boxes_b_t)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret", "dtype"))
def sphiou_pallas_batch(
    boxes_a_t: jax.Array,  # (B, 4, N) f32
    boxes_b_t: jax.Array,  # (B, 4, M) f32
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
    dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    """Per-row SphIoU matrices: (B, 4, N) x (B, 4, M) -> (B, N, M).

    The batch axis is the leading (slowest-varying) grid dimension so
    each row's tiles stream through VMEM contiguously; the tile body is
    identical to the unbatched kernel.  One dispatch covers the whole
    pod tick instead of one ``pallas_call`` per stream.
    """
    b, _, n = boxes_a_t.shape
    m = boxes_b_t.shape[2]
    grid = (b, pl.cdiv(n, block_n), pl.cdiv(m, block_m))
    return pl.pallas_call(
        functools.partial(_kernel_batch, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4, block_n), lambda r, i, j: (r, 0, i)),
            pl.BlockSpec((1, 4, block_m), lambda r, i, j: (r, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n, block_m), lambda r, i, j: (r, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n, m), jnp.float32),
        interpret=interpret,
    )(boxes_a_t, boxes_b_t)

"""Pallas TPU kernel: flash attention (full / causal / sliding-window).

Serves the LM-family architectures of the framework: causal training
attention, prefill, KV-cache decode, and the sliding-window variant
that makes ``long_500k`` feasible for mixtral-style models (attention
cost O(seq * window) with a window-bounded KV cache).

Design: classic flash-attention-2 schedule adapted to the TPU grid —
  * grid = (batch*heads, q_blocks, kv_blocks) with the kv axis
    innermost and marked "arbitrary" (sequential) so the running
    max / denominator / accumulator live in VMEM scratch across the
    kv sweep;
  * each (BQ, BK) tile does one MXU matmul for the scores and one for
    the value gather, with the online-softmax rescale between them on
    the VPU (all f32 accumulation regardless of input dtype);
  * causal/window tiles that fall entirely outside the band are
    skipped via ``pl.when`` — with window w the per-row work drops
    from O(S) to O(w), which is what the roofline for long_500k needs;
  * ``q_offset`` aligns query positions when Sq != Skv (decode /
    chunked prefill): absolute q position = q_offset + local index.

Block sizes default to (128, 128) — MXU-native tiles; the wrapper pads
ragged tails and masks padded kv columns via ``kv_len``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed CompilerParams <-> TPUCompilerParams across jax releases
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, BQ, D)
    k_ref,  # (1, BK, D)
    v_ref,  # (1, BK, D)
    o_ref,  # (1, BQ, D)
    m_ref,  # (BQ, 1) f32 scratch
    l_ref,  # (BQ, 1) f32 scratch
    acc_ref,  # (BQ, D) f32 scratch
    *,
    scale: float,
    causal: bool,
    window: int | None,
    kv_len: int,
    q_offset: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # -- static-ish band check: can this (qi, ki) tile contribute? --
    q_lo = q_offset + qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1
    live = k_lo <= jnp.minimum(q_hi, kv_len - 1) if causal else k_lo < kv_len
    if window is not None:
        live = jnp.logical_and(live, k_hi >= q_lo - (window - 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # (BQ, 1)
        p = jnp.exp(s - m_new)  # (BQ, BK)
        p = jnp.where(mask, p, 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale",
        "causal",
        "window",
        "kv_len",
        "q_offset",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def mha_pallas(
    q: jax.Array,  # (BH, Sq_pad, D)
    k: jax.Array,  # (BH, Skv_pad, D)
    v: jax.Array,  # (BH, Skv_pad, D)
    *,
    scale: float,
    causal: bool,
    window: int | None,
    kv_len: int,
    q_offset: int,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    n_q = sq // block_q
    n_kv = skv // block_k
    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        kv_len=kv_len,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle for the flash attention kernel.

Materialises the full (Sq, Skv) score matrix in f32 — O(S^2) memory,
fine for test shapes, intractable for the long-context cells (which is
the point of the kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Skv, D)
    v: jax.Array,  # (BH, Skv, D)
    *,
    scale: float,
    causal: bool = False,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    sq, skv = q.shape[1], k.shape[1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)  # rows fully masked -> 0, not NaN
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

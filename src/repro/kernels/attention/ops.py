"""Jitted public wrapper for flash attention.

Handles: GQA head broadcasting, (B, S, H, D) <-> (BH, S, D) layout,
padding Sq/Skv to block multiples with correct masking, block-size
selection for short sequences, and interpret-mode fallback off-TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.attention import attention as _a
from repro.kernels.attention.ref import mha_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block(size: int, preferred: int) -> int:
    if size >= preferred:
        return preferred
    b = 1
    while b * 2 <= size:
        b *= 2
    return b


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = _a.DEFAULT_BLOCK_Q,
    block_k: int = _a.DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    """Multi-head attention with optional GQA, causality and window.

    ``q_offset`` is the absolute position of q[0] (used at decode time,
    where Sq=1 and the KV cache holds ``Skv`` entries).
    Returns (B, Sq, Hq, D) in q's dtype.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if scale is None:
        scale = d ** -0.5
    assert hq % hkv == 0, "GQA requires query heads to be a multiple of kv heads"
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    # (B, S, H, D) -> (B*H, S, D)
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * hq, x.shape[1], d)

    qf, kf, vf = fold(q), fold(k), fold(v)

    bq = _pick_block(sq, block_q)
    bk = _pick_block(skv, block_k)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    out = _a.mha_pallas(
        qf,
        kf,
        vf,
        scale=scale,
        causal=causal,
        window=window,
        kv_len=skv,
        q_offset=q_offset,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
    )
    out = out[:, :sq, :]
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Oracle with the same (B, S, H, D) GQA API."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * hq, x.shape[1], d)

    out = mha_ref(
        fold(q), fold(k), fold(v), scale=scale, causal=causal, window=window,
        q_offset=q_offset,
    )
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)

"""Pure-jnp oracle for the gnomonic resampling kernel.

Delegates to :func:`repro.core.projection.sample_erp_bilinear`, which is
the framework's reference sampler — the kernel must match it bit-for-bit
up to float associativity.
"""

from __future__ import annotations

import jax

from repro.core.projection import gnomonic_coords, sample_erp_bilinear


def gnomonic_sample_ref(erp: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    return sample_erp_bilinear(erp, u, v)


__all__ = ["gnomonic_sample_ref", "gnomonic_coords"]

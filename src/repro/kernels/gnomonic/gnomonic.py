"""Pallas TPU kernel: gnomonic ERP -> PI bilinear resampling.

This is OmniSense's preprocessing hot-spot (the paper spends a profiled
d^P per SRoI on OpenCV ``remap``).  The GPU-idiomatic formulation is an
arbitrary global gather; that ports badly to TPU, so the kernel is
restructured around the observation that the gnomonic map is *smooth*:
for a strip of output rows, the source ERP pixels live in a narrow band
of ERP rows.

Design (HBM -> VMEM -> VPU):

  * the wrapper computes the sampling map (u, v) on the host (it is a
    function of SRoI geometry only, never of frame data), derives a
    per-output-strip source row offset, and the maximum band height
    ``src_rows`` across strips (static);
  * grid = one program per output row strip; the per-strip row offset
    arrives via scalar prefetch (SMEM) and selects a dynamic slice of
    the ERP held in ``pl.ANY`` (compiler-placed / HBM) memory — a
    contiguous DMA, not a gather;
  * in-VMEM the strip does the 4-tap bilinear blend vectorised on the
    VPU; the only gather left is *within* the VMEM band (``jnp.take``
    over src_rows * width elements), which is the TPU-native place for
    irregular access.  The ERP seam is handled by pre-padding two
    columns so u+1 never wraps.

VMEM budget: ``src_rows * (erp_w + 2) * channels * 4`` bytes; the
wrapper checks it against a configurable cap and falls back to the
pure-jnp oracle for pathological strips (e.g. pole-centred PIs whose
row band degenerates to the whole frame).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed MemorySpace <-> TPUMemorySpace across jax releases
_MEMORY_SPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# Conservative per-core VMEM budget for the source band (bytes).
VMEM_CAP_BYTES = 8 * 1024 * 1024
SEAM_PAD = 2  # columns appended on the right so u0+1 never wraps


def _kernel(
    row_off_ref,  # scalar prefetch: (n_strips,) int32 in SMEM
    u_ref,  # (strip_h, out_w) f32 VMEM
    v_ref,  # (strip_h, out_w) f32 VMEM
    erp_ref,  # (erp_h, erp_w + SEAM_PAD, c) in ANY/HBM
    out_ref,  # (strip_h, out_w, c) VMEM
    *,
    src_rows: int,
    erp_h: int,
):
    strip_idx = pl.program_id(0)
    row_off = row_off_ref[strip_idx]

    band = erp_ref[pl.ds(row_off, src_rows), :, :]  # (src_rows, wp, c)
    src_r, wp, c = band.shape

    u = u_ref[...]
    v = v_ref[...]
    u0 = jnp.floor(u)
    v0 = jnp.floor(v)
    fu = (u - u0)[..., None]
    fv = (v - v0)[..., None]

    u0i = u0.astype(jnp.int32)  # in [0, erp_w - 1] by construction
    u1i = u0i + 1  # reaches erp_w -> covered by seam pad
    v0i = jnp.clip(v0.astype(jnp.int32), 0, erp_h - 1) - row_off
    v1i = jnp.clip(v0.astype(jnp.int32) + 1, 0, erp_h - 1) - row_off

    flat = band.reshape(src_r * wp, c)
    shp = u.shape

    def tap(rows, cols):
        idx = (rows * wp + cols).reshape(-1)
        return jnp.take(flat, idx, axis=0).reshape(shp + (c,))

    p00 = tap(v0i, u0i)
    p01 = tap(v0i, u1i)
    p10 = tap(v1i, u0i)
    p11 = tap(v1i, u1i)

    top = p00 * (1.0 - fu) + p01 * fu
    bot = p10 * (1.0 - fu) + p11 * fu
    out_ref[...] = (top * (1.0 - fv) + bot * fv).astype(out_ref.dtype)


def plan_strips(
    v_map: np.ndarray, erp_h: int, strip_h: int
) -> tuple[np.ndarray, int]:
    """Host-side planning: per-strip source row offsets + band height.

    ``v_map``: concrete (out_h, out_w) float v coordinates.
    Returns (row_off[n_strips] int32, src_rows).
    """
    out_h = v_map.shape[0]
    n_strips = out_h // strip_h
    v0 = np.clip(np.floor(v_map).astype(np.int64), 0, erp_h - 1)
    v1 = np.clip(np.floor(v_map).astype(np.int64) + 1, 0, erp_h - 1)
    offs = np.zeros((n_strips,), dtype=np.int32)
    extent = 1
    for s in range(n_strips):
        lo = int(v0[s * strip_h : (s + 1) * strip_h].min())
        hi = int(v1[s * strip_h : (s + 1) * strip_h].max())
        offs[s] = lo
        extent = max(extent, hi - lo + 1)
    src_rows = min(int(2 ** int(np.ceil(np.log2(max(extent, 1))))), erp_h)
    # keep the band inside the frame
    offs = np.minimum(offs, max(erp_h - src_rows, 0)).astype(np.int32)
    return offs, src_rows


@functools.partial(
    jax.jit, static_argnames=("src_rows", "strip_h", "erp_h", "interpret")
)
def gnomonic_pallas(
    erp_padded: jax.Array,  # (erp_h, erp_w + SEAM_PAD, c)
    u: jax.Array,  # (out_h, out_w) f32
    v: jax.Array,  # (out_h, out_w) f32
    row_off: jax.Array,  # (n_strips,) int32
    *,
    src_rows: int,
    strip_h: int,
    erp_h: int,
    interpret: bool = False,
) -> jax.Array:
    out_h, out_w = u.shape
    c = erp_padded.shape[-1]
    n_strips = out_h // strip_h

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_strips,),
        in_specs=[
            pl.BlockSpec((strip_h, out_w), lambda i, *_: (i, 0)),
            pl.BlockSpec((strip_h, out_w), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=_MEMORY_SPACE.ANY),
        ],
        out_specs=pl.BlockSpec((strip_h, out_w, c), lambda i, *_: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, src_rows=src_rows, erp_h=erp_h),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, c), erp_padded.dtype),
        interpret=interpret,
    )(row_off, u, v, erp_padded)

"""Jitted public wrapper around the gnomonic Pallas kernel.

``gnomonic_sample`` plans the strip decomposition on the host (the
sampling map is geometry, not data), checks the VMEM budget, and
dispatches either to the Pallas kernel or — for pathological bands —
to the jnp oracle.  Interpret mode is used automatically off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.projection import gnomonic_coords, sample_erp_bilinear
from repro.kernels.gnomonic import gnomonic as _g
from repro.kernels.gnomonic.ref import gnomonic_sample_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_strip_h(out_h: int) -> int:
    for cand in (8, 4, 2, 1):
        if out_h % cand == 0:
            return cand
    return 1


def gnomonic_sample(
    erp: jax.Array,
    u_map: np.ndarray,
    v_map: np.ndarray,
    *,
    interpret: bool | None = None,
    vmem_cap: int = _g.VMEM_CAP_BYTES,
) -> jax.Array:
    """Sample ``erp`` (H, W, C) at host-concrete maps (out_h, out_w).

    Returns (out_h, out_w, C) with identical semantics to
    :func:`repro.core.projection.sample_erp_bilinear` (horizontal wrap,
    vertical clamp, pixel-centre bilinear).
    """
    u_map = np.asarray(u_map, dtype=np.float32)
    v_map = np.asarray(v_map, dtype=np.float32)
    erp_h, erp_w, c = erp.shape
    out_h, out_w = u_map.shape
    if interpret is None:
        interpret = not _on_tpu()

    strip_h = _pick_strip_h(out_h)
    row_off, src_rows = _g.plan_strips(v_map, erp_h, strip_h)
    band_bytes = src_rows * (erp_w + _g.SEAM_PAD) * c * erp.dtype.itemsize
    if band_bytes > vmem_cap:
        # pole-centred / degenerate PI: band would blow VMEM; use oracle
        return gnomonic_sample_ref(erp, jnp.asarray(u_map), jnp.asarray(v_map))

    # wrap u into [0, erp_w) exactly as the oracle's mod does, then pad
    # the seam so u0 + 1 never leaves the array.
    u_wrapped = np.mod(u_map, erp_w).astype(np.float32)
    # floor(u) of values in [erp_w - 1, erp_w) is erp_w - 1; +1 hits the pad
    erp_padded = jnp.concatenate([erp, erp[:, : _g.SEAM_PAD, :]], axis=1)

    return _g.gnomonic_pallas(
        erp_padded,
        jnp.asarray(u_wrapped),
        jnp.asarray(v_map),
        jnp.asarray(row_off),
        src_rows=src_rows,
        strip_h=strip_h,
        erp_h=erp_h,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("out_size",))
def _project_srois_jit(
    erps: jax.Array,     # (B, H, W, C)
    centers: jax.Array,  # (B, 2) (theta, phi)
    fovs: jax.Array,     # (B, 2) (h, v) radians
    *,
    out_size: tuple[int, int],
) -> jax.Array:
    """ONE dispatch for a whole tick's crops: vmapped gnomonic coords +
    bilinear ERP sampling.  Rows are independent, so the same compiled
    program called at B=1 produces bit-identical rows to the B=k call —
    the invariant the fused-tick exactness tests pin.
    """
    erp_size = erps.shape[1:3]

    def one(erp, center, fov):
        u, v = gnomonic_coords(center[0], center[1], (fov[0], fov[1]),
                               out_size, erp_size)
        return sample_erp_bilinear(erp, u, v)

    return jax.vmap(one)(erps, centers, fovs)


def project_srois_batched(
    frames, centers, fovs, out_size: tuple[int, int]
) -> jax.Array:
    """Batched SRoI -> PI projection: (B frames, B regions) -> (B, S, S, C).

    The staged path issues one ``project_sroi`` dispatch per crop (each
    itself several kernels: coords, rotation, sampling) and re-enters
    Python between crops; this entry stacks the tick's frames and region
    geometry once and projects every crop in a single jitted program.
    The jit cache is keyed by (B, ERP shape, out_size) — callers pad B
    to a ``ShapeBuckets`` batch rung to bound compile counts.

    ``frames``: sequence of (H, W, C) arrays (one per crop — repeats
    are fine and common); ``centers``/``fovs``: (B, 2) array-likes.
    """
    erps = jnp.stack([jnp.asarray(f) for f in frames])
    centers = jnp.asarray(np.asarray(centers, dtype=np.float32))
    fovs = jnp.asarray(np.asarray(fovs, dtype=np.float32))
    return _project_srois_jit(erps, centers, fovs,
                              out_size=(int(out_size[0]), int(out_size[1])))


def project_sroi_kernel(
    erp: jax.Array,
    center_theta: float,
    center_phi: float,
    fov: tuple[float, float],
    out_size: tuple[int, int],
    **kw,
) -> jax.Array:
    """SRoI -> PI via the Pallas path (host-concrete geometry)."""
    u, v = gnomonic_coords(
        jnp.asarray(center_theta),
        jnp.asarray(center_phi),
        fov,
        out_size,
        erp.shape[:2],
    )
    return gnomonic_sample(erp, np.asarray(u), np.asarray(v), **kw)

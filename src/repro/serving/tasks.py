"""Analytics task registry — multi-task serving.

The paper's framework (SRoI pruning + resource-aware model scaling) is
task-agnostic; this module makes the serving stack agnostic too.  An
:class:`AnalyticsTask` declares everything the pod needs to serve one
workload:

  * its **variant ladder** (``ModelProfile`` rungs with gav tables),
  * its **latency curve** (an ``OmniSenseLatencyModel`` or subclass —
    the pricing the allocator, queues and tick model share),
  * its **accuracy proxy** (the ``serving.evaluation`` metric name),
  * its **batched backend entry** (oracle factory for benches/replay),
  * its **result kind** (what ``finish_frame`` hands back).

``detection`` is registered first by pure delegation to the existing
factories (``profiles.make_ladder`` / ``OmniSenseLatencyModel`` /
``OracleBackend`` / ``OmniSenseLoop``), so detection-only serving built
through the registry is bit-identical to the pre-registry construction
— pinned by the replay corpora.

``action_recognition`` is the second task: consecutive per-stream SRoI
crops window into tubelets (the per-region window lives in the backend)
and a small temporal head (``repro.models.action``) classifies them.
Its P1-P4 ladder scales clip length x resolution, so its cost curve has
a genuinely different shape from detection's — the first real test that
``solve_pod`` generalises past one cost curve.  Action results are
ordinary ``sroi.Detection`` records whose ``category`` is the action
class, so NMS, digests, history feedback and telemetry are unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import sroi as sroi_mod
from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import SyntheticVideo
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.scheduler import (OmniSenseLatencyModel, OracleBackend,
                                     _angular_distance, _fully_enclosed,
                                     _in_sroi)

N_ACTION_CLASSES = 16

# P1-P4 action ladder: (name, clip length, crop resolution).  Cost
# scales with clip * resolution^2 — a different shape from detection's
# single-frame resolution ladder.
ACTION_LADDER: tuple[tuple[str, int, int], ...] = (
    ("act-p1-4x96", 4, 96),
    ("act-p2-8x96", 8, 96),
    ("act-p3-8x128", 8, 128),
    ("act-p4-16x128", 16, 128),
)
ACTION_CLIP_LEN: dict[str, int] = {n: c for n, c, _ in ACTION_LADDER}

# per-frame forward seconds at 96x96 on the edge tier; rungs scale by
# clip length and pixel count (see models/action.py flops_per_clip)
_ACTION_FRAME_S96 = 0.03
_ACTION_MODEL_MB = (9, 9, 14, 14)


def action_ladder(n_categories: int = acc_mod.N_CATEGORIES, seed: int = 7,
                  quality_penalty: float = 1.0) -> list[acc_mod.ModelProfile]:
    """The action task's P1-P4 ``ModelProfile`` ladder.

    gav tables share the detection table's synthetic generator (longer
    clips / higher resolution -> higher per-class accuracy) under a
    task-specific seed; ``infer_s`` is the full-tubelet forward.
    """
    gav = acc_mod.synthetic_gav_table(len(ACTION_LADDER), n_categories,
                                      seed=seed)
    out = []
    for i, (name, clip, res) in enumerate(ACTION_LADDER):
        infer_s = _ACTION_FRAME_S96 * clip * (res / 96.0) ** 2
        out.append(acc_mod.ModelProfile(
            name=name, index=i + 1, input_size=res, location="edge",
            gav=gav[i] * quality_penalty, infer_s=infer_s,
            model_bytes=_ACTION_MODEL_MB[i] * 2 ** 20))
    return out


class ActionLatencyModel(OmniSenseLatencyModel):
    """Detection's latency curve generalised to tubelets.

    Projection/encode run once per clip frame and the remote payload is
    the whole tubelet, so ``_pre``/``_inf`` scale by the variant's clip
    length.  Everything downstream — batching, sharding, queue costs,
    tick hooks — is inherited, so a mixed-task pod's tick model resolves
    to the SAME curve functions for both tasks.
    """

    def __init__(self, costs, network, clip_len: dict[str, int],
                 profiler=None, batch_marginal: float = 0.15):
        super().__init__(costs, network, profiler=profiler,
                         batch_marginal=batch_marginal)
        self.clip_len = dict(clip_len)

    def _clip(self, variant: acc_mod.ModelProfile) -> int:
        return self.clip_len.get(variant.name, 1)

    def _pre(self, variant: acc_mod.ModelProfile) -> float:
        return super()._pre(variant) * self._clip(variant)

    def _inf(self, variant: acc_mod.ModelProfile) -> float:
        t = variant.infer_s
        if variant.location != "device":
            n_bytes = (self._clip(variant) * variant.input_size ** 2
                       * self.costs.bytes_per_pixel)
            est = self.profiler.estimate(variant.name)
            if est == self.profiler.initial_s:
                t += self.network.delivery_delay(n_bytes)
            else:
                t += est
        return t


@dataclasses.dataclass
class OracleActionBackend:
    """Ground-truth-driven action sampling (``OracleBackend``'s twin).

    Each ground-truth object carries a deterministic action class; the
    variant's gav is the top-1 hit probability, discounted by how full
    the region's tubelet window is (a fresh window has seen too few
    frames for the clip length, so recognition warms up as consecutive
    crops of the same region accumulate).  Results are ``Detection``
    records with ``category`` = action class.
    """

    video: SyntheticVideo
    clip_len: dict[str, int] = dataclasses.field(
        default_factory=lambda: dict(ACTION_CLIP_LEN))
    frame: int = 0
    seed: int = 0
    fp_rate: float = 0.02
    n_actions: int = N_ACTION_CLASSES
    semantic_batch = True  # class-level: not a dataclass field

    def __post_init__(self):
        # region key -> (last frame observed, consecutive-run length)
        self._windows: dict = {}

    def set_frame(self, frame: int) -> None:
        self.frame = frame

    def _window_fill(self, region: sroi_mod.SRoI,
                     variant: acc_mod.ModelProfile) -> float:
        """Advance the region's tubelet window; return fill in (0, 1].

        Idempotent per frame (a repeat observation of the same frame —
        the batched-vs-inline equivalence path — leaves the run
        unchanged) and monotone under carried-request rewinds.
        """
        key = (round(region.center[0], 1), round(region.center[1], 1))
        last, run = self._windows.get(key, (-2, 0))
        if self.frame == last + 1:
            run += 1
        elif self.frame > last + 1:
            run = 1
        self._windows[key] = (max(last, self.frame), run)
        clip = self.clip_len.get(variant.name, 1)
        return min(run, clip) / clip

    def _action_of(self, det: sroi_mod.Detection, okey: int) -> int:
        return (det.category * 7 + okey) % self.n_actions

    def _recognise(self, candidates, variant, region_tag: int,
                   fill: float = 1.0, ref_sr: float = 4 * math.pi,
                   region: sroi_mod.SRoI | None = None):
        out = []
        n_cat = len(variant.gav) // 3
        fp_rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.frame) * 137 + variant.index * 11
            + region_tag)
        for det in candidates:
            okey = hash((round(float(det.box[2]), 6),
                         round(float(det.box[3]), 6), det.category))
            action = self._action_of(det, okey)
            # temporally-coherent sampling, same idiom as the detection
            # oracle: the hit decision re-randomises every few frames
            rng = np.random.default_rng(
                (self.seed * 5_915_587 + okey) % (2 ** 31)
                + variant.index * 89 + (self.frame // 4) * 29)
            level = sroi_mod.size_level_in(det, ref_sr, acc_mod.SMALL_NOA,
                                           acc_mod.MEDIUM_NOA)
            acc = float(variant.gav[level * n_cat + action % n_cat]) * fill
            if region is not None:
                if not _fully_enclosed(det, region):
                    acc *= 0.3
                d = _angular_distance(det, region)
                acc *= max(math.cos(min(d, math.pi / 2)), 0.15) ** 2
            if rng.uniform() < acc:
                jitter = (1.0 - acc) * 0.1
                box = det.box.copy()
                box[0] += rng.normal(0, jitter * box[2])
                box[1] += rng.normal(0, jitter * box[3])
                out.append(sroi_mod.Detection(
                    box=box, category=action,
                    score=float(np.clip(acc + rng.normal(0, 0.05),
                                        0.05, 1.0))))
        if fp_rng.uniform() < self.fp_rate and candidates:
            ref = candidates[0]
            out.append(sroi_mod.Detection(
                box=ref.box * np.array([1.0, 1.0, 0.7, 0.7]),
                category=int(fp_rng.integers(0, self.n_actions)), score=0.3))
        return out

    def infer_sroi(self, frame_img, region: sroi_mod.SRoI,
                   variant: acc_mod.ModelProfile):
        del frame_img
        gt = self.video.visible_objects(self.frame)
        cands = [d for d in gt if _in_sroi(d, region)]
        tag = hash((round(region.center[0], 3),
                    round(region.center[1], 3))) % 9973
        fill = self._window_fill(region, variant)
        return self._recognise(
            cands, variant, tag, fill=fill,
            ref_sr=sroi_mod.region_solid_angle(*region.fov), region=region)

    def infer_srois_batched(self, items, variant: acc_mod.ModelProfile):
        """Semantic batch: bit-identical to per-request calls."""
        return [self.infer_sroi(frame_img, region, variant)
                for frame_img, region in items]

    def infer_erp(self, frame_img, variant: acc_mod.ModelProfile):
        """Full-ERP pass (discovery): distortion demotes the gav, no
        tubelet warm-up discount (the ERP sees every region)."""
        del frame_img
        gt = self.video.visible_objects(self.frame)
        third = len(variant.gav) // 3
        demoted = dataclasses.replace(
            variant, gav=np.concatenate([
                variant.gav[:third] * 0.3,
                variant.gav[third: 2 * third] * 0.6,
                variant.gav[2 * third:] * 0.9,
            ]))
        return self._recognise(gt, demoted, region_tag=0)


class JaxActionBackend:
    """Real path: gnomonic crops window into tubelets, one jitted
    temporal-head forward per (variant, padded-batch) bucket.

    Mirrors ``JaxDetectorBackend``'s compile discipline: the jit cache
    is keyed by (variant, padded batch), ``trace_count`` increments at
    trace time only, so a serving lifetime compiles at most
    ``len(buckets) * n_variants`` programs.
    """

    def __init__(self, cfgs, params_per_variant, buckets=None,
                 use_kernel: bool = True):
        from repro.serving.batching import ShapeBuckets

        self.cfgs = list(cfgs)
        self.params = list(params_per_variant)
        self.use_kernel = use_kernel
        self.buckets = buckets or ShapeBuckets(
            resolutions=tuple(sorted({c.input_size for c in self.cfgs})))
        self._jit_cache: dict = {}
        self.trace_count = 0  # incremented at trace time only
        self._clips: dict = {}  # (variant idx, region key) -> recent crops
        self.frame = 0

    def set_frame(self, frame: int) -> None:
        self.frame = frame

    def _project(self, frame_img, region: sroi_mod.SRoI, size: int):
        import jax.numpy as jnp

        if self.use_kernel:
            from repro.kernels.gnomonic import ops as gno_ops

            return gno_ops.project_sroi_kernel(
                jnp.asarray(frame_img), region.center[0], region.center[1],
                region.fov, (size, size))
        from repro.core.projection import project_sroi

        return project_sroi(jnp.asarray(frame_img),
                            jnp.asarray(region.center[0]),
                            jnp.asarray(region.center[1]),
                            region.fov, (size, size))

    def _window(self, key, pi, clip_len: int):
        """Append the crop to the region's window, return the tubelet
        (short windows left-pad by repeating the oldest crop)."""
        win = self._clips.setdefault(key, [])
        win.append(np.asarray(pi))
        del win[:-clip_len]
        frames = [win[0]] * (clip_len - len(win)) + win
        return np.stack(frames)

    def _batched_fn(self, idx: int, b_pad: int):
        import jax

        key = (idx, b_pad)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfgs[idx]

            def run(params, clips):
                from repro.models import action as act_mod

                self.trace_count += 1
                return act_mod.apply(params, clips, cfg)

            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn

    def infer_srois_batched(self, items, variant: acc_mod.ModelProfile):
        import jax.numpy as jnp

        idx = variant.index - 1
        cfg = self.cfgs[idx]
        size = cfg.input_size
        clips = []
        for frame_img, region in items:
            pi = self._project(frame_img, region, size)
            key = (idx, round(region.center[0], 1),
                   round(region.center[1], 1))
            clips.append(self._window(key, pi, cfg.clip_len))
        b = len(clips)
        b_pad = self.buckets.pad_batch(b)
        batch = np.zeros((b_pad, cfg.clip_len, size, size, 3), np.float32)
        batch[:b] = np.stack(clips)
        logits = np.asarray(
            self._batched_fn(idx, b_pad)(self.params[idx],
                                         jnp.asarray(batch)))[:b]
        out = []
        for row, (_, region) in zip(logits, items):
            e = np.exp(row - row.max())
            probs = e / e.sum()
            cat = int(np.argmax(probs))
            ct, cp = region.center
            fh, fv = region.fov
            out.append([sroi_mod.Detection(
                box=np.array([ct, cp, fh * 0.8, fv * 0.8]),
                category=cat, score=float(probs[cat]))])
        return out

    def infer_sroi(self, frame_img, region: sroi_mod.SRoI,
                   variant: acc_mod.ModelProfile):
        return self.infer_srois_batched([(frame_img, region)], variant)[0]

    def infer_erp(self, frame_img, variant: acc_mod.ModelProfile):
        del frame_img, variant
        return []  # the action head has no full-ERP discovery pass


def default_action_configs(n_actions: int = N_ACTION_CLASSES):
    """``ActionConfig`` per ladder rung (the JaxActionBackend zoo)."""
    from repro.models.action import ActionConfig

    return [ActionConfig(name=name, input_size=res, clip_len=clip,
                         n_actions=n_actions)
            for name, clip, res in ACTION_LADDER]


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalyticsTask:
    """One registered analytics workload (see module docstring)."""

    name: str
    make_ladder: Callable[[], list]
    make_latency_model: Callable[[], object]
    make_backend: Callable[[SyntheticVideo], object]
    make_loop: Callable[..., object]
    accuracy_proxy: str  # metric name in repro.serving.evaluation
    result_kind: str

    def ladder_names(self) -> tuple[str, ...]:
        return tuple(v.name for v in self.make_ladder())


TASKS: dict[str, AnalyticsTask] = {}
_VARIANT_TASK: dict[str, str] = {}


def register_task(task: AnalyticsTask) -> AnalyticsTask:
    if task.name in TASKS:
        raise ValueError(f"task {task.name!r} already registered")
    for name in task.ladder_names():
        owner = _VARIANT_TASK.get(name)
        if owner is not None:
            raise ValueError(
                f"variant {name!r} already registered to task {owner!r}")
    TASKS[task.name] = task
    for name in task.ladder_names():
        _VARIANT_TASK[name] = task.name
    return task


def get_task(name: str) -> AnalyticsTask:
    try:
        return TASKS[name]
    except KeyError:
        raise ValueError(f"unknown task {name!r}; registered: "
                         f"{sorted(TASKS)}") from None


def task_names() -> list[str]:
    return sorted(TASKS)


def task_for_variant(variant_name: str) -> str:
    """The owning task of a registered variant name.

    Unregistered names (toy test ladders) default to ``detection`` —
    the pre-registry behavior of every queue/policy path.
    """
    return _VARIANT_TASK.get(variant_name, "detection")


def _detection_loop(variants, latency_model, backend, budget_s, **kw):
    loop = OmniSenseLoop(variants, latency_model, backend,
                         budget_s=budget_s, **kw)
    loop.task = "detection"
    return loop


def _action_loop(variants, latency_model, backend, budget_s, **kw):
    loop = OmniSenseLoop(variants, latency_model, backend,
                         budget_s=budget_s, **kw)
    loop.task = "action_recognition"
    return loop


register_task(AnalyticsTask(
    name="detection",
    make_ladder=profiles.make_ladder,
    make_latency_model=lambda: OmniSenseLatencyModel(
        profiles.paper_profile(), NetworkModel()),
    make_backend=OracleBackend,
    make_loop=_detection_loop,
    accuracy_proxy="sph_map",
    result_kind="detections",
))

register_task(AnalyticsTask(
    name="action_recognition",
    make_ladder=action_ladder,
    make_latency_model=lambda: ActionLatencyModel(
        profiles.paper_profile(), NetworkModel(),
        clip_len=dict(ACTION_CLIP_LEN)),
    make_backend=OracleActionBackend,
    make_loop=_action_loop,
    accuracy_proxy="action_top1",
    result_kind="actions",
))


# --------------------------------------------------------------------------
# mixed-task pod builders
# --------------------------------------------------------------------------


def build_task_streams(stream_tasks: Sequence[str], videos, budgets, *,
                       detection_variants: Sequence[str] | None = None):
    """Per-stream loops/backends for a (possibly mixed-task) pod.

    One shared ladder + latency model per task present (first-seen
    order), loops built through each task's registered factories —
    detection-only input reproduces the pre-registry construction
    bit-identically.  ``detection_variants`` optionally subsets the
    detection ladder by name (a replay spec's ``variants``); other
    tasks always serve their full registered ladder.

    Returns ``(variants, loops, backends, cost_fn)``: ``variants`` is
    the union ladder in first-seen task order and ``cost_fn`` prices
    any union variant with its own task's latency model (placement
    seeding).
    """
    ctx: dict = {}
    order: list[str] = []
    for tname in stream_tasks:
        if tname in ctx:
            continue
        task = get_task(tname)
        ladder = task.make_ladder()
        if tname == "detection" and detection_variants is not None:
            by_name = {v.name: v for v in ladder}
            unknown = [n for n in detection_variants if n not in by_name]
            if unknown:
                raise ValueError(f"unknown variants {unknown}; ladder has "
                                 f"{sorted(by_name)}")
            ladder = [by_name[n] for n in detection_variants]
        lat = task.make_latency_model()
        costs = [lat._pre(v) + lat._inf(v) for v in ladder]
        ctx[tname] = (ladder, lat, costs)
        order.append(tname)

    loops, backends = [], []
    for s, tname in enumerate(stream_tasks):
        ladder, lat, costs = ctx[tname]
        task = get_task(tname)
        backend = task.make_backend(videos[s])
        loops.append(task.make_loop(ladder, lat, backend, budgets[s],
                                    explore_costs=costs))
        backends.append(backend)

    union = [v for tname in order for v in ctx[tname][0]]
    if len(ctx) == 1:
        cost_fn = ctx[order[0]][1]._inf
    else:
        lat_by_name = {v.name: ctx[tname][1]
                       for tname in order for v in ctx[tname][0]}

        def cost_fn(v):
            return lat_by_name[v.name]._inf(v)

    return union, loops, backends, cost_fn


def shape_buckets_for(tasks: Sequence[str], max_batch: int = 8):
    """``ShapeBuckets`` whose legal crop resolutions are the UNION of
    the given tasks' ladder input sizes — the (task, variant) shape
    space of a mixed-task pod's real (pixel-touching) backends."""
    from repro.serving.batching import ShapeBuckets

    sizes = sorted({v.input_size for t in tasks
                    for v in get_task(t).make_ladder()})
    return ShapeBuckets.for_max_batch(max_batch, tuple(sizes))


def stream_tasks_for(mode: str, n_streams: int) -> list[str]:
    """Expand a ``--tasks`` shorthand into per-stream task names.

    ``detection`` / ``action`` are homogeneous pods; ``mixed``
    alternates the two (even streams detect, odd streams recognise).
    """
    if mode in ("detection", "action", "action_recognition"):
        name = "detection" if mode == "detection" else "action_recognition"
        return [name] * n_streams
    if mode == "mixed":
        return ["detection" if s % 2 == 0 else "action_recognition"
                for s in range(n_streams)]
    raise ValueError(f"unknown task mode {mode!r} "
                     "(expected detection|action|mixed)")

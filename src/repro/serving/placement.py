"""Per-variant replica groups: multi-device placement for pod serving.

PR 2 gave the pod ONE batched forward per variant per tick, but every
variant still serialises on a single accelerator — V variants pay the
SUM of their batched delays.  This module partitions the pod's devices
into per-variant **replica groups** so the V forwards run concurrently,
each sharded over its group's ``data`` axis:

  * ``VariantPlacement.partition`` greedily assigns devices to variants
    by *profiled load* (variant FLOPs-derived ``infer_s`` x observed
    popularity): every variant keeps at least one device, and each
    remaining device goes to the group with the highest load per
    device, so a variant 5x costlier than its peers ends up with ~5x
    the devices.  When there are more variants than devices, variants
    are bin-packed onto shared groups (lightest-bin-first), so the
    device partition is always a disjoint cover.
  * ``observe`` feeds per-tick request counts into a popularity EMA and
    ``maybe_rebalance`` re-partitions when the allocator has shifted
    variant popularity past a threshold.  Every variant maps to a group
    at ALL times (popularity is floored, groups are swapped
    atomically), so a rebalance can never strand a queued request.
  * ``ReplicaGroup.mesh`` lazily builds the group's 1-axis ``data``
    mesh for ``shard_map``-sharded batched inference
    (``JaxDetectorBackend.infer_srois_batched(..., group=...)``).

Devices may be real ``jax.Device`` objects (the sharded Jax path) or
plain placeholders (ints) for simulation backends: the oracle pod
prices the device-aware tick model without touching an accelerator,
which keeps placement logic testable on the single-device fast tier.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class ReplicaGroup:
    """One disjoint device group serving one (or more) variants."""

    index: int
    variants: tuple[str, ...]
    devices: tuple[Any, ...]

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def is_virtual(self) -> bool:
        """Placeholder device slots (simulation pricing only): the
        group can model the tick but cannot host a sharded forward."""
        return any(isinstance(d, int) for d in self.devices)

    @functools.cached_property
    def mesh(self):
        """The group's 1-axis ``("data",)`` mesh (real devices only)."""
        import numpy as np
        from jax.sharding import Mesh

        if self.is_virtual:
            raise TypeError(
                f"group {self.index} holds virtual device slots "
                f"{self.devices}; a mesh needs real jax devices")
        return Mesh(np.array(self.devices), ("data",))

    def shard_batch(self, b: int) -> int:
        """Smallest batch >= ``b`` divisible by the group width (the
        extra rows are masked padding, like batch-bucket padding)."""
        g = self.n_devices
        return int(math.ceil(b / g)) * g


class VariantPlacement:
    """Greedy load-balanced partition of devices into replica groups.

    ``variants`` are ``ModelProfile``s (their FLOPs-derived ``infer_s``
    is the static load term); ``devices`` defaults to ``jax.devices()``.
    ``popularity_smoothing`` is the EMA step applied by :meth:`observe`;
    ``rebalance_threshold`` is the relative device-count shift that
    makes :meth:`maybe_rebalance` adopt a fresh partition.
    """

    def __init__(self, variants: Sequence, devices: Sequence[Any] | None = None,
                 *, popularity_smoothing: float = 0.5,
                 rebalance_threshold: float = 0.25,
                 min_popularity: float = 0.05,
                 cost_fn=None):
        if devices is None:
            import jax

            devices = jax.devices()
        if not variants:
            raise ValueError("placement needs at least one variant")
        if not devices:
            raise ValueError("placement needs at least one device")
        self.devices = tuple(devices)
        # static load term: FLOPs-derived profiled forward seconds by
        # default; pass the latency model's ``_inf`` as ``cost_fn`` to
        # weigh remote variants by their full serving cost (compute +
        # payload delivery), which is the real per-tick bottleneck
        cost_fn = cost_fn or (lambda v: v.infer_s)
        self._flops = {v.name: float(cost_fn(v)) for v in variants}
        self._order = [v.name for v in variants]
        self.smoothing = popularity_smoothing
        self.threshold = rebalance_threshold
        self.min_popularity = min_popularity
        self._popularity = {name: 1.0 for name in self._order}
        self.rebalances = 0
        self._adopt(self.partition(self._weights(), self.devices))

    # -- partition ---------------------------------------------------------

    def _weights(self) -> dict[str, float]:
        return {name: self._flops[name]
                * max(self._popularity[name], self.min_popularity)
                for name in self._order}

    @staticmethod
    def partition(weights: Mapping[str, float],
                  devices: Sequence[Any]) -> list[ReplicaGroup]:
        """Greedy FLOPs-weighted device partition (see module doc).

        Deterministic: variants are processed heaviest-first (name
        tie-break) and devices are sliced contiguously, so equal inputs
        always produce the identical partition.
        """
        names = sorted(weights, key=lambda n: (-weights[n], n))
        n_groups = min(len(names), len(devices))
        # 1) bin-pack variants onto groups (lightest bin first)
        bin_vars: list[list[str]] = [[] for _ in range(n_groups)]
        bin_w = [0.0] * n_groups
        for name in names:
            i = min(range(n_groups), key=lambda k: (bin_w[k], k))
            bin_vars[i].append(name)
            bin_w[i] += weights[name]
        # 2) one device each, then devices chase the highest load/device
        counts = [1] * n_groups
        for _ in range(len(devices) - n_groups):
            i = max(range(n_groups),
                    key=lambda k: (bin_w[k] / counts[k], -k))
            counts[i] += 1
        groups, lo = [], 0
        for i in range(n_groups):
            groups.append(ReplicaGroup(
                index=i, variants=tuple(bin_vars[i]),
                devices=tuple(devices[lo:lo + counts[i]])))
            lo += counts[i]
        return groups

    def _adopt(self, groups: list[ReplicaGroup]) -> None:
        self.groups = groups
        self._by_variant = {name: g for g in groups for name in g.variants}

    # -- queries -----------------------------------------------------------

    def group_for(self, variant_name: str) -> ReplicaGroup:
        return self._by_variant[variant_name]

    @property
    def variant_names(self) -> tuple[str, ...]:
        return tuple(self._order)

    def device_counts(self) -> dict[str, int]:
        return {name: self._by_variant[name].n_devices
                for name in self._order}

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    # -- popularity feedback / rebalance -----------------------------------

    def observe(self, request_counts: Mapping[str, int]) -> None:
        """Fold one tick's per-variant request counts into the EMA."""
        total = sum(request_counts.values())
        if total <= 0:
            return
        s = self.smoothing
        for name in self._order:
            share = request_counts.get(name, 0) / total
            self._popularity[name] = (1 - s) * self._popularity[name] + s * share

    def maybe_rebalance(self) -> bool:
        """Re-partition if the load shift warrants it; returns whether a
        new partition was adopted.  The swap is atomic — every variant
        has a group before AND after — so callers may rebalance with
        requests already queued."""
        fresh = self.partition(self._weights(), self.devices)
        cur = self.device_counts()
        new = {name: g.n_devices for g in fresh for name in g.variants}
        shift = max((abs(new[n] - cur[n]) / max(cur[n], 1)
                     for n in self._order), default=0.0)
        if shift <= self.threshold:
            return False
        self._adopt(fresh)
        self.rebalances += 1
        return True

    @classmethod
    def virtual(cls, variants: Sequence, n_devices: int,
                **kwargs) -> "VariantPlacement":
        """Placement over ``n_devices`` virtual slots — the simulation
        (oracle) pod prices the device-aware tick model without any
        accelerator behind it."""
        return cls(variants, devices=list(range(n_devices)), **kwargs)

"""Deterministic replay over the telemetry event log.

A recorded serving run (``repro.serving.telemetry``) is fully
re-drivable: the pod is deterministic given its construction
parameters (seeded oracle videos, calibrated latency model, virtual
device slots — no wall clock in any replayed quantity), and the
traffic is either a closed-loop ``range(frames)`` or the exact
``arrival`` records in the log.  This module makes that a harness:

  * :class:`CorpusSpec` — the rebuildable pod recipe (the standard
    oracle pod every bench/test in this repo serves).  ``record()``
    writes it into the log as a ``corpus_spec`` event, so a log is a
    self-contained replay artifact;
  * :func:`record` — serve a spec under a sink, stamping
    ``corpus_spec`` first and the final ``run_stats`` fingerprint
    last;
  * :func:`replay` — rebuild the pod from a log's spec (optionally
    under a DIFFERENT schedule/admission policy), re-drive the
    recorded traffic, and compare: same policy must reproduce
    ``ServeStats`` and every per-frame detection digest
    BIT-IDENTICALLY (the replay-determinism CI lane); a different
    policy yields an apples-to-apples :func:`format_policy_diff`;
  * :func:`stats_fingerprint` — ``ServeStats`` as a JSON-stable dict
    with the wall-clock field (``sum_overhead``, the only
    non-deterministic quantity in the dataclass) excluded.
"""

from __future__ import annotations

import dataclasses
import json

from repro.serving.telemetry import MemorySink, read_events

# ServeStats fields measured with time.perf_counter — everything else
# in the dataclass is event-clock/model-priced and must replay exactly
_WALL_CLOCK_FIELDS = frozenset({"sum_overhead"})


@dataclasses.dataclass
class CorpusSpec:
    """The rebuildable recipe of one recorded serving run.

    Everything here feeds seeded constructors (``make_video(seed0+s)``,
    ``ArrivalProcess(seed=traffic_seed)``, ``VariantPlacement.virtual``)
    so two pods built from equal specs are indistinguishable.  The
    variant ladder is selected BY NAME from ``profiles.make_ladder()``
    — the calibrated Table II ladder — so a spec stays valid across
    refactors that reorder it.
    """

    mode: str = "closed"            # "closed" | "open"
    n_streams: int = 4
    frames: int = 8                 # closed: tick count; open: video floor
    budget_s: float | list = 1.8    # scalar or one per stream
    variants: tuple = ("yolo-p5-896", "yolo-p6-1280")
    # per-stream analytics tasks (repro.serving.tasks registry names,
    # one per stream); () keeps every stream on detection — the
    # backward-compatible reading of pre-multi-task logs.  ``variants``
    # only subsets the DETECTION ladder; non-detection streams serve
    # their task's full registered ladder.
    tasks: tuple = ()
    devices: int = 8                # virtual slots; 0 = single-device pod
    max_batch: int = 8
    policy: str = "sync"
    pod_allocate: bool = False
    max_carry: int | None = None    # async policy only
    admission: str | None = None    # None = admit-all
    slo_s: float | None = None      # open-loop SLO target
    seed0: int = 100                # per-stream video seed base
    # fleet tier (repro.serving.fleet): 0 = single pod (the default,
    # backward-compatible with pre-fleet logs); > 0 records an
    # open-loop FleetServer run with a FIXED active set — elastic
    # scaling is exercised by the fleet tests, not the replay corpora
    pods: int = 0
    routing: str = "least-loaded"
    # open-loop traffic (ignored in closed mode)
    fps: float = 0.5
    jitter: float = 0.0
    traffic_seed: int = 0
    horizon_s: float = 30.0
    churn: tuple = ()               # (t_s, stream, connected) triples
    rate_trace: tuple = ()          # (t_start_s, scale) steps

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["variants"] = list(d["variants"])
        d["tasks"] = list(d["tasks"])
        d["churn"] = [list(c) for c in d["churn"]]
        d["rate_trace"] = [list(r) for r in d["rate_trace"]]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CorpusSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = d.keys() - known
        if unknown:
            raise ValueError(f"corpus_spec has unknown fields "
                             f"{sorted(unknown)}")
        d = dict(d)
        for key in ("variants", "tasks", "churn", "rate_trace"):
            if key in d:
                d[key] = tuple(tuple(x) if isinstance(x, list) else x
                               for x in d[key])
        if isinstance(d.get("budget_s"), list):
            d["budget_s"] = list(d["budget_s"])
        return cls(**d)

    def budget_for(self, stream: int) -> float:
        if isinstance(self.budget_s, (int, float)):
            return float(self.budget_s)
        return float(self.budget_s[stream])

    def traffic(self):
        """The spec's seeded :class:`~repro.serving.traffic.
        ArrivalProcess` (open mode only)."""
        from repro.serving.traffic import ArrivalProcess, ChurnEvent

        return ArrivalProcess(
            self.n_streams, fps=self.fps, jitter=self.jitter,
            seed=self.traffic_seed, horizon_s=self.horizon_s,
            churn=[ChurnEvent(t_s=t, stream=s, connected=bool(c))
                   for t, s, c in self.churn],
            rate_trace=self.rate_trace)


def _build_streams(spec: CorpusSpec):
    """The spec's shared per-stream state, built through the analytics
    task registry (``repro.serving.tasks``): per-task calibrated
    ladders and latency models, seeded oracle backends and loops.  A
    spec with no ``tasks`` is an all-detection pod and reproduces the
    pre-registry construction bit-identically.  One build serves a
    single pod or a whole fleet — every fleet pod must see the SAME
    lists so global stream indices stay valid on any pod.

    Returns ``(variants, loops, backends, cost_fn)``: ``variants`` is
    the union ladder over the tasks present and ``cost_fn`` prices a
    union variant with its own task's latency model (placement
    seeding)."""
    from repro.data.synthetic import make_video
    from repro.serving import tasks as task_registry

    stream_tasks = list(spec.tasks) or ["detection"] * spec.n_streams
    if len(stream_tasks) != spec.n_streams:
        raise ValueError(
            f"corpus_spec.tasks names {len(stream_tasks)} streams, "
            f"n_streams is {spec.n_streams}")
    frames = spec.frames
    if spec.mode == "open":
        frames = max(frames, int(spec.horizon_s * spec.fps) + 8)
    videos = [make_video(n_frames=frames + 8,
                         n_objects=30 + 5 * (s % 4),
                         seed=spec.seed0 + s)
              for s in range(spec.n_streams)]
    budgets = [spec.budget_for(s) for s in range(spec.n_streams)]
    try:
        return task_registry.build_task_streams(
            stream_tasks, videos, budgets,
            detection_variants=spec.variants)
    except ValueError as e:
        if "unknown variants" in str(e):
            raise ValueError(f"corpus_spec names {e}") from None
        raise


def build_pod(spec: CorpusSpec, policy=None, admission=None,
              telemetry=None):
    """The standard deterministic oracle pod for ``spec``.

    ``policy``/``admission`` override the spec's (the policy-diff
    path); ``None`` rebuilds exactly what was recorded.
    """
    from repro.serving.placement import VariantPlacement
    from repro.serving.server import PodServer

    variants, loops, backends, cost_fn = _build_streams(spec)
    placement = None
    if spec.devices > 0:
        placement = VariantPlacement.virtual(variants, spec.devices,
                                             cost_fn=cost_fn)
    if policy is None:
        policy = _spec_policy(spec, admission)
    elif admission is not None:
        raise ValueError("pass admission inside the policy instance or "
                         "leave policy=None")
    return PodServer(loops, backends, max_batch=spec.max_batch,
                     placement=placement, policy=policy,
                     telemetry=telemetry)


def build_fleet(spec: CorpusSpec, policy=None, admission=None,
                telemetry=None):
    """The deterministic ``spec.pods``-pod fleet over the same shared
    streams as :func:`build_pod`.

    ``spec.devices`` is the fleet-wide budget: each pod gets the
    per-pod power-of-two width :func:`~repro.distributed.elastic.
    serving_scale_plan` assigns (0 keeps every pod single-device).
    Each pod receives its OWN placement and (spec-built) policy
    instance; a ``policy`` override instance is shared across pods —
    schedule policies are stateless config objects, so sharing is
    safe — and overrides work the same as on :func:`build_pod`.
    """
    from repro.distributed.elastic import serving_scale_plan
    from repro.serving.fleet import FleetServer
    from repro.serving.placement import VariantPlacement
    from repro.serving.server import PodServer

    if spec.pods < 1:
        raise ValueError(f"build_fleet needs spec.pods >= 1, got "
                         f"{spec.pods}")
    if spec.mode != "open":
        raise ValueError("fleet corpora are open-loop; set mode='open'")
    variants, loops, backends, cost_fn = _build_streams(spec)
    per_pod = serving_scale_plan(spec.devices, spec.pods)["per_pod_devices"]
    if policy is not None and admission is not None:
        raise ValueError("pass admission inside the policy instance or "
                         "leave policy=None")

    def make_pod(pod_id: int) -> PodServer:
        placement = None
        if per_pod > 0:
            placement = VariantPlacement.virtual(variants, per_pod,
                                                 cost_fn=cost_fn)
        pol = policy if policy is not None \
            else _spec_policy(spec, admission)
        return PodServer(loops, backends, max_batch=spec.max_batch,
                         placement=placement, policy=pol)

    return FleetServer(make_pod, spec.pods, routing=spec.routing,
                       telemetry=telemetry)


def _spec_policy(spec: CorpusSpec, admission=None):
    from repro.serving.runtime import POLICIES, AsyncDrainPolicy

    cls = POLICIES[spec.policy]
    adm = admission if admission is not None else spec.admission
    if cls is AsyncDrainPolicy and spec.max_carry is not None:
        return cls(pod_allocate=spec.pod_allocate,
                   max_carry=spec.max_carry, admission=adm)
    return cls(pod_allocate=spec.pod_allocate, admission=adm)


def stats_fingerprint(stats) -> dict:
    """``ServeStats`` as a JSON-round-trip-stable dict, wall-clock
    fields excluded.  Dict keys pass through ``str`` (JSON would do it
    anyway), so a fingerprint read back from a log compares equal to a
    fresh one.

    A :class:`~repro.serving.fleet.FleetStats` (recognised by its
    ``pod_stats`` attribute) fingerprints recursively: the fleet-only
    control-plane counters plus one per-pod ``ServeStats`` fingerprint
    in pod-id order — so a fleet replay must reproduce every pod AND
    every routing/scaling decision bit-identically."""
    if hasattr(stats, "pod_stats"):
        out = {"routing": stats.routing,
               "pod_ids": list(stats.pod_ids),
               "routes": stats.routes,
               "migrations": stats.migrations,
               "scale_ups": stats.scale_ups,
               "scale_downs": stats.scale_downs,
               "pods": [stats_fingerprint(s) for s in stats.pod_stats]}
        return json.loads(json.dumps(out))
    out = {}
    for f in dataclasses.fields(stats):
        if f.name in _WALL_CLOCK_FIELDS:
            continue
        v = getattr(stats, f.name)
        if isinstance(v, dict):
            v = {str(k): v[k] for k in sorted(v, key=str)}
        out[f.name] = v
    # json round-trip normalises tuples/numpy scalars the way a
    # JsonlSink record would have
    return json.loads(json.dumps(out))


def record(spec: CorpusSpec, sink) -> "object":
    """Serve ``spec`` with telemetry into ``sink``; returns the stats.

    The log leads with the ``corpus_spec`` record (so :func:`replay`
    can rebuild the pod) and ends with ``run_stats`` (the fingerprint
    a same-policy replay must reproduce)."""
    sink.emit("corpus_spec", spec=spec.to_dict())
    if spec.pods > 0:
        server = build_fleet(spec, telemetry=sink)
        stats = server.run_open_loop(spec.traffic(), slo_s=spec.slo_s)
    else:
        server = build_pod(spec, telemetry=sink)
        if spec.mode == "open":
            stats = server.run_open_loop(spec.traffic(), slo_s=spec.slo_s)
        else:
            stats = server.run(range(spec.frames))
    sink.emit("run_stats", stats=stats_fingerprint(stats))
    sink.close()
    return stats


def _log_spec(events) -> CorpusSpec:
    specs = [e for e in events if e["event"] == "corpus_spec"]
    if not specs:
        raise ValueError("log has no corpus_spec record; was it written "
                         "by repro.serving.replay.record()?")
    return CorpusSpec.from_dict(specs[0]["spec"])


def _log_digests(events) -> dict:
    """Per (stream, frame_idx): the recorded detection digest."""
    return {(e["stream"], e["frame_idx"]): e["det_digest"]
            for e in events if e["event"] == "frame_finish"}


@dataclasses.dataclass
class ReplayResult:
    """A replay run next to what its log recorded."""

    spec: CorpusSpec
    recorded_stats: dict            # fingerprint from the log
    replayed_stats: dict            # fingerprint of the re-driven run
    recorded_digests: dict          # (stream, frame_idx) -> sha1
    replayed_digests: dict
    events: list                    # the replay's own event records
    same_policy: bool

    @property
    def identical(self) -> bool:
        return (self.replayed_stats == self.recorded_stats
                and self.replayed_digests == self.recorded_digests)

    def drift(self) -> list[str]:
        """Human-readable drift lines (empty when bit-identical)."""
        out = []
        for k in self.recorded_stats:
            a, b = self.recorded_stats[k], self.replayed_stats.get(k)
            if a != b:
                out.append(f"stats.{k}: recorded {a!r} != replayed {b!r}")
        for k in self.replayed_stats.keys() - self.recorded_stats.keys():
            out.append(f"stats.{k}: only in replay")
        keys = self.recorded_digests.keys() | self.replayed_digests.keys()
        drifted = [k for k in sorted(keys)
                   if self.recorded_digests.get(k)
                   != self.replayed_digests.get(k)]
        if drifted:
            out.append(
                f"detections drifted on {len(drifted)} frames "
                f"(first: stream {drifted[0][0]} frame {drifted[0][1]})")
        return out


def replay(log, policy=None, admission=None) -> ReplayResult:
    """Re-drive a recorded log; compare against what it recorded.

    ``log`` is a path (JSONL) or an event-record list.  With
    ``policy``/``admission`` None the pod is rebuilt exactly as
    recorded and the result must be bit-identical; an override turns
    the run into a policy experiment over the SAME content and traffic
    (``format_policy_diff`` renders the comparison).
    """
    events = read_events(log) if isinstance(log, str) else list(log)
    spec = _log_spec(events)
    recorded = [e for e in events if e["event"] == "run_stats"]
    if not recorded:
        raise ValueError("log has no run_stats record (truncated "
                         "recording?)")
    sink = MemorySink()
    if spec.pods > 0:
        server = build_fleet(spec, policy=policy, admission=admission,
                             telemetry=sink)
    else:
        server = build_pod(spec, policy=policy, admission=admission,
                           telemetry=sink)
    if spec.mode == "open":
        from repro.serving.traffic import arrivals_from_records

        stats = server.run_open_loop(arrivals_from_records(events),
                                     slo_s=spec.slo_s)
    else:
        stats = server.run(range(spec.frames))
    return ReplayResult(
        spec=spec,
        recorded_stats=recorded[0]["stats"],
        replayed_stats=stats_fingerprint(stats),
        recorded_digests=_log_digests(events),
        replayed_digests=_log_digests(sink.events),
        events=sink.events,
        same_policy=policy is None and admission is None)


# which fingerprint fields the policy-diff table shows, in order
_DIFF_FIELDS = (
    "frames", "ticks", "dispatches", "carried_requests", "carry_tick_slots",
    "sum_tick_inf_s",
    "sum_plan_value", "arrivals", "admitted", "degraded", "rejected",
    "missed", "empty_frames", "slo_violations", "total_detections",
)


def fingerprint_metrics(fp: dict) -> dict:
    """The diff-table scalars of one stats fingerprint.

    A fleet fingerprint (the ``pods`` key) aggregates: counters sum
    across pods, the e2e percentile pools every pod's events, and the
    fleet-only control-plane counters ride along."""
    if "pods" in fp:
        out = {}
        for k in _DIFF_FIELDS:
            vals = [p.get(k) for p in fp["pods"] if p.get(k) is not None]
            out[k] = round(sum(vals), 4) if vals else None
        e2e = [x for p in fp["pods"] for x in (p.get("event_e2e") or [])]
        if e2e:
            srt = sorted(e2e)
            out["p95_e2e_s"] = round(srt[min(len(srt) - 1,
                                             int(0.95 * len(srt)))], 4)
        for k in ("routes", "migrations", "scale_ups", "scale_downs"):
            out[k] = fp.get(k)
        return out
    out = {}
    for k in _DIFF_FIELDS:
        v = fp.get(k)
        if isinstance(v, float):
            v = round(v, 4)
        out[k] = v
    e2e = fp.get("event_e2e") or []
    if e2e:
        srt = sorted(e2e)
        out["p95_e2e_s"] = round(srt[min(len(srt) - 1,
                                         int(0.95 * len(srt)))], 4)
    return out


def format_policy_diff(result: ReplayResult) -> list[str]:
    """Side-by-side recorded-vs-replayed report lines.

    Same policy: a one-line bit-identical verdict (or the drift list —
    the CI lane's failure payload).  Different policy: the
    apples-to-apples metric table over identical content and traffic.
    """
    rec = fingerprint_metrics(result.recorded_stats)
    rep = fingerprint_metrics(result.replayed_stats)
    if result.same_policy:
        if result.identical:
            fleet = (f", {result.spec.pods} pods "
                     f"({result.spec.routing} routing)"
                     if result.spec.pods else "")
            return [f"replay [{result.spec.policy} policy, "
                    f"{result.spec.mode}-loop, {result.spec.n_streams} "
                    f"streams{fleet}]: bit-identical "
                    f"({rec['frames']} frames, {rec['dispatches']} "
                    f"dispatches, {len(result.recorded_digests)} "
                    f"detection digests)"]
        return ["replay DRIFTED from its recording:"] + [
            f"  {line}" for line in result.drift()]
    rec_pol = result.recorded_stats.get("policy", result.spec.policy)
    rep_pol = result.replayed_stats.get("policy", "?")
    lines = [f"policy diff over identical content/traffic "
             f"[{result.spec.mode}-loop, {result.spec.n_streams} "
             f"streams]: recorded={rec_pol} replayed={rep_pol}"]
    width = max(len(k) for k in rec)
    for k in rec:
        a, b = rec.get(k), rep.get(k)
        if a in (None, 0, 0.0) and b in (None, 0, 0.0):
            continue
        mark = "" if a == b else "  *"
        lines.append(f"  {k:<{width}}  recorded={a!s:>10}  "
                     f"replayed={b!s:>10}{mark}")
    return lines

"""Shape-bucketed variant batching for the pod serving loop.

At pod scale the dominant serving lever is *variant batching*: PI
requests from many streams that chose the same model variant are
stacked into one accelerator forward.  Batched dispatch on a jitted
backend recompiles per input shape, so unrestricted batch sizes would
turn every new stream count into an XLA compile; this module bounds the
shape space instead (the ROADMAP shape-bucketing item):

  * **batch buckets** — a small fixed ladder of batch sizes.  A drained
    chunk of ``b`` requests is zero-padded up to the smallest bucket
    ``>= b`` and the padded rows are masked out of the decode, so the
    jit cache holds at most ``len(batch_sizes)`` entries per variant.
  * **resolution buckets** — the set of legal crop resolutions.  Each
    variant projects its SRoIs at its own fixed input size, so the
    resolution set is exactly the ladder's input sizes; the helper
    validates that no dispatch can introduce an off-ladder shape.

``VariantQueues`` is the tick-level request fabric shared by
``PodServer`` and the baselines: requests accumulate per variant and
drain into bucketed chunks, each chunk becoming one batched detector
forward (``repro.serving.scheduler.*.infer_srois_batched``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Sequence

DEFAULT_BATCH_BUCKETS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class ShapeBuckets:
    """The bounded shape space of batched dispatches.

    ``batch_sizes`` must be strictly increasing; ``resolutions`` is the
    optional set of legal (square) crop sizes (``None`` = unrestricted,
    for oracle backends that never touch pixels).
    """

    batch_sizes: tuple[int, ...] = DEFAULT_BATCH_BUCKETS
    resolutions: tuple[int, ...] | None = None

    def __post_init__(self):
        if not self.batch_sizes or any(b <= 0 for b in self.batch_sizes):
            raise ValueError(f"invalid batch buckets {self.batch_sizes}")
        if list(self.batch_sizes) != sorted(set(self.batch_sizes)):
            raise ValueError(
                f"batch buckets must be strictly increasing: {self.batch_sizes}")

    @property
    def max_batch(self) -> int:
        return self.batch_sizes[-1]

    def pad_batch(self, b: int) -> int:
        """Smallest bucket >= ``b`` (the padded dispatch batch size)."""
        if b <= 0 or b > self.max_batch:
            raise ValueError(f"batch {b} outside buckets {self.batch_sizes}")
        for size in self.batch_sizes:
            if size >= b:
                return size
        raise AssertionError  # unreachable: b <= max_batch

    def split(self, count: int) -> list[int]:
        """Split ``count`` queued requests into chunk sizes <= max_batch.

        Greedy full-bucket chunks followed by one remainder chunk; the
        remainder still pads up to a bucket, never to an ad-hoc shape.
        """
        out, rest = [], count
        while rest > self.max_batch:
            out.append(self.max_batch)
            rest -= self.max_batch
        if rest:
            out.append(rest)
        return out

    def bucket_resolution(self, size: int) -> int:
        """Validate/snap a crop resolution into the bounded set."""
        if self.resolutions is None:
            return size
        if size in self.resolutions:
            return size
        raise ValueError(
            f"crop resolution {size} outside buckets {self.resolutions}")

    @classmethod
    def for_max_batch(cls, max_batch: int,
                      resolutions: tuple[int, ...] | None = None
                      ) -> "ShapeBuckets":
        """Default bucket ladder capped at ``max_batch`` (kept as the
        top bucket so a full drain always lands on an exact bucket)."""
        sizes = tuple(b for b in DEFAULT_BATCH_BUCKETS if b < max_batch)
        return cls(sizes + (max_batch,), resolutions)


@dataclasses.dataclass
class QueuedRequest:
    """One SRoI inference request parked in a variant queue."""

    request: Any                  # repro.core.omnisense.InferenceRequest
    owner: Any                    # opaque scatter key (the pending frame)
    backend: Any                  # executes the batched forward
    latency_model: Any = None     # prices the dispatch (may be None)


class VariantQueues:
    """Per-variant request queues drained into bucketed batched forwards.

    ``put`` parks requests; ``drain`` empties every queue into chunks of
    at most ``buckets.max_batch`` requests, issues one
    ``infer_srois_batched`` call per (chunk, backend) group and returns
    the per-request detections plus per-dispatch accounting records.
    Variants are drained in sorted-name order so a tick's dispatch
    schedule is deterministic.
    """

    def __init__(self, buckets: ShapeBuckets | None = None):
        self.buckets = buckets or ShapeBuckets()
        self._queues: dict[str, collections.deque] = collections.defaultdict(
            collections.deque)

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def put(self, item: QueuedRequest) -> None:
        self._queues[item.request.variant.name].append(item)

    def drain(self) -> tuple[list[tuple[QueuedRequest, list]], list[dict]]:
        """Empty all queues; returns (results, dispatch records).

        ``results``: (queued_request, detections) per drained request,
        in dispatch order.  ``dispatches``: one record per batched
        forward with the variant, real batch ``b``, padded bucket size
        and the items it served — the tick schedule the server prices.
        """
        results: list[tuple[QueuedRequest, list]] = []
        dispatches: list[dict] = []
        for name in sorted(self._queues):
            q = self._queues[name]
            while q:
                chunk = [q.popleft()
                         for _ in range(min(len(q), self.buckets.max_batch))]
                results.extend(self._dispatch_chunk(name, chunk, dispatches))
        return results, dispatches

    def _dispatch_chunk(self, name: str, chunk: Sequence[QueuedRequest],
                        dispatches: list[dict]):
        """One drained chunk -> one batched detector forward.

        Streams normally share one backend (the real detector ladder),
        so the whole chunk is a single ``infer_srois_batched`` call;
        per-stream oracle backends sub-group by identity (an execution
        detail of the simulation — the chunk remains ONE dispatch in
        the tick schedule the server prices).
        """
        variant = chunk[0].request.variant
        groups: dict[int, list[QueuedRequest]] = {}
        for item in chunk:
            groups.setdefault(id(item.backend), []).append(item)
        out = []
        for items in groups.values():
            dets = items[0].backend.infer_srois_batched(
                [(it.request.frame, it.request.region) for it in items],
                variant)
            assert len(dets) == len(items)
            out.extend(zip(items, dets))
        # `semantic`: every backend in the chunk declares its batched
        # entry a pure simulation (`semantic_batch = True`, e.g. the
        # oracle), so the chunk models ONE shared-accelerator dispatch
        # and is priced as such.  Otherwise each backend group is a
        # real forward and must be priced individually.
        dispatches.append(dict(
            variant=name,
            b=len(chunk),
            padded=self.buckets.pad_batch(len(chunk)),
            items=list(chunk),
            forwards=len(groups),
            group_sizes=[len(items) for items in groups.values()],
            semantic=all(getattr(it.backend, "semantic_batch", False)
                         for it in chunk),
        ))
        return out

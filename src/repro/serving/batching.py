"""Shape-bucketed variant batching for the pod serving loop.

At pod scale the dominant serving lever is *variant batching*: PI
requests from many streams that chose the same model variant are
stacked into one accelerator forward.  Batched dispatch on a jitted
backend recompiles per input shape, so unrestricted batch sizes would
turn every new stream count into an XLA compile; this module bounds the
shape space instead (the ROADMAP shape-bucketing item):

  * **batch buckets** — a small fixed ladder of batch sizes.  A drained
    chunk of ``b`` requests is zero-padded up to the smallest bucket
    ``>= b`` and the padded rows are masked out of the decode, so the
    jit cache holds at most ``len(batch_sizes)`` entries per variant.
  * **resolution buckets** — the set of legal crop resolutions.  Each
    variant projects its SRoIs at its own fixed input size, so the
    resolution set is exactly the ladder's input sizes; the helper
    validates that no dispatch can introduce an off-ladder shape.

``VariantQueues`` is the tick-level request fabric shared by
``PodServer`` and the baselines: requests accumulate per variant and
drain into bucketed chunks, each chunk becoming one batched detector
forward (``repro.serving.scheduler.*.infer_srois_batched``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Sequence

DEFAULT_BATCH_BUCKETS = (1, 2, 4, 8)
# detection-count ladder for the tick's batched spherical-NMS rows:
# rows pad to the smallest member >= the tick's max row length, so the
# (B, N) device path compiles one program per ladder rung instead of
# one per distinct detection count (ROADMAP: bounded NMS shapes).
DEFAULT_NMS_SIZES = (8, 16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class ShapeBuckets:
    """The bounded shape space of batched dispatches.

    ``batch_sizes`` must be strictly increasing; ``resolutions`` is the
    optional set of legal (square) crop sizes (``None`` = unrestricted,
    for oracle backends that never touch pixels).
    """

    batch_sizes: tuple[int, ...] = DEFAULT_BATCH_BUCKETS
    resolutions: tuple[int, ...] | None = None
    nms_sizes: tuple[int, ...] = DEFAULT_NMS_SIZES

    def __post_init__(self):
        for name, sizes in (("batch", self.batch_sizes),
                            ("nms", self.nms_sizes)):
            if not sizes or any(b <= 0 for b in sizes):
                raise ValueError(f"invalid {name} buckets {sizes}")
            if list(sizes) != sorted(set(sizes)):
                raise ValueError(
                    f"{name} buckets must be strictly increasing: {sizes}")

    @property
    def max_batch(self) -> int:
        return self.batch_sizes[-1]

    def pad_batch(self, b: int) -> int:
        """Smallest bucket >= ``b`` (the padded dispatch batch size)."""
        if b <= 0 or b > self.max_batch:
            raise ValueError(f"batch {b} outside buckets {self.batch_sizes}")
        for size in self.batch_sizes:
            if size >= b:
                return size
        raise AssertionError  # unreachable: b <= max_batch

    def split(self, count: int) -> list[int]:
        """Split ``count`` queued requests into chunk sizes <= max_batch.

        Greedy full-bucket chunks followed by one remainder chunk; the
        remainder still pads up to a bucket, never to an ad-hoc shape.
        """
        out, rest = [], count
        while rest > self.max_batch:
            out.append(self.max_batch)
            rest -= self.max_batch
        if rest:
            out.append(rest)
        return out

    def pad_nms_rows(self, n: int) -> int:
        """Smallest NMS bucket >= ``n`` (the padded row length of the
        tick's batched-NMS dispatch).  Beyond the top rung, rows round
        up to a top-rung multiple so pathological ticks stay bounded
        (one extra shape per multiple) instead of erroring."""
        if n <= 0:
            return self.nms_sizes[0]
        for size in self.nms_sizes:
            if size >= n:
                return size
        top = self.nms_sizes[-1]
        return -(-n // top) * top

    def bucket_resolution(self, size: int) -> int:
        """Validate/snap a crop resolution into the bounded set."""
        if self.resolutions is None:
            return size
        if size in self.resolutions:
            return size
        raise ValueError(
            f"crop resolution {size} outside buckets {self.resolutions}")

    @classmethod
    def for_max_batch(cls, max_batch: int,
                      resolutions: tuple[int, ...] | None = None
                      ) -> "ShapeBuckets":
        """Default bucket ladder capped at ``max_batch`` (kept as the
        top bucket so a full drain always lands on an exact bucket)."""
        sizes = tuple(b for b in DEFAULT_BATCH_BUCKETS if b < max_batch)
        return cls(sizes + (max_batch,), resolutions)


@dataclasses.dataclass
class QueuedRequest:
    """One SRoI inference request parked in a variant queue.

    ``deadline`` is the owning stream's latency budget (seconds) —
    the cross-variant ordering key of
    ``repro.serving.runtime.DeadlineOrderPolicy``; ``emitted_s`` is
    the event-clock time the request was emitted (no dispatch may
    launch before it); ``age`` counts whole ticks the request has
    waited in the queue (bumped by every drain that leaves it behind —
    the async carry-over staleness bound).

    ``task`` names the owning analytics task (``repro.serving.tasks``).
    Queues still key on the variant NAME — task ladders own disjoint
    name spaces, so (task, variant) and the name are the same key —
    but the tag rides along for per-task accounting and telemetry.
    """

    request: Any                  # repro.core.omnisense.InferenceRequest
    owner: Any                    # opaque scatter key (the pending frame)
    backend: Any                  # executes the batched forward
    latency_model: Any = None     # prices the dispatch (may be None)
    deadline: float | None = None
    emitted_s: float = 0.0
    age: int = 0
    task: str = "detection"
    # the stream frame index the request was emitted for.  Simulation
    # backends (``set_frame``) sample ground truth by CURRENT frame, so
    # a request carried across ticks must be replayed at its emission
    # frame or it would observe the future (real pixel backends are
    # immune: the pixels travel inside the request).
    frame_idx: int | None = None


class VariantQueues:
    """Per-variant request queues drained into bucketed batched forwards.

    ``put`` parks requests; ``drain`` empties every queue into chunks of
    at most ``buckets.max_batch`` requests, issues one
    ``infer_srois_batched`` call per (chunk, backend) group and returns
    the per-request detections plus per-dispatch accounting records.
    Variants are drained in sorted-name order so a tick's dispatch
    schedule is deterministic.
    """

    def __init__(self, buckets: ShapeBuckets | None = None):
        self.buckets = buckets or ShapeBuckets()
        self._queues: dict[str, collections.deque] = collections.defaultdict(
            collections.deque)

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def put(self, item: QueuedRequest) -> None:
        self._queues[item.request.variant.name].append(item)

    def counts(self) -> dict[str, int]:
        """Live queue depth per variant (zero-depth variants included
        once seen, so drain planners observe a stable key set)."""
        return {name: len(q) for name, q in self._queues.items()}

    def peek(self, name: str) -> tuple[QueuedRequest, ...]:
        """The queue's items in FIFO (pop) order, without popping —
        drain policies read deadlines/ages from here."""
        return tuple(self._queues.get(name, ()))

    def head(self, name: str) -> QueuedRequest | None:
        """The queue's next-to-pop item without the O(n) copy of
        :meth:`peek` (per-chunk pricing only needs the variant and
        latency model, which every item of a queue shares)."""
        q = self._queues.get(name)
        return q[0] if q else None

    def newly_carried(self) -> int:
        """Queued requests that were carried for the FIRST time by the
        drain that just ran (``age == 1``: :meth:`drain_ops` ages every
        left-behind request once per drain).  Summing this per tick
        counts each carried request exactly once, however many ticks it
        ends up waiting — the unique-requests carry counter
        (``ServeStats.carried_requests``)."""
        return sum(1 for q in self._queues.values()
                   for item in q if item.age == 1)

    def full_drain_ops(self) -> list[tuple[str, int]]:
        """The plan covering EVERY queued request: variants in
        sorted-name order, one op per bucket-capped chunk
        (``ShapeBuckets.split``) — the pre-runtime schedule.  The
        single source of the full-drain chunking, shared by
        :meth:`drain`, the sync policy and ``PodServer.flush`` so the
        three can never disagree on it."""
        return [(name, take) for name in sorted(self._queues)
                for take in self.buckets.split(len(self._queues[name]))]

    def drain(self, placement=None
              ) -> tuple[list[tuple[QueuedRequest, list]], list[dict]]:
        """Empty all queues; returns (results, dispatch records) —
        :meth:`drain_ops` over :meth:`full_drain_ops`."""
        return self.drain_ops(self.full_drain_ops(), placement)

    def drain_ops(self, ops, placement=None
                  ) -> tuple[list[tuple[QueuedRequest, list]], list[dict]]:
        """Execute an explicit dispatch plan; returns (results, records).

        ``ops``: ordered ``(variant_name, take)`` pairs (or objects
        with ``.variant``/``.take`` — ``repro.serving.runtime.DrainOp``)
        each popping ``take`` requests FIFO into ONE batched forward.
        Requests not covered by any op stay queued (the async
        carry-over) and age by one tick.

        ``results``: (queued_request, detections) per drained request,
        in dispatch order.  ``dispatches``: one record per batched
        forward with the variant, real batch ``b``, padded bucket size
        and the items it served — the tick schedule the server prices.

        With a ``placement`` (``repro.serving.placement``), each
        chunk's forward routes to its variant's replica group and every
        forward is LAUNCHED before any result is resolved: backends
        exposing the non-blocking ``launch_srois_batched`` entry
        overlap the per-variant forwards across their disjoint device
        groups instead of serialising in plan order.
        """
        resolvers: list[tuple[list[QueuedRequest], Any]] = []
        dispatches: list[dict] = []
        for op in ops:
            name, take = (op.variant, op.take) if hasattr(op, "variant") \
                else op
            q = self._queues[name]
            if not 0 < take <= len(q):
                raise ValueError(
                    f"drain op wants {take} of variant {name!r} but the "
                    f"queue holds {len(q)}")
            if take > self.buckets.max_batch:
                raise ValueError(
                    f"drain op of {take} exceeds the top bucket "
                    f"{self.buckets.max_batch}")
            group = placement.group_for(name) if placement is not None \
                else None
            chunk = [q.popleft() for _ in range(take)]
            resolvers.extend(
                self._launch_chunk(name, chunk, dispatches, group))
        results: list[tuple[QueuedRequest, list]] = []
        for items, resolve in resolvers:
            dets = resolve()
            assert len(dets) == len(items)
            results.extend(zip(items, dets))
        for q in self._queues.values():  # carried requests wait a tick
            for item in q:
                item.age += 1
        return results, dispatches

    def _launch_chunk(self, name: str, chunk: Sequence[QueuedRequest],
                      dispatches: list[dict], group=None):
        """One drained chunk -> one (launched) batched detector forward.

        Streams normally share one backend (the real detector ladder),
        so the whole chunk is a single ``infer_srois_batched`` call;
        per-stream oracle backends sub-group by identity (an execution
        detail of the simulation — the chunk remains ONE dispatch in
        the tick schedule the server prices).  ``set_frame`` backends
        additionally sub-group by the requests' emission frame and are
        replayed at it, so a request carried across ticks still
        samples the ground truth of the frame that emitted it.
        Returns ``(items, resolver)`` pairs; backends without a
        non-blocking entry execute inline and resolve trivially.
        """
        variant = chunk[0].request.variant
        groups: dict[tuple, list[QueuedRequest]] = {}
        for item in chunk:
            frame_key = item.frame_idx \
                if hasattr(item.backend, "set_frame") else None
            groups.setdefault((id(item.backend), frame_key), []).append(item)
        out = []
        # virtual-slot groups price the tick model but cannot host a
        # sharded forward — execution falls back to the plain batched
        # path while the dispatch record keeps the group for pricing
        exec_group = group if group is not None and not group.is_virtual \
            else None
        for (_, frame_key), items in groups.items():
            backend = items[0].backend
            if frame_key is not None:
                backend.set_frame(frame_key)
            pairs = [(it.request.frame, it.request.region) for it in items]
            if hasattr(backend, "launch_srois_batched"):
                out.append((items, backend.launch_srois_batched(
                    pairs, variant, exec_group)))
            else:
                dets = backend.infer_srois_batched(pairs, variant)
                out.append((items, lambda dets=dets: dets))
        # `semantic`: every backend in the chunk declares its batched
        # entry a pure simulation (`semantic_batch = True`, e.g. the
        # oracle), so the chunk models ONE shared-accelerator dispatch
        # and is priced as such.  Otherwise each backend group is a
        # real forward and must be priced individually.
        dispatches.append(dict(
            variant=name,
            b=len(chunk),
            padded=self.buckets.pad_batch(len(chunk)),
            items=list(chunk),
            forwards=len(groups),
            group_sizes=[len(items) for items in groups.values()],
            semantic=all(getattr(it.backend, "semantic_batch", False)
                         for it in chunk),
            group=group,
        ))
        return out

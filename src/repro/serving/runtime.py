"""Event-driven serving runtime: the tick-overlap clock + drain policies.

``PodServer.step`` used to be a tick-barrier monolith: every variant
queue drained fully, the tick paid ``max`` over per-group dispatch sums,
and no stream advanced until the slowest replica group finished.  That
barrier is exactly the serialization the paper's pipeline overlapping
avoids — the edge admits work as capacity frees, not at batch
boundaries.  This module makes the timeline explicit so drain policies
can be composed instead of hard-coded:

  * :class:`GroupClock` — the pod's event clock: ``now`` (the current
    tick's start) plus a monotone ``free_at`` per replica group.  A
    dispatch on group ``g`` launches at ``max(now, free_at(g))`` (groups
    serialise internally, run concurrently across each other) and
    pushes ``free_at(g)`` to its completion — the tick-overlap pricing
    the ROADMAP's async-drain item needed.
  * :class:`DispatchEvent` / :class:`TickTimeline` — one record per
    batched forward with launch/complete stamps.  The timeline
    generalises ``OmniSenseLatencyModel.tick_inference_delay`` to
    overlapping dispatches: with no carry-in its barrier delay is
    bit-identical to the old max-over-group-sums charge
    (:meth:`TickTimeline.barrier_delay`), and with carry-in the
    event-time horizon prices work launched while a group was still
    busy from an earlier tick (``tick_overlap_delay`` on the latency
    model is the same curve in closed form).
  * :class:`SchedulePolicy` — owns the three decisions the monolith
    hard-wired: **admission** (per-stream knapsacks vs the pod-level
    fixed point, the old ``pod_allocate`` flag), **drain ordering**
    (which chunk dispatches first) and **carry-over** (which requests
    wait for the next tick).  ``PodServer.step``/``run`` are thin
    drivers over whatever policy is plugged in.

Shipped policies:

  * :class:`SyncTickPolicy` — the pre-refactor behaviour, bit-identical
    on seeded corpora (sorted-variant drain order, full drain every
    tick, barrier advance; proven by the equivalence tests in
    ``tests/test_runtime.py``).
  * :class:`DeadlineOrderPolicy` — earliest-deadline-first cross-variant
    ordering over the streams' latency budgets, shortest-forward-first
    among equal deadlines.  Same dispatches, same tick makespan, but
    urgent/cheap chunks complete earlier, which is what the event-clock
    E2E percentiles in ``serving_bench --policy`` measure.
  * :class:`AsyncDrainPolicy` — residual sub-bucket chunks carry to the
    next tick while their replica group is still busy (or sits on the
    tick's critical path), merging into fuller batches; the tick
    advances as soon as capacity frees (min over busy groups) instead
    of at the barrier.  Priced end-to-end by the overlap model; on a
    single-group pod the advance degenerates to the barrier over the
    admitted work while carry-over still merges chunks.

All three price from one shared curve: the pod-level allocator's
per-group :func:`repro.serving.pod_allocation.projected_group_load`
(``solve_pod`` exports it per tick; without pod-level allocation the
policies rebuild the same chunked-drain sums from the live queues via
the server's chunk-cost callable), so the capacity envelope and the
drain decisions can never disagree on what a queue costs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

_EPS = 1e-12

# how many ticks a residual request may be carried before the async
# policy must dispatch it (bounds per-request staleness to one tick)
DEFAULT_MAX_CARRY = 1


@dataclasses.dataclass(frozen=True)
class DispatchEvent:
    """One batched forward on the event clock.

    ``launch_s``/``complete_s`` are absolute clock seconds;
    ``emitted_s`` is the latest emission time over the requests the
    dispatch serves, so ``launch_s >= emitted_s`` is the causality
    invariant the property tests pin (no dispatch may launch before its
    inputs exist).  ``carried`` counts the chunk's requests that waited
    at least one tick in the queue (async carry-over).
    """

    variant: str
    b: int
    padded: int
    group: int
    n_devices: int
    cost_s: float
    launch_s: float
    complete_s: float
    emitted_s: float
    tick: int
    carried: int = 0


class GroupClock:
    """Per-replica-group availability on one shared event timeline.

    ``now`` is the current tick's start (monotone — it only advances);
    ``free_at(g)`` is when group ``g``'s last dispatch completes
    (monotone per group: every dispatch launches at
    ``max(now, free_at(g))`` and can only push the horizon out).
    Groups the clock has never seen are free at the clock's start.
    """

    def __init__(self, start: float = 0.0):
        self.start = start
        self.now = start
        self._free_at: dict[int, float] = {}

    def free_at(self, group: int) -> float:
        return self._free_at.get(group, self.start)

    def busy(self, group: int) -> bool:
        """Whether ``group`` is still executing past the current tick
        start (i.e. carrying work over from an earlier tick)."""
        return self.free_at(group) > self.now + _EPS

    def dispatch(self, group: int, cost_s: float) -> tuple[float, float]:
        """Book one dispatch; returns ``(launch_s, complete_s)``."""
        if cost_s < 0:
            raise ValueError(f"dispatch cost must be >= 0, got {cost_s}")
        launch = max(self.now, self.free_at(group))
        complete = launch + cost_s
        self._free_at[group] = complete
        return launch, complete

    def horizon(self) -> float:
        """When the last booked dispatch completes (>= ``now``)."""
        return max(self.now, max(self._free_at.values(), default=self.now))

    def carry(self) -> dict[int, float]:
        """Busy seconds past ``now`` per group still executing (empty
        when every group is free) — the open-loop admission backlog's
        carry-in term."""
        return {g: t - self.now for g, t in self._free_at.items()
                if t > self.now + _EPS}

    def next_free(self) -> float | None:
        """Earliest completion among groups still busy past ``now``
        (``None`` when every group is already free) — the async
        policy's "admit as capacity frees" advance point."""
        busy = [t for t in self._free_at.values() if t > self.now + _EPS]
        return min(busy) if busy else None

    def advance(self, to: float) -> float:
        """Move the tick start forward (never backward)."""
        self.now = max(self.now, to)
        return self.now


class TickTimeline:
    """The event record of one scheduler tick.

    Generalises ``OmniSenseLatencyModel.tick_inference_delay`` to
    overlapping dispatches: :meth:`barrier_delay` reproduces the old
    charge exactly (max over per-group cost sums, carry-in ignored)
    while :meth:`overlap_delay` prices the true event horizon — what
    the tick costs when some groups were still busy at its start.
    """

    def __init__(self, tick: int, start: float):
        self.tick = tick
        self.start = start
        self.events: list[DispatchEvent] = []
        # per-group cost sums in dispatch order: the same accumulation
        # the barrier server used, so barrier_delay is bit-identical
        self.group_costs: dict[int, float] = {}
        self.carry_in: dict[int, float] = {}

    def open_group(self, group: int, free_at: float) -> None:
        """Record a group's carry-in (busy seconds past the tick
        start) the first time the tick touches it."""
        if group not in self.carry_in:
            self.carry_in[group] = max(0.0, free_at - self.start)

    def record(self, event: DispatchEvent) -> None:
        self.events.append(event)
        self.group_costs[event.group] = (
            self.group_costs.get(event.group, 0.0) + event.cost_s)

    def barrier_delay(self, tick_lat=None) -> float:
        """The pre-refactor tick charge: every group starts free at the
        tick boundary, groups run concurrently, dispatches within a
        group serialise — max over per-group sums.  ``tick_lat`` is
        ``OmniSenseLatencyModel.tick_inference_delay`` when the pricing
        latency model provides one (kept so a curve change there cannot
        silently diverge from the runtime's charge)."""
        if tick_lat is not None:
            return tick_lat(self.group_costs.values())
        return max(self.group_costs.values(), default=0.0)

    def overlap_delay(self) -> float:
        """Event-time tick cost: latest completion relative to the tick
        start.  Equals :meth:`barrier_delay` (up to float association)
        when no group carried work in; strictly larger on the group
        that was still busy — the overlap pricing of carried work."""
        return max((e.complete_s for e in self.events),
                   default=self.start) - self.start

    def horizon(self) -> float:
        return max((e.complete_s for e in self.events), default=self.start)


@dataclasses.dataclass(frozen=True)
class DrainOp:
    """One planned dispatch: pop ``take`` queued requests of
    ``variant`` (FIFO) and run them as a single batched forward."""

    variant: str
    take: int


# admission verdicts (AdmissionPolicy.decide return values)
ADMIT = "admit"
DEGRADE = "degrade"
REJECT = "reject"


class AdmissionPolicy:
    """Open-loop admission: what to do with one arriving frame.

    Closed-loop ticks admit everything by construction (the clock only
    advances at pod capacity), so admission is a no-op there.  Under
    arrival-clocked traffic (``PodServer.run_open_loop``) every arrival
    consults the schedule policy's ``admission`` hook BEFORE emission:

      * ``ADMIT`` — emit the stream's full allocator plan;
      * ``DEGRADE`` — re-plan restricted to skip + the P1 variant (the
        cheapest real model), shedding load while keeping the frame;
      * ``REJECT`` — drop the frame entirely (counted, never served).

    ``decide`` sees the pod's projected state in seconds: ``backlog_s``
    (busy carry-in plus queued drain cost, max over replica groups, on
    the server's shared pricing curve), the candidate plan's cost, the
    degraded plan's cost, and the run's SLO target (``None`` when the
    run has no SLO — the default policy admits everything either way).
    """

    name = "admit-all"

    def decide(self, *, backlog_s: float, plan_cost_s: float,
               degraded_cost_s: float, slo_s: float | None) -> str:
        del backlog_s, plan_cost_s, degraded_cost_s, slo_s
        return ADMIT


class SloAdmissionPolicy(AdmissionPolicy):
    """Admit while the projected completion fits the SLO envelope.

    The envelope is ``slo_s * slack``: a frame whose backlog + full
    plan cost fits is admitted untouched; one that fits only with the
    degraded (P1-only) plan is degraded; one that cannot fit even
    degraded is rejected — graceful degradation before load shedding,
    the paper's under-pressure behaviour.  With no SLO configured the
    policy admits everything (same as :class:`AdmissionPolicy`).
    """

    name = "slo"

    def __init__(self, slack: float = 1.0):
        if slack <= 0:
            raise ValueError(f"slack must be > 0, got {slack}")
        self.slack = slack

    def decide(self, *, backlog_s: float, plan_cost_s: float,
               degraded_cost_s: float, slo_s: float | None) -> str:
        if slo_s is None:
            return ADMIT
        limit = slo_s * self.slack
        if backlog_s + plan_cost_s <= limit + _EPS:
            return ADMIT
        if backlog_s + degraded_cost_s <= limit + _EPS:
            return DEGRADE
        return REJECT


ADMISSIONS: dict[str, type[AdmissionPolicy]] = {
    AdmissionPolicy.name: AdmissionPolicy,
    SloAdmissionPolicy.name: SloAdmissionPolicy,
}


def make_admission(spec) -> AdmissionPolicy:
    """Resolve an admission spec: instance passes through, a registered
    name constructs, ``None`` means admit-all."""
    if spec is None:
        return AdmissionPolicy()
    if isinstance(spec, AdmissionPolicy):
        return spec
    try:
        cls = ADMISSIONS[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown admission policy {spec!r}; choose from "
            f"{sorted(ADMISSIONS)} or pass an AdmissionPolicy instance"
        ) from None
    return cls()


class SchedulePolicy:
    """The serving runtime's decision surface (see module docstring).

    Subclasses override :meth:`plan_drain` (admission order +
    carry-over) and :meth:`close_tick` (when the next tick may start
    and what the finished tick is charged).  ``pod_allocate`` is the
    admission half the old ``PodServer(pod_allocate=True)`` boolean
    controlled: whether each tick's plans come from the pod-level
    fixed point (``repro.serving.pod_allocation.solve_pod``) or from
    per-stream knapsacks.  ``admission`` is the open-loop arrival hook
    (:class:`AdmissionPolicy` instance or registered name; default
    admit-all) consulted by ``PodServer.run_open_loop`` — closed-loop
    ``step``/``run`` never invoke it.
    """

    name = "base"

    def __init__(self, pod_allocate: bool = False, admission=None):
        self.pod_allocate = pod_allocate
        self.admission = make_admission(admission)

    def describe(self) -> dict:
        """The policy's replayable configuration — what the telemetry
        ``run_meta`` records and the replay harness reconstructs
        (subclasses extend with their own knobs, e.g. ``max_carry``)."""
        return {"name": self.name, "pod_allocate": self.pod_allocate,
                "admission": self.admission.name}

    # -- drain -------------------------------------------------------------

    def plan_drain(self, queues, buckets, placement, clock: GroupClock, *,
                   chunk_cost=None, projected_load=None) -> list[DrainOp]:
        """Return the tick's ordered dispatch list.

        ``queues`` is the live :class:`~repro.serving.batching.
        VariantQueues`; ``chunk_cost(variant_name, b)`` prices one
        chunk on the server's curve (marginal overrides included);
        ``projected_load`` is the per-group expected drain seconds of
        this tick's demand — ``solve_pod``'s exported projection under
        pod-level allocation, else recomputed from the queues with the
        same shared helper.  Requests not covered by the returned ops
        stay queued (carry-over) and age by one tick.
        """
        raise NotImplementedError

    # -- clock -------------------------------------------------------------

    def close_tick(self, clock: GroupClock, timeline: TickTimeline,
                   tick_lat=None, overlap_lat=None) -> tuple[float, float]:
        """Return ``(charge_s, next_tick_start)`` for a finished tick.

        ``tick_lat``/``overlap_lat`` are the pricing latency model's
        ``tick_inference_delay``/``tick_overlap_delay`` hooks when it
        provides them.  The base rule is the barrier: the next tick
        starts when every group is free, and the charge is the
        pre-refactor max-over-group-sums (bit-identical via
        :meth:`TickTimeline.barrier_delay`).
        """
        del overlap_lat  # barrier ticks never start with carry-in
        return timeline.barrier_delay(tick_lat), clock.horizon()

    # -- placement ---------------------------------------------------------

    def rebalance_point(self, placement, clock: GroupClock,
                        queues) -> bool:
        """Whether NOW is a placement-rebalance opportunity.

        ``PodServer`` consults this hook wherever it used to call
        ``placement.maybe_rebalance()`` unconditionally (after each
        closed-loop emission wave and each open-loop admission);
        returning ``False`` defers the rebalance check entirely, so a
        policy can pin atomically-moving devices to its own capacity
        boundaries.  The base rule is every emission — bit-identical
        to the pre-hook hard-wired timing (pinned by the sync
        equivalence corpus in ``tests/test_runtime.py``).
        """
        del placement, clock, queues
        return True

    # -- helpers shared by the shipped policies ----------------------------

    @staticmethod
    def _group_index(placement, variant_name: str) -> int:
        if placement is None:
            return 0
        return placement.group_for(variant_name).index

    def _full_drain_ops(self, queues, buckets) -> list[DrainOp]:
        """Sorted-variant full drain — the pre-refactor schedule
        (``VariantQueues.full_drain_ops`` is the single source of the
        chunking; the server validates its buckets match the queues')."""
        del buckets
        return [DrainOp(name, take) for name, take in queues.full_drain_ops()]


class SyncTickPolicy(SchedulePolicy):
    """Bit-identical to the pre-refactor ``PodServer.step``: every
    queue drains fully in sorted-variant order and the next tick waits
    at the barrier for the slowest replica group."""

    name = "sync"

    def plan_drain(self, queues, buckets, placement, clock, *,
                   chunk_cost=None, projected_load=None) -> list[DrainOp]:
        del placement, clock, chunk_cost, projected_load
        return self._full_drain_ops(queues, buckets)


class DeadlineOrderPolicy(SchedulePolicy):
    """Earliest-deadline-first cross-variant dispatch ordering.

    Every queue still drains fully (no carry-over; the tick makespan
    equals sync's), but chunks launch in ``(deadline, cost/b, name)``
    order instead of sorted-variant order: a chunk's deadline is the
    tightest ABSOLUTE due time (emission time + the stream's latency
    budget) among the requests it serves — so staggered arrivals sort
    by when work is actually due and carried requests gain urgency as
    they age — and equal
    deadlines fall back to shortest-forward-first PER REQUEST SERVED
    (weighted SJF — a cheap b=1 forward must not jump a b=8 batch and
    delay eight frames to advance one).  FIFO precedence within a
    variant is kept by giving every chunk the suffix-min of its
    variant's remaining keys: a chunk blocking an urgent chunk sorts
    with the urgent key, so precedence never demotes a deadline.
    Within a replica group urgent/cheap forwards therefore complete
    first, cutting the per-request event-clock E2E when variants
    differ 5x in cost (the ROADMAP cross-variant-ordering item).
    """

    name = "deadline"

    def plan_drain(self, queues, buckets, placement, clock, *,
                   chunk_cost=None, projected_load=None) -> list[DrainOp]:
        del clock, projected_load
        per_variant: dict[str, list[tuple]] = {}
        for name, count in sorted(queues.counts().items()):
            if not count:
                continue
            items = queues.peek(name)
            lo = 0
            for b in buckets.split(count):
                chunk = items[lo:lo + b]
                lo += b
                # EDF orders by ABSOLUTE due time: a request's deadline
                # field is the stream's relative latency budget, so the
                # due time is emission + budget.  (Sorting the bare
                # budget is only equivalent while every emission shares
                # one tick boundary — wrong under staggered arrivals,
                # and it would deny carried/aged requests the urgency
                # their early emission earned.)
                deadline = min((it.emitted_s + it.deadline for it in chunk
                                if it.deadline is not None),
                               default=float("inf"))
                cost = chunk_cost(name, b) if chunk_cost is not None else 0.0
                per_variant.setdefault(name, []).append(
                    ((deadline, cost / b, name), DrainOp(name, b)))
        # a DrainOp pops FIFO, so a variant's chunks must dispatch in
        # their original split order.  A chunk therefore inherits the
        # urgency of everything it BLOCKS: its effective key is the
        # suffix-min of its variant's remaining chunk keys (EDF with
        # precedence).  Effective keys are non-decreasing along each
        # FIFO sequence by construction, so one stable sort yields a
        # global deadline order that never inverts a variant's chunks
        # — and never lets a lax early chunk squat on the slot a tight
        # later chunk of the same variant earned.
        keyed = []
        for chunks in per_variant.values():
            keys = [key for key, _ in chunks]
            for i in range(len(keys) - 2, -1, -1):
                keys[i] = min(keys[i], keys[i + 1])
            keyed.extend(zip(keys, (op for _, op in chunks)))
        keyed.sort(key=lambda kv: kv[0])
        return [op for _, op in keyed]


class AsyncDrainPolicy(SchedulePolicy):
    """Residual sub-bucket chunks carry over; the tick advances as
    capacity frees.

    Drain order follows sync (sorted variants), but a variant's final
    chunk is withheld when it under-fills the top batch bucket AND its
    replica group either (a) is still busy executing an earlier tick's
    work, or (b) sits on this tick's critical path (its carry-in plus
    projected drain load — the shared
    :func:`~repro.serving.pod_allocation.projected_group_load` curve —
    is the pod max, so shedding its residual shortens the tick).
    Carried requests age by one tick and are dispatched once any of
    them reaches ``max_carry`` ticks waited, bounding staleness.

    Carry-over is additionally DEADLINE-AWARE: a residual chunk is
    withheld only while the merged batch it would join still meets the
    tightest ABSOLUTE due time (emission + latency budget) among the
    withheld requests — the carried chunk cannot complete before the
    group's expected drain horizon plus its own forward, so when that
    projection busts a member's deadline the chunk dispatches NOW
    instead.  This bounds the event-E2E tail that pure
    batch-efficiency carry paid (the ROADMAP deadline-aware-carry
    follow-on); requests without deadlines are always carry-eligible.

    :meth:`close_tick` advances to the earliest busy-group completion
    (``GroupClock.next_free``) instead of the barrier and charges the
    elapsed event time, so the mean tick is the true interleaved
    makespan over ticks; ``PodServer.flush`` settles the tail.  On a
    single-group pod the advance rule degenerates to the barrier over
    the ADMITTED work (nothing overlaps), but residual carry-over
    still merges sub-bucket chunks into fuller batches.
    """

    name = "async"

    def __init__(self, pod_allocate: bool = False,
                 max_carry: int = DEFAULT_MAX_CARRY, admission=None):
        super().__init__(pod_allocate, admission)
        if max_carry < 1:
            raise ValueError(f"max_carry must be >= 1, got {max_carry}")
        self.max_carry = max_carry

    def describe(self) -> dict:
        return {**super().describe(), "max_carry": self.max_carry}

    def plan_drain(self, queues, buckets, placement, clock, *,
                   chunk_cost=None, projected_load=None) -> list[DrainOp]:
        counts = queues.counts()
        load = self._group_load(queues, buckets, placement, chunk_cost,
                                projected_load)
        expected = {g: max(0.0, clock.free_at(g) - clock.now) + s
                    for g, s in load.items()}
        critical = max(expected.values(), default=0.0)
        ops = []
        for name in sorted(counts):
            count = counts[name]
            if not count:
                continue
            chunks = buckets.split(count)
            g = self._group_index(placement, name)
            if (chunks[-1] < buckets.max_batch
                    and self._may_carry(queues.peek(name), chunks[-1])
                    and self._deadline_allows(
                        queues.peek(name), chunks[-1], name, g,
                        clock, expected, chunk_cost)
                    and (clock.busy(g)
                         or expected.get(g, 0.0) >= critical - _EPS)):
                chunks = chunks[:-1]
            ops.extend(DrainOp(name, b) for b in chunks)
        return ops

    def _may_carry(self, items: Sequence, residual: int) -> bool:
        """The residual chunk is the queue's newest ``residual`` items;
        carrying is allowed only while all of them are fresher than
        ``max_carry`` ticks (so no request waits unboundedly)."""
        return all(it.age < self.max_carry for it in items[-residual:])

    def _deadline_allows(self, items: Sequence, residual: int, name: str,
                         group: int, clock, expected, chunk_cost) -> bool:
        """Carry only while the merged batch still meets the tightest
        withheld member's absolute due time.

        A carried chunk cannot complete before the group's expected
        drain horizon (carry-in plus this tick's projected load, which
        already prices the residual itself) plus the merged forward it
        joins next tick — lower-bounded by the residual's own chunk
        cost.  When that projection busts ``emitted_s + deadline`` for
        any withheld request, the chunk must dispatch now.
        """
        due = min((it.emitted_s + it.deadline for it in items[-residual:]
                   if it.deadline is not None), default=math.inf)
        if due == math.inf:
            return True
        cost = chunk_cost(name, residual) if chunk_cost is not None else 0.0
        eta = clock.now + expected.get(group, 0.0) + cost
        return eta <= due + _EPS

    def _group_load(self, queues, buckets, placement, chunk_cost,
                    projected_load) -> dict[int, float]:
        """Per-group expected drain seconds of the queued demand.

        With the pod-level allocator's exported projection
        (``solve_pod`` already priced this tick's EMISSIONS on the
        shared curve) the policy consumes it and only adds the
        requests an earlier tick carried over — the projection cannot
        know about those, and ignoring them would misplace the
        critical path right after a carry.  Without a projection the
        whole chunked-drain sum is rebuilt from the live queues on the
        server's chunk-cost curve.
        """
        if chunk_cost is None:
            return dict(projected_load or {})
        load: dict[int, float] = dict(projected_load or {})
        for name, count in queues.counts().items():
            if not count:
                continue
            n = count if projected_load is None else \
                sum(1 for it in queues.peek(name) if it.age > 0)
            if not n:
                continue
            g = self._group_index(placement, name)
            load[g] = load.get(g, 0.0) + sum(
                chunk_cost(name, b) for b in buckets.split(n))
        return load

    def close_tick(self, clock, timeline, tick_lat=None, overlap_lat=None):
        del tick_lat, overlap_lat  # the event clock IS the async price
        nxt = clock.next_free()
        if nxt is None:
            nxt = timeline.horizon()
        return max(0.0, nxt - timeline.start), nxt

    def rebalance_point(self, placement, clock, queues) -> bool:
        """Rebalance only at capacity boundaries: while any replica
        group is still executing carried work past the tick start,
        moving devices would invalidate the in-flight dispatch pricing
        the carry decision was made against — wait until every group is
        free (the same advance point :meth:`close_tick` targets)."""
        del placement, queues
        return clock.next_free() is None


POLICIES: dict[str, type[SchedulePolicy]] = {
    SyncTickPolicy.name: SyncTickPolicy,
    DeadlineOrderPolicy.name: DeadlineOrderPolicy,
    AsyncDrainPolicy.name: AsyncDrainPolicy,
}


def make_policy(spec, pod_allocate: bool = False,
                admission=None) -> SchedulePolicy:
    """Resolve a policy spec: an instance passes through (its own
    ``pod_allocate``/``admission`` win), a name constructs the
    registered class."""
    if isinstance(spec, SchedulePolicy):
        return spec
    try:
        cls = POLICIES[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown scheduling policy {spec!r}; choose from "
            f"{sorted(POLICIES)} or pass a SchedulePolicy instance"
        ) from None
    return cls(pod_allocate=pod_allocate, admission=admission)

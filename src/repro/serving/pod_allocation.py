"""Pod-level allocation: couple the per-stream knapsacks.

Algorithm 2 (``repro.core.allocation``) prices every inference request
as if the stream had the edge to itself, but at pod scale the true
marginal cost of a variant depends on how many co-streams pick it this
tick (the batched forward amortizes the fixed dispatch cost,
``OmniSenseLatencyModel.batched_inference_delay``) and on which replica
group serves it (dispatches within a group serialise; groups run
concurrently — ``repro.serving.placement``).  A stream planning alone
therefore both OVERPAYS for popular variants (it ignores the batching
discount it would share) and cannot see idle replica groups.

``solve_pod`` closes the loop with a capacity-enveloped best-response
fixed point:

  1. round 0 solves every stream's knapsack on its own base matrices —
     byte-identical to the uncoupled path.  These plans are the
     incumbents, and their projected device load defines the tick
     CAPACITY ENVELOPE ``T_cap`` (max over replica groups of the
     chunked drain cost, :func:`projected_tick` — the exact curve
     ``OmniSenseLatencyModel.tick_schedule_delay`` prices);
  2. each later round sweeps the streams in index order (Gauss–Seidel:
     the pod counts update as each stream re-plans).  Stream ``s``
     re-prices its ``d_inf`` rows against the co-stream demand —
     for variant ``v``:

         coupled = (d_inf * amort(v, 1 + co_v) + qw * wait_v)
                   * (1 + uw * utilisation[group(v)])

     where ``co_v`` is the co-stream demand for ``v``, ``amort`` is
     the per-request share of the chunked tick drain relative to the
     b=1 forward (``OmniSenseLatencyModel.pod_amortization``; == 1.0
     exactly at ``co_v == 0`` on one device, so a lone stream
     reproduces its uncoupled plan bit-for-bit), ``wait_v`` is the
     co-stream queue depth of OTHER variants sharing ``v``'s replica
     group (seconds, ``variant_queue_cost``) and ``utilisation`` is
     the observed cross-tick busy fraction of the group
     (``ServeStats.group_utilisation``), steering demand toward idle
     groups;
  3. the stream switches to its re-priced knapsack optimum ONLY when
     it is STRICTLY more valuable (or the incumbent went infeasible
     under the coupled prices) AND the switch keeps the pod's
     projected tick within ``T_cap`` — so the batching discount can
     upgrade plans (skips become runs, models grow) only into device
     time the uncoupled schedule was already paying for.  Keeping the
     incumbent on non-strict improvement is the tie-break that removes
     equal-value swap cycles; ``damping`` caps how many streams may
     switch per round;
  4. iterate until a full sweep changes nothing, or the round cap hits.

The envelope makes the coupled solution dominate by construction:
the projected tick never exceeds the uncoupled projection, and
per-stream values are monotone non-decreasing from the uncoupled
incumbents whenever those incumbents stay budget-feasible under the
coupled prices — structural with per-variant replica groups and no
utilisation markup, where every coupling term is a discount
(``factor <= 1``, no co-variant queue wait).  On a SHARED group the
queue-wait term (or a heavy utilisation markup) may price an
overcommitted incumbent out of its budget, and shedding that work is
the correct answer there.  Accuracy up at equal-or-lower tick latency
is exactly what ``benchmarks/serving_bench.py --pod-allocate``
measures and ``benchmarks/check_regression.py`` gates.

Termination is proven by the round cap; a convergent run is a genuine
fixed point — re-running :func:`best_response` against the returned
plans changes nothing (property-tested).  Degenerate pods
short-circuit: with one variant there is no cross-variant choice to
couple, and a single stream has no co-streams, so both return the
uncoupled plans unchanged (bit-identical).

``solve_pod_bruteforce`` enumerates the joint assignment space on tiny
pods — the oracle for the fixed point's feasibility/value tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import allocation
from repro.serving.batching import ShapeBuckets

DEFAULT_MAX_ROUNDS = 6
DEFAULT_DAMPING = 1.0       # fraction of streams allowed to switch/round
DEFAULT_QUEUE_WEIGHT = 0.5  # fraction of the co-stream group queue paid
DEFAULT_UTIL_WEIGHT = 0.5   # busy-group price inflation at utilisation 1
_TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class StreamProblem:
    """One stream's per-frame allocation instance.

    Mirrors ``OmniSenseLoop.FrameContext``: (1 + M, R) matrices with
    the zero-cost skip row 0, or ``None`` matrices when the frame
    predicted no SRoIs (the stream then plans nothing).

    ``variants``/``latency_model`` override the pod-level defaults for
    MIXED-TASK pods (``repro.serving.tasks``): the stream's matrices
    are shaped by ITS task's ladder and priced on ITS task's latency
    curve, while the solver still couples every stream under one
    capacity envelope over the union ladder.  ``None`` (the default)
    means "the shared pod ladder" — the single-task path, bit-identical
    to the pre-task-registry solver.
    """

    acc: np.ndarray | None
    d_pre: np.ndarray | None
    d_inf: np.ndarray | None
    budget: float
    variants: tuple | None = None
    latency_model: object | None = None


@dataclasses.dataclass(frozen=True)
class VariantPrice:
    """Coupled repricing terms of one variant for one stream.

    ``coupled_d_inf = (d_inf * factor + extra) * mult`` and
    ``coupled_d_pre = d_pre * pre_factor`` — identity
    (1.0, 0.0, 1.0, 1.0) exactly when the stream has no co-streams and
    the group is idle, which is what pins the degenerate cases.
    """

    factor: float  # batching amortization (<= 1: Q(n) <= n * Q(1))
    extra: float   # co-stream queue wait of the variant's group, seconds
    mult: float    # observed-utilisation congestion inflation (>= 1)
    # mobile-side d_pre amortization: projection/encode also batch when
    # co-streams share the variant (``pre_amortization``'s shallower
    # curve); == 1.0 EXACTLY at b=1, the identity pin that keeps
    # uncoupled d_pre pricing byte-identical
    pre_factor: float = 1.0

    def apply(self, d_inf: float) -> float:
        return (d_inf * self.factor + self.extra) * self.mult

    def apply_pre(self, d_pre: float) -> float:
        return d_pre * self.pre_factor


@dataclasses.dataclass
class PodSolution:
    plans: list            # allocation.Plan | None, one per stream
    rounds: int            # fixed-point rounds run (0 = short-circuit)
    converged: bool        # choices stabilised before the round cap
    counts: dict           # final per-variant request counts
    coupled: bool          # False when a degenerate pod short-circuited
    tick_cap: float        # capacity envelope (uncoupled projected tick)
    projected_tick: float  # projected tick of the returned plans
    # per replica group, the projected chunked-drain seconds of the
    # returned plans (projected_tick is its max).  Exported so the
    # serving runtime's drain policies price admission/carry-over from
    # the SAME curve as the envelope instead of recomputing it
    # (ROADMAP: "share projected_tick when they land").
    projected_load: dict = dataclasses.field(default_factory=dict)


def _plan_counts(plan, variants) -> dict[str, int]:
    out = {v.name: 0 for v in variants}
    if plan is not None:
        for i in plan.models:
            if i > 0:
                out[variants[i - 1].name] += 1
    return out


def _total_counts(plans, variants, problems=None) -> dict[str, int]:
    """Joint per-variant counts.  ``variants`` is the (union) key
    space; with ``problems``, each plan's model indices resolve through
    its stream's OWN ladder (mixed-task pods)."""
    out = {v.name: 0 for v in variants}
    for s, plan in enumerate(plans):
        svars = variants
        if problems is not None and problems[s].variants is not None:
            svars = problems[s].variants
        for name, c in _plan_counts(plan, svars).items():
            out[name] = out.get(name, 0) + c
    return out


def _union_ladder(problems, variants, latency_model):
    """The pod's union ladder: the shared base ladder first (in its
    given order — the float-sum order every single-task projection
    already uses), then per-stream override extras in first-seen
    order.  Returns ``(union, lat_by_name)``; base variants price on
    the base latency model, extras on their stream's override.
    """
    union = list(variants)
    seen = {v.name for v in variants}
    lat_by = {v.name: latency_model for v in variants}
    for p in problems:
        if p.variants is None:
            continue
        lat = p.latency_model if p.latency_model is not None \
            else latency_model
        for v in p.variants:
            if v.name not in seen:
                seen.add(v.name)
                union.append(v)
                lat_by[v.name] = lat
    return union, lat_by


def _group_of(placement, name):
    """(group index, n_devices) of a variant; the placement-less pod is
    one implicit single-device group (every dispatch serialises)."""
    if placement is None:
        return 0, 1
    g = placement.group_for(name)
    return g.index, g.n_devices


def projected_group_load(counts: dict, variants: Sequence, latency_model,
                         buckets: ShapeBuckets, placement=None,
                         latency_models: dict | None = None
                         ) -> dict[int, float]:
    """Per replica group, the chunked drain seconds of serving
    ``counts`` requests/variant (``variant_queue_cost`` — the same
    curve ``tick_schedule_delay`` prices).  The shared load projection:
    :func:`projected_tick` takes its max for the capacity envelope, and
    the serving runtime's drain policies consume it for carry-over
    decisions (``solve_pod`` exports it per tick so neither recomputes
    the other's numbers).  ``latency_models`` optionally maps variant
    name -> that variant's task latency model (mixed-task pods); absent
    entries fall back to ``latency_model``.
    """
    lat_by = latency_models or {}
    group_load: dict[int, float] = {}
    for v in variants:
        gidx, n_dev = _group_of(placement, v.name)
        group_load[gidx] = group_load.get(gidx, 0.0) + \
            lat_by.get(v.name, latency_model).variant_queue_cost(
                v, counts.get(v.name, 0), buckets, n_dev)
    return group_load


def projected_tick(counts: dict, variants: Sequence, latency_model,
                   buckets: ShapeBuckets, placement=None,
                   latency_models: dict | None = None) -> float:
    """Device-aware tick cost of serving ``counts`` requests/variant.

    Max over replica groups of :func:`projected_group_load` — the
    projection of what ``PodServer`` will charge via
    ``tick_inference_delay`` when these counts hit the queues, so the
    solver's capacity envelope and the served tick can never disagree
    on the curve.
    """
    return max(projected_group_load(counts, variants, latency_model,
                                    buckets, placement,
                                    latency_models).values(),
               default=0.0)


def stream_prices(
    variants: Sequence,
    co_counts: dict[str, int],
    latency_model,
    buckets: ShapeBuckets,
    placement=None,
    group_utilisation: dict | None = None,
    queue_weight: float = DEFAULT_QUEUE_WEIGHT,
    util_weight: float = DEFAULT_UTIL_WEIGHT,
    all_variants: Sequence | None = None,
    latency_models: dict | None = None,
) -> dict[str, VariantPrice]:
    """One stream's coupled repricing terms, per variant.

    ``co_counts``: this tick's demand for each variant from the OTHER
    streams.  Three coupling terms, all derived from the latency
    model's batched curve (``pod_amortization`` /
    ``variant_queue_cost``) — the same curve ``tick_schedule_delay``
    prices, so the allocator can never believe in a cost the tick
    model would not charge:

      * ``factor`` — the batching discount: per-request share of the
        variant's chunked tick drain (with this request joining the
        co-stream batch), relative to the solo b=1 forward;
      * ``extra``  — queue depth: the co-stream load of OTHER variants
        serialising ahead in the same replica group, in seconds;
      * ``mult``   — congestion: the group's observed cross-tick busy
        fraction (``ServeStats.group_utilisation``), steering demand
        toward idle groups.

    A stream with no co-streams and an idle group gets the exact
    identity (1.0, 0.0, 1.0, 1.0): coupling can never perturb a lone
    stream's plan.

    ``all_variants`` widens the queue-depth accumulation past the
    stream's OWN ladder (``variants``, the output keys) to the pod's
    union ladder, so a mixed-task stream pays for the OTHER task's
    load serialising in its replica groups; ``latency_models`` maps
    union variant names to their task's latency model.  Both default
    to the single-task identity.
    """
    lat_by = latency_models or {}
    pool = variants if all_variants is None else all_variants
    co = {v.name: max(0, int(round(co_counts.get(v.name, 0))))
          for v in pool}
    # co-stream queue depth per group, in device-busy seconds
    group_load: dict[int, float] = {}
    cost: dict[str, float] = {}
    for v in pool:
        gidx, n_dev = _group_of(placement, v.name)
        cost[v.name] = lat_by.get(v.name, latency_model).variant_queue_cost(
            v, co[v.name], buckets, n_dev)
        group_load[gidx] = group_load.get(gidx, 0.0) + cost[v.name]
    out: dict[str, VariantPrice] = {}
    for v in variants:
        gidx, n_dev = _group_of(placement, v.name)
        lm = lat_by.get(v.name, latency_model)
        factor = lm.pod_amortization(v, 1 + co[v.name], buckets, n_dev)
        pre_fn = getattr(lm, "pre_amortization", None)
        wait = group_load[gidx] - cost[v.name]  # other variants' queue
        util = (group_utilisation or {}).get(gidx, 0.0)
        out[v.name] = VariantPrice(
            factor=factor,
            extra=queue_weight * wait,
            mult=1.0 + util_weight * util,
            pre_factor=(pre_fn(v, 1 + co[v.name])
                        if pre_fn is not None else 1.0),
        )
    return out


def price_hook(prices: dict[str, VariantPrice],
               variants: Sequence) -> allocation.CostHook:
    """The :data:`~repro.core.allocation.CostHook` carrying one
    stream's coupled prices (skip row 0 untouched)."""
    by_row = [None] + [prices[v.name] for v in variants]

    def hook(i: int, j: int, d_pre: float, d_inf: float):
        del j
        if i == 0:
            return d_pre, d_inf
        return by_row[i].apply_pre(d_pre), by_row[i].apply(d_inf)

    return hook


def best_response(
    problems: Sequence[StreamProblem],
    plans: Sequence,
    variants: Sequence,
    latency_model,
    buckets: ShapeBuckets,
    placement=None,
    group_utilisation: dict | None = None,
    queue_weight: float = DEFAULT_QUEUE_WEIGHT,
    util_weight: float = DEFAULT_UTIL_WEIGHT,
    tick_cap: float | None = None,
    max_switches: int | None = None,
):
    """One Gauss–Seidel sweep: streams re-plan in index order against
    the live pod counts.  Returns ``(new_plans, changed)``.

    A stream switches away from its incumbent only when the coupled
    candidate is STRICTLY more valuable (or the incumbent went
    infeasible under the current prices) AND — with a ``tick_cap`` —
    the switch keeps the pod's :func:`projected_tick` within the
    envelope.  A kept incumbent is re-priced so its ``t_done`` reflects
    the current coupled costs.  ``max_switches`` bounds how many
    streams may switch this sweep (the damping knob).  Deterministic:
    equal inputs produce equal outputs, which is what makes a
    convergent :func:`solve_pod` run a checkable fixed point.
    """
    plans = list(plans)
    union, lat_by = _union_ladder(problems, variants, latency_model)
    counts = _total_counts(plans, union, problems)
    changed = False
    switches = 0
    for s, prob in enumerate(problems):
        old = plans[s]
        if prob.acc is None or prob.acc.shape[1] == 0:
            continue
        svars = prob.variants if prob.variants is not None else variants
        own = _plan_counts(old, svars)
        co = {name: c - own.get(name, 0) for name, c in counts.items()}
        prices = stream_prices(
            svars, co, latency_model, buckets, placement,
            group_utilisation, queue_weight, util_weight,
            all_variants=union, latency_models=lat_by)
        # the materialised hook matrices serve both the knapsack and
        # the incumbent re-pricing below (allocate(d_pre_c, d_inf_c)
        # == allocate(cost_hook=hook) bit-for-bit, without running the
        # hook loop twice)
        d_pre_c, d_inf_c = allocation.apply_cost_hook(
            price_hook(prices, svars), prob.d_pre, prob.d_inf)
        cand = allocation.allocate(prob.acc, d_pre_c, d_inf_c, prob.budget)
        keep = cand is None
        forced = False  # incumbent priced out of its budget
        old_lat = None
        if old is not None:
            old_lat = allocation.plan_latency(old.models, d_pre_c, d_inf_c)
            forced = old_lat > prob.budget + _TOL
            # hysteresis tie-break: switch only on strict improvement
            # (or a budget-infeasible incumbent)
            if not keep and not forced and cand.value <= old.value + _TOL:
                keep = True
        cand_counts = None
        if not keep and (old is None or cand.models != old.models):
            cand_counts = dict(counts)
            for name, c in _plan_counts(cand, svars).items():
                cand_counts[name] += c - own[name]
            if tick_cap is not None and projected_tick(
                    cand_counts, union, latency_model, buckets,
                    placement, latency_models=lat_by) > tick_cap + _TOL:
                # capacity envelope: the upgrade must fit inside the
                # device time the incumbent schedule was already paying
                # for.  A FORCED switch that busts the envelope still
                # keeps the incumbent: its load is already inside the
                # cap, and the over-budget t_done is a per-stream
                # planning estimate, not a pod constraint — the
                # envelope is.
                keep = True
            elif not forced and max_switches is not None and \
                    switches >= max_switches:
                # damping: this sweep's switch budget is spent (never
                # blocks a forced shed)
                keep = True
        if keep:
            # a rejected candidate NEVER falls through to adoption —
            # even for the (currently unreachable) old=None case
            chosen = old if old is None else allocation.Plan(
                old.value,
                float(sum(d_pre_c[i, j] for j, i in enumerate(old.models))),
                old_lat, old.models)
        else:
            chosen = cand
        if ((chosen.models if chosen is not None else None)
                != (old.models if old is not None else None)):
            changed = True
            switches += 1
            counts = cand_counts  # the switch's delta, already applied
        plans[s] = chosen
    return plans, changed


def solve_pod(
    problems: Sequence[StreamProblem],
    variants: Sequence,
    latency_model,
    *,
    buckets: ShapeBuckets | None = None,
    placement=None,
    group_utilisation: dict | None = None,
    max_rounds: int = DEFAULT_MAX_ROUNDS,
    damping: float = DEFAULT_DAMPING,
    queue_weight: float = DEFAULT_QUEUE_WEIGHT,
    util_weight: float = DEFAULT_UTIL_WEIGHT,
    slo_s: float | None = None,
) -> PodSolution:
    """The pod-level fixed point (see the module docstring).

    ``damping`` is the fraction of streams allowed to switch plans per
    sweep (1.0 = all of them); lower values smooth oscillating pods.
    A no-switch sweep is a fixed point at any damping, so convergence
    semantics do not depend on it.

    ``slo_s`` tightens the capacity envelope to the run's SLO target:
    ``T_cap = min(uncoupled projected tick, slo_s)``, so the batching
    discount may upgrade plans only into device time that also fits
    the service objective — not merely into whatever the uncoupled
    schedule happened to cost.  ``None`` (the default) keeps the
    round-0 self-referential envelope bit-identical.  The returned
    ``tick_cap`` is the effective (possibly clamped) envelope;
    ``projected_tick`` always reports the returned plans' projection.
    """
    buckets = buckets or ShapeBuckets()
    union, lat_by = _union_ladder(problems, variants, latency_model)
    plans = [
        allocation.allocate(p.acc, p.d_pre, p.d_inf, p.budget)
        if p.acc is not None and p.acc.shape[1] > 0 else None
        for p in problems]
    counts = _total_counts(plans, union, problems)
    cap_load = projected_group_load(counts, union, latency_model, buckets,
                                    placement, lat_by)
    uncoupled_tick = max(cap_load.values(), default=0.0)
    tick_cap = uncoupled_tick if slo_s is None \
        else min(uncoupled_tick, slo_s)
    if len(problems) <= 1 or len(union) <= 1:
        # one stream has no co-streams to share a batch with; one
        # variant has no cross-variant choice to arbitrate — both keep
        # the calibrated per-stream plans byte-identical.
        return PodSolution(plans, rounds=0, converged=True, counts=counts,
                           coupled=False, tick_cap=tick_cap,
                           projected_tick=uncoupled_tick,
                           projected_load=cap_load)
    max_switches = max(1, math.ceil(damping * len(problems)))
    converged = False
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        plans, changed = best_response(
            problems, plans, variants, latency_model, buckets,
            placement=placement, group_utilisation=group_utilisation,
            queue_weight=queue_weight, util_weight=util_weight,
            tick_cap=tick_cap, max_switches=max_switches)
        if not changed:
            converged = True
            break
    counts = _total_counts(plans, union, problems)
    load = projected_group_load(counts, union, latency_model, buckets,
                                placement, lat_by)
    return PodSolution(
        plans, rounds=rounds, converged=converged, counts=counts,
        coupled=True, tick_cap=tick_cap,
        projected_tick=max(load.values(), default=0.0),
        projected_load=load)


def solve_pod_bruteforce(
    problems: Sequence[StreamProblem],
    variants: Sequence,
    latency_model,
    *,
    buckets: ShapeBuckets | None = None,
    placement=None,
    group_utilisation: dict | None = None,
    tick_cap: float | None = None,
    queue_weight: float = DEFAULT_QUEUE_WEIGHT,
    util_weight: float = DEFAULT_UTIL_WEIGHT,
):
    """Exhaustive joint-allocation oracle for tiny pods (tests only).

    Enumerates every combination of per-stream choice vectors, keeps
    the combinations where EVERY stream's plan is feasible under the
    coupled prices induced by the joint counts (each stream priced
    against the others' demand, exactly like one :func:`best_response`
    step) and — when given — the joint :func:`projected_tick` fits
    ``tick_cap``, and returns ``(plans, total_value)`` of the best one.
    The all-skip assignment is always feasible, so the result is never
    ``None``.  Cost grows as ``(1+V)^(S*R)`` — keep S, V, R tiny.
    """
    import itertools

    buckets = buckets or ShapeBuckets()
    union, lat_by = _union_ladder(problems, variants, latency_model)
    spaces = []
    for p in problems:
        r = p.acc.shape[1] if p.acc is not None else 0
        svars = p.variants if p.variants is not None else variants
        spaces.append(list(itertools.product(
            range(1 + len(svars)), repeat=r)))
    best_plans, best_value = None, -1.0
    for combo in itertools.product(*spaces):
        pseudo = [allocation.Plan(0.0, 0.0, 0.0, models) for models in combo]
        counts = _total_counts(pseudo, union, problems)
        if tick_cap is not None and projected_tick(
                counts, union, latency_model, buckets,
                placement, latency_models=lat_by) > tick_cap + _TOL:
            continue
        plans = []
        total = 0.0
        feasible = True
        for s, (prob, models) in enumerate(zip(problems, combo)):
            if not models:
                plans.append(None)
                continue
            svars = prob.variants if prob.variants is not None else variants
            own = _plan_counts(pseudo[s], svars)
            co = {name: c - own.get(name, 0) for name, c in counts.items()}
            prices = stream_prices(
                svars, co, latency_model, buckets, placement,
                group_utilisation, queue_weight, util_weight,
                all_variants=union, latency_models=lat_by)
            d_pre_c, d_inf_c = allocation.apply_cost_hook(
                price_hook(prices, svars), prob.d_pre, prob.d_inf)
            lat = allocation.plan_latency(models, d_pre_c, d_inf_c)
            if lat > prob.budget + _TOL:
                feasible = False
                break
            value = float(sum(prob.acc[i, j]
                              for j, i in enumerate(models)))
            total += value
            plans.append(allocation.Plan(
                value,
                float(sum(d_pre_c[i, j] for j, i in enumerate(models))),
                lat, models))
        if feasible and total > best_value + _TOL:
            best_plans, best_value = plans, total
    return best_plans, best_value

"""The paper's two resource-agnostic baselines (section V-B).

* ``ERP``     — feed the whole (downsampled) ERP frame to one detector;
  convert rectangular BBs to SphBBs.
* ``CubeMap`` — project the frame onto the 6 cube faces (90x90 FoV
  PIs), run the detector on each face, back-project and merge.

Both run every frame with a FIXED model — no content/network
adaptivity — which is exactly what OmniSense's allocator beats.
E2E latencies follow the same stage-cost + network model as OmniSense
(CubeMap pipelines face preprocessing with inference, like the paper's
implementation does).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import allocation, sroi as sroi_mod
from repro.core.omnisense import InferenceRequest
from repro.core.sphere import pad_detection_rows, sph_nms_batch
from repro.serving.batching import QueuedRequest, ShapeBuckets, VariantQueues
from repro.serving.scheduler import OmniSenseLatencyModel

CUBE_CENTERS = [
    (0.0, 0.0), (math.pi / 2, 0.0), (math.pi, 0.0), (-math.pi / 2, 0.0),
    (0.0, math.pi / 2), (0.0, -math.pi / 2),
]


def run_erp_baseline(video, backend, latency: OmniSenseLatencyModel,
                     variant: acc_mod.ModelProfile, frames: range):
    """Returns (predictions [(frame, det)], mean E2E seconds)."""
    preds = []
    e2e = []
    for f in frames:
        backend.set_frame(f)
        dets = backend.infer_erp(None, variant)
        for d in dets:
            preds.append((f, d))
        t = latency._pre(variant) + latency._inf(variant)
        if variant.location != "device":
            latency.observe_delivery(variant)
        e2e.append(t)
    return preds, float(np.mean(e2e))


def run_cubemap_baseline(video, backend, latency: OmniSenseLatencyModel,
                         variant: acc_mod.ModelProfile, frames: range,
                         nms_threshold: float = 0.6,
                         face_batch: int = 1):
    """Six 90-degree faces through the pod's variant-queue machinery.

    Faces enqueue as :class:`InferenceRequest`s and drain through the
    same bucketed ``infer_srois_batched`` dispatch path as
    ``PodServer`` (resource-agnostic baselines share the serving
    engine, they just never adapt).  ``face_batch=1`` reproduces the
    paper's single-GPU implementation — preprocessing pipelined with
    per-face inference — and keeps the calibrated E2E formula exactly;
    ``face_batch>1`` additionally batches faces per forward (beyond
    paper: serial preprocessing + sub-linear batched inference).

    Frames are independent (no detection feedback), so the overlapping
    face-edge detections of the WHOLE range are merged in one padded
    ``sph_nms_batch`` call — one row per frame — instead of a host NMS
    loop per frame.
    """
    fov = (math.pi / 2, math.pi / 2)
    e2e = []
    d_pre = latency._pre(variant)
    d_inf = latency._inf(variant)
    n_faces = len(CUBE_CENTERS)
    buckets = ShapeBuckets.for_max_batch(face_batch)
    if face_batch == 1:
        per_frame_e2e = allocation.plan_latency(
            tuple([1] * n_faces),
            np.array([[0.0] * n_faces, [d_pre] * n_faces]),
            np.array([[0.0] * n_faces, [d_inf] * n_faces]))
    else:
        per_frame_e2e = n_faces * d_pre + sum(
            latency.batched_inference_delay(variant, b)
            for b in buckets.split(n_faces))
    queues = VariantQueues(buckets)
    per_frame: list[tuple[int, list]] = []
    for f in frames:
        backend.set_frame(f)
        for slot, (ct, cp) in enumerate(CUBE_CENTERS):
            region = sroi_mod.SRoI(center=(ct, cp), fov=fov)
            queues.put(QueuedRequest(
                request=InferenceRequest(region=region, variant=variant,
                                         slot=slot, special=False),
                owner=f, backend=backend, latency_model=latency))
        results, _ = queues.drain()
        by_slot = {item.request.slot: d for item, d in results}
        dets = []
        for slot in range(n_faces):
            dets.extend(by_slot[slot])
        per_frame.append((f, dets))
        if variant.location != "device":
            latency.observe_delivery(variant)
        e2e.append(per_frame_e2e)

    preds = []
    rows = [(f, dets) for f, dets in per_frame if dets]
    if rows:
        boxes, scores, mask = pad_detection_rows([dets for _, dets in rows])
        keep = sph_nms_batch(boxes, scores, mask, iou_threshold=nms_threshold)
        for r, (f, dets) in enumerate(rows):
            preds.extend((f, d) for d, k in zip(dets, keep[r]) if k)
    return preds, float(np.mean(e2e))

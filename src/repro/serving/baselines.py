"""The paper's two resource-agnostic baselines (section V-B).

* ``ERP``     — feed the whole (downsampled) ERP frame to one detector;
  convert rectangular BBs to SphBBs.
* ``CubeMap`` — project the frame onto the 6 cube faces (90x90 FoV
  PIs), run the detector on each face, back-project and merge.

Both run every frame with a FIXED model — no content/network
adaptivity — which is exactly what OmniSense's allocator beats.
E2E latencies follow the same stage-cost + network model as OmniSense
(CubeMap pipelines face preprocessing with inference, like the paper's
implementation does).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import allocation, sroi as sroi_mod
from repro.core.sphere import sph_nms_host
from repro.serving.scheduler import OmniSenseLatencyModel

CUBE_CENTERS = [
    (0.0, 0.0), (math.pi / 2, 0.0), (math.pi, 0.0), (-math.pi / 2, 0.0),
    (0.0, math.pi / 2), (0.0, -math.pi / 2),
]


def run_erp_baseline(video, backend, latency: OmniSenseLatencyModel,
                     variant: acc_mod.ModelProfile, frames: range):
    """Returns (predictions [(frame, det)], mean E2E seconds)."""
    preds = []
    e2e = []
    for f in frames:
        backend.set_frame(f)
        dets = backend.infer_erp(None, variant)
        for d in dets:
            preds.append((f, d))
        t = latency._pre(variant) + latency._inf(variant)
        if variant.location != "device":
            latency.observe_delivery(variant)
        e2e.append(t)
    return preds, float(np.mean(e2e))


def run_cubemap_baseline(video, backend, latency: OmniSenseLatencyModel,
                         variant: acc_mod.ModelProfile, frames: range,
                         nms_threshold: float = 0.6):
    """Six 90-degree faces, preprocessing pipelined with inference."""
    fov = (math.pi / 2, math.pi / 2)
    preds = []
    e2e = []
    d_pre = latency._pre(variant)
    d_inf = latency._inf(variant)
    pipelined = allocation.plan_latency(
        tuple([1] * 6),
        np.array([[0.0] * 6, [d_pre] * 6]),
        np.array([[0.0] * 6, [d_inf] * 6]))
    for f in frames:
        backend.set_frame(f)
        dets = []
        for ct, cp in CUBE_CENTERS:
            region = sroi_mod.SRoI(center=(ct, cp), fov=fov)
            dets.extend(backend.infer_sroi(None, region, variant))
        if dets:
            boxes = np.stack([d.box for d in dets])
            scores = np.array([d.score for d in dets])
            keep = sph_nms_host(boxes, scores, nms_threshold)
            dets = [d for d, k in zip(dets, keep) if k]
        for d in dets:
            preds.append((f, d))
        if variant.location != "device":
            latency.observe_delivery(variant)
        e2e.append(pipelined)
    return preds, float(np.mean(e2e))

"""The paper's two resource-agnostic baselines (section V-B).

* ``ERP``     — feed the whole (downsampled) ERP frame to one detector;
  convert rectangular BBs to SphBBs.
* ``CubeMap`` — project the frame onto the 6 cube faces (90x90 FoV
  PIs), run the detector on each face, back-project and merge.

Both run every frame with a FIXED model — no content/network
adaptivity — which is exactly what OmniSense's allocator beats.
E2E latencies follow the same stage-cost + network model as OmniSense
(CubeMap pipelines face preprocessing with inference, like the paper's
implementation does).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import allocation, sroi as sroi_mod
from repro.core.sphere import pad_detection_rows, sph_nms_batch
from repro.serving.scheduler import OmniSenseLatencyModel

CUBE_CENTERS = [
    (0.0, 0.0), (math.pi / 2, 0.0), (math.pi, 0.0), (-math.pi / 2, 0.0),
    (0.0, math.pi / 2), (0.0, -math.pi / 2),
]


def run_erp_baseline(video, backend, latency: OmniSenseLatencyModel,
                     variant: acc_mod.ModelProfile, frames: range):
    """Returns (predictions [(frame, det)], mean E2E seconds)."""
    preds = []
    e2e = []
    for f in frames:
        backend.set_frame(f)
        dets = backend.infer_erp(None, variant)
        for d in dets:
            preds.append((f, d))
        t = latency._pre(variant) + latency._inf(variant)
        if variant.location != "device":
            latency.observe_delivery(variant)
        e2e.append(t)
    return preds, float(np.mean(e2e))


def run_cubemap_baseline(video, backend, latency: OmniSenseLatencyModel,
                         variant: acc_mod.ModelProfile, frames: range,
                         nms_threshold: float = 0.6):
    """Six 90-degree faces, preprocessing pipelined with inference.

    Frames are independent (no detection feedback), so the overlapping
    face-edge detections of the WHOLE range are merged in one padded
    ``sph_nms_batch`` call — one row per frame — instead of a host NMS
    loop per frame.
    """
    fov = (math.pi / 2, math.pi / 2)
    e2e = []
    d_pre = latency._pre(variant)
    d_inf = latency._inf(variant)
    pipelined = allocation.plan_latency(
        tuple([1] * 6),
        np.array([[0.0] * 6, [d_pre] * 6]),
        np.array([[0.0] * 6, [d_inf] * 6]))
    per_frame: list[tuple[int, list]] = []
    for f in frames:
        backend.set_frame(f)
        dets = []
        for ct, cp in CUBE_CENTERS:
            region = sroi_mod.SRoI(center=(ct, cp), fov=fov)
            dets.extend(backend.infer_sroi(None, region, variant))
        per_frame.append((f, dets))
        if variant.location != "device":
            latency.observe_delivery(variant)
        e2e.append(pipelined)

    preds = []
    rows = [(f, dets) for f, dets in per_frame if dets]
    if rows:
        boxes, scores, mask = pad_detection_rows([dets for _, dets in rows])
        keep = sph_nms_batch(boxes, scores, mask, iou_threshold=nms_threshold)
        for r, (f, dets) in enumerate(rows):
            preds.extend((f, d) for d, k in zip(dets, keep[r]) if k)
    return preds, float(np.mean(e2e))

"""Network model + passive bandwidth profiling (paper sections IV-C, V-B).

The paper shapes the mobile uplink to 17.9 Mbps (average US 5G upload,
T-Mobile / Opensignal Jan-2022) with Linux ``tc`` and estimates delivery
delays with an *online passive* profiler: the edge server keeps the
mean delivery delay of the most recent omega (=7) requests per model
and piggybacks the update on the detection results.

``NetworkModel`` simulates the shaped link (with optional jitter and a
time-varying trace for the sensitivity study); ``PassiveProfiler`` is
the omega-window estimator the allocator consults.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

PAPER_UPLINK_MBPS = 17.9


@dataclasses.dataclass
class NetworkModel:
    bandwidth_mbps: float = PAPER_UPLINK_MBPS
    rtt_s: float = 0.010
    jitter: float = 0.0  # multiplicative stddev on each transfer
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def delivery_delay(self, n_bytes: float) -> float:
        t = self.rtt_s + n_bytes * 8.0 / (self.bandwidth_mbps * 1e6)
        if self.jitter > 0:
            t *= float(np.exp(self._rng.normal(0.0, self.jitter)))
        return t

    def set_bandwidth(self, mbps: float) -> None:
        """tc-style reshaping (used by the Fig. 9b sensitivity sweep)."""
        self.bandwidth_mbps = mbps


class PassiveProfiler:
    """Sliding mean of the last omega delivery delays per model."""

    def __init__(self, omega: int = 7, initial_s: float = 0.3,
                 rtt_s: float = 0.0):
        self.omega = omega
        self.initial_s = initial_s
        # the link's fixed round-trip floor: observed delays include it,
        # but it does not scale with payload size, so rescaling an
        # estimate to a different payload must hold it constant
        self.rtt_s = rtt_s
        self._window: dict[str, collections.deque] = {}

    def observe(self, model_name: str, delay_s: float) -> None:
        w = self._window.setdefault(
            model_name, collections.deque(maxlen=self.omega))
        w.append(delay_s)

    def estimate(self, model_name: str) -> float:
        w = self._window.get(model_name)
        if not w:
            return self.initial_s
        return float(np.mean(w))

    def scale_estimate(self, model_name: str, ref_bytes: float,
                       new_bytes: float) -> float:
        """Estimate for a different payload size.

        Only the bandwidth term of a delivery delay is linear in bytes;
        the ``rtt_s`` round-trip floor is payload-invariant.  Scaling
        the whole mean (the old behaviour) shrank the RTT along with
        the payload and underpriced small transfers — a zero-byte
        estimate went to 0 instead of to the RTT floor.
        """
        base = self.estimate(model_name)
        if ref_bytes <= 0:
            return base
        bw = max(0.0, base - self.rtt_s)
        return self.rtt_s + bw * new_bytes / ref_bytes

"""Structured tick telemetry for the pod serving runtime.

``TickTimeline`` stamps launch/complete/emission per dispatch, but until
this module the data died in ``ServeStats`` aggregates: a policy PR was
reviewable only through coarse bench ratios.  This module exports the
event stream itself — one structured record per arrival, admission
verdict, emission, dispatch launch/complete, carry-over, placement
rebalance, policy decision, tick close and frame finish — through a
``TelemetrySink`` hook on :class:`repro.serving.server.PodServer`:

  * :class:`TelemetrySink` — the default no-op (``enabled = False``, so
    the server skips building payloads entirely; a telemetry-less run
    pays nothing);
  * :class:`MemorySink` — in-memory record list (tests, replay);
  * :class:`JsonlSink` — one JSON object per line on disk, the artifact
    the nightly bench uploads and the replay harness
    (``repro.serving.replay``) re-drives.

Every record is a flat dict with an ``event`` type tag; the required
keys per type live in :data:`EVENT_FIELDS` and are enforced at emit
time (a malformed record fails the producer, not a reader three PRs
later).  Records carry only deterministic quantities — event-clock
seconds, model-priced costs, seeded-oracle detection digests — never
wall-clock measurements, so recording the same seeded corpus twice
yields byte-identical logs and a replay can be checked for
BIT-IDENTICAL drift (the replay-determinism CI lane).

:func:`format_timeline_report` is the offline operator surface: per-
group utilisation, queueing-delay histogram and admission-verdict
breakdown from a log alone — no server, no stats object.
"""

from __future__ import annotations

import collections
import hashlib
import json

import numpy as np

SCHEMA_VERSION = 1

# required keys per event type (the ``event`` tag itself is implicit).
# Extra keys are allowed — readers must tolerate forward growth — but a
# record MISSING a required key is rejected at emit time.
EVENT_FIELDS: dict[str, frozenset] = {
    # one per recorded run: what the pod was (the replay harness stores
    # its rebuildable corpus parameters separately, in ``corpus_spec``)
    "run_meta": frozenset({
        "schema", "mode", "n_streams", "policy", "max_batch", "devices",
        "variants", "tasks", "slo_s"}),
    # repro.serving.replay.CorpusSpec as a dict — everything needed to
    # rebuild the pod and re-drive the run
    "corpus_spec": frozenset({"spec"}),
    # the recorded run's final ServeStats fingerprint (wall-clock
    # fields excluded) — what a same-policy replay must reproduce
    "run_stats": frozenset({"stats"}),
    # open loop: one frame hitting the pod's front door
    "arrival": frozenset({"t_s", "stream", "frame_idx"}),
    # open loop: the admission verdict for one arrival
    # (admit / degrade / reject / missed).  ``task`` is the stream's
    # analytics task — mixed-task replays diff per task.
    "admission": frozenset({
        "t_s", "stream", "task", "frame_idx", "verdict", "backlog_s",
        "plan_cost_s", "degraded_cost_s", "slo_s"}),
    # one frame's requests entering the variant queues
    "emit": frozenset({
        "t_s", "stream", "task", "frame_idx", "n_requests", "plan_value",
        "variants"}),
    # the drain plan the schedule policy returned for one tick
    "policy_decision": frozenset({"tick", "t_s", "policy", "ops"}),
    # one batched forward booked on the event clock (launch half);
    # ``queue_delays`` is the per-request launch-minus-emission list
    "dispatch_launch": frozenset({
        "tick", "dispatch", "variant", "task", "b", "padded", "group",
        "n_devices", "cost_s", "launch_s", "emitted_s", "carried",
        "queue_delays"}),
    # its completion half (same ``dispatch`` id joins the two)
    "dispatch_complete": frozenset({
        "tick", "dispatch", "variant", "group", "complete_s", "cost_s"}),
    # requests left queued after a drain (async carry-over)
    "carry": frozenset({"tick", "t_s", "queued", "total"}),
    # an atomic replica-group rebalance (device counts after the swap)
    "rebalance": frozenset({"t_s", "groups"}),
    # the policy's close rule for one finished tick
    "tick_close": frozenset({
        "tick", "t_s", "charge_s", "next_start_s", "dispatches"}),
    # one frame finishing (post-NMS): the detection digest is what the
    # replay-determinism gate compares for drift
    "frame_finish": frozenset({
        "t_s", "stream", "task", "frame_idx", "event_e2e_s",
        "n_detections", "det_digest", "slo_violation"}),
    # fleet tier (repro.serving.fleet): one routing decision binding a
    # stream to a pod ("new" stream, "migrate" off a retired pod, or a
    # ring move after elastic scaling)
    "route": frozenset({"t_s", "stream", "pod", "reason"}),
    # fleet tier: one elastic-controller action ("grow"/"shrink") with
    # the sustained SLO pressure that triggered it
    "scale": frozenset({"t_s", "action", "pod", "n_pods", "pressure"}),
}


def validate_event(record: dict) -> dict:
    """Check one record against :data:`EVENT_FIELDS`; returns it."""
    kind = record.get("event")
    required = EVENT_FIELDS.get(kind)
    if required is None:
        raise ValueError(
            f"unknown telemetry event type {kind!r}; known types: "
            f"{sorted(EVENT_FIELDS)}")
    missing = required - record.keys()
    if missing:
        raise ValueError(
            f"telemetry event {kind!r} missing required keys "
            f"{sorted(missing)}")
    return record


def detections_digest(detections) -> str:
    """Deterministic digest of a frame's post-NMS detections.

    Hashes the exact float64 bytes of every box plus category and
    score, so the replay gate compares detections bit-for-bit without
    storing them (a 40-char line instead of kilobytes per frame)."""
    h = hashlib.sha1()
    for det in detections:
        h.update(np.asarray(det.box, dtype=np.float64).tobytes())
        h.update(int(det.category).to_bytes(8, "little", signed=True))
        h.update(np.float64(det.score).tobytes())
    return h.hexdigest()


class TelemetrySink:
    """The no-op default.  ``enabled`` gates payload construction: the
    server checks it before building per-event dicts (digests, delay
    lists), so an un-instrumented run does no telemetry work at all."""

    enabled = False

    def emit(self, event: str, **fields) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class MemorySink(TelemetrySink):
    """Collect validated records in ``self.events`` (replay, tests)."""

    enabled = True

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: str, **fields) -> None:
        self.events.append(validate_event({"event": event, **fields}))


class JsonlSink(TelemetrySink):
    """One JSON object per line at ``path`` — the durable event log.

    Floats serialise via ``repr`` (Python's default), which round-trips
    float64 exactly, so a log read back compares bit-identically to
    the in-memory record stream that produced it."""

    enabled = True

    def __init__(self, path):
        self.path = path
        self._f = open(path, "w")

    def emit(self, event: str, **fields) -> None:
        record = validate_event({"event": event, **fields})
        self._f.write(json.dumps(record, sort_keys=True) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_events(path) -> list[dict]:
    """Load a JSONL event log back into validated records."""
    out = []
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(validate_event(json.loads(line)))
            except (json.JSONDecodeError, ValueError) as exc:
                raise ValueError(
                    f"{path}:{line_no}: bad telemetry record: {exc}"
                ) from None
    return out


# ---------------------------------------------------------------------------
# offline report
# ---------------------------------------------------------------------------

# queueing-delay histogram edges (seconds); the last bucket is open
_DELAY_EDGES = (0.001, 0.01, 0.1, 1.0)


def _delay_histogram(delays) -> list[str]:
    labels = ["<1ms", "1-10ms", "10-100ms", "0.1-1s", ">=1s"]
    counts = [0] * len(labels)
    for d in delays:
        for i, edge in enumerate(_DELAY_EDGES):
            if d < edge:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
    total = max(len(delays), 1)
    return [f"    {lab:>8}: {c:>6} ({c / total:.0%})"
            for lab, c in zip(labels, counts) if c]


def format_timeline_report(events) -> list[str]:
    """Human-readable summary lines computed from a log ALONE.

    Accepts the record list of :func:`read_events` / ``MemorySink``.
    Reports per-group utilisation (dispatch busy seconds over the
    ticks' charged seconds), the queueing-delay histogram over every
    dispatched request, and — when the log holds an open-loop run —
    the admission-verdict breakdown.  No server or stats object
    needed: this is the offline operator surface over the artifact the
    nightly CI uploads.
    """
    by_type: dict[str, list] = collections.defaultdict(list)
    for e in events:
        by_type[e["event"]].append(e)

    lines = []
    meta = by_type.get("run_meta")
    head = (f"[{meta[0]['policy'].get('name', '?')} policy, "
            f"{meta[0]['mode']}-loop, {meta[0]['n_streams']} streams] "
            if meta else "")
    lines.append(
        f"timeline {head}{len(events)} events: "
        f"{len(by_type.get('tick_close', []))} ticks, "
        f"{len(by_type.get('dispatch_launch', []))} dispatches, "
        f"{len(by_type.get('frame_finish', []))} frames finished")

    busy: dict[str, float] = {}
    delays: list[float] = []
    for d in by_type.get("dispatch_launch", ()):
        g = str(d["group"])
        busy[g] = busy.get(g, 0.0) + d["cost_s"]
        delays.extend(d["queue_delays"])
    tick_s = sum(t["charge_s"] for t in by_type.get("tick_close", ()))
    if busy:
        util = ", ".join(f"g{g}={b / tick_s:.0%}" if tick_s > 0 else f"g{g}=0%"
                         for g, b in sorted(busy.items()))
        lines.append(f"group utilisation over {tick_s:.2f} charged tick "
                     f"seconds: {util}")
    if delays:
        lines.append(f"queueing delay over {len(delays)} dispatched "
                     f"requests (mean {np.mean(delays) * 1e3:.1f}ms):")
        lines.extend(_delay_histogram(delays))

    verdicts = collections.Counter(
        a["verdict"] for a in by_type.get("admission", ()))
    if verdicts:
        breakdown = ", ".join(f"{v}={c}" for v, c in sorted(verdicts.items()))
        lines.append(
            f"admission verdicts over {sum(verdicts.values())} arrivals: "
            f"{breakdown}")

    finishes = by_type.get("frame_finish", ())
    if finishes:
        e2e = [f["event_e2e_s"] for f in finishes]
        viol = sum(1 for f in finishes if f["slo_violation"])
        lines.append(
            f"frame E2E: mean {np.mean(e2e):.3f}s  "
            f"p95 {np.percentile(e2e, 95):.3f}s  "
            f"p99 {np.percentile(e2e, 99):.3f}s  "
            f"({viol} SLO violations)")
    carries = by_type.get("carry", ())
    if carries:
        lines.append(
            f"carry-over: {len(carries)} ticks left work queued "
            f"(max {max(c['total'] for c in carries)} requests)")
    if by_type.get("rebalance"):
        lines.append(f"placement rebalances: {len(by_type['rebalance'])}")
    if by_type.get("route"):
        reasons = collections.Counter(
            r["reason"] for r in by_type["route"])
        lines.append(
            f"fleet routing over {len(by_type['route'])} decisions: "
            + ", ".join(f"{k}={c}" for k, c in sorted(reasons.items())))
    if by_type.get("scale"):
        acts = collections.Counter(s["action"] for s in by_type["scale"])
        lines.append(
            "fleet scaling: "
            + ", ".join(f"{k}={c}" for k, c in sorted(acts.items())))
    return lines

"""Pod-scale serving loop: many camera streams multiplexed on one mesh.

The paper's testbed serves ONE stream on one edge GPU.  At pod scale the
same per-frame pipeline (SRoI predict -> allocate -> project -> infer ->
NMS) runs for hundreds of streams, and the interesting systems problem
becomes *variant batching*: PI requests from many streams that chose the
same model variant are batched into one accelerator dispatch.

``PodServer`` runs that loop against a virtual clock:

  * each stream runs its own ``OmniSenseLoop`` state (history,
    discovery, allocator) against the shared latency model; per tick
    every loop EMITS its planned inference requests
    (``begin_frame``) instead of executing them inline;
  * the requests park in real per-variant queues
    (``repro.serving.batching.VariantQueues``) and drain into chunks of
    at most ``max_batch``, each chunk zero-padded up to a batch-size
    bucket and executed as ONE batched detector forward
    (``infer_srois_batched``) — S streams choosing V distinct variants
    issue exactly V batched forwards when V queues fit their buckets;
  * the decoded detections scatter back to their owning loops
    (``finish_frame``), which run discovery and defer suppression;
  * spherical NMS is NOT run per stream: every stream finishing in
    the tick defers suppression, the raw detections are padded into one
    ``(B, N, 4)`` stack, and a single ``sph_nms_batch`` dispatch
    suppresses all rows at once — the inference dispatch and the NMS
    dispatch share one tick schedule;
  * the tick's inference time is charged per DISPATCH via
    ``OmniSenseLatencyModel.batched_inference_delay`` (per-batch fixed
    cost + per-item marginal), not as a per-request ``_inf`` sum;
    utilisation, queue depths and per-stream E2E are reported;
  * with a ``VariantPlacement`` (``repro.serving.placement``), each
    variant's forward routes to its own replica group — sharded over
    the group's ``data`` axis and launched before any result is
    resolved, so V variants execute concurrently on disjoint device
    groups — and the tick model switches from the dispatch SUM to the
    device-aware MAX over per-group sums
    (``OmniSenseLatencyModel.tick_inference_delay``).

This is the runnable stand-in for the 256-chip serving mesh (the
dry-run proves the detector steps compile on that mesh; this loop
proves the control plane sustains multi-stream operation).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import (nms_auto_backend, pad_detection_rows,
                               sph_nms_batch)
from repro.serving.batching import QueuedRequest, ShapeBuckets, VariantQueues


@dataclasses.dataclass
class ServeStats:
    frames: int = 0
    ticks: int = 0
    total_detections: int = 0
    sum_e2e: float = 0.0
    sum_overhead: float = 0.0
    batch_sizes: list = dataclasses.field(default_factory=list)
    # batched-dispatch accounting (one entry of work per tick)
    dispatches: int = 0
    sum_batched_inf_s: float = 0.0      # aggregate device-busy seconds
    sum_per_request_inf_s: float = 0.0  # what B per-request forwards would
    # device-aware tick accounting: replica groups run concurrently, so
    # the tick pays max-over-groups, not the dispatch sum
    sum_tick_inf_s: float = 0.0
    group_busy_s: dict = dataclasses.field(default_factory=dict)
    # device count per group index as last seen at dispatch time, so
    # utilisation reports label busy seconds with the partition that
    # actually accrued them (rebalances can change a group's width)
    group_devices: dict = dataclasses.field(default_factory=dict)
    # summed allocator plan values (the paper's objective — the pod
    # bench's accuracy proxy, comparable coupled vs uncoupled because
    # values come from the acc matrices, never from prices)
    sum_plan_value: float = 0.0
    # pod-level allocation accounting (zero when pod_allocate is off)
    pod_rounds: int = 0
    pod_ticks: int = 0
    pod_converged_ticks: int = 0

    @property
    def mean_e2e(self) -> float:
        return self.sum_e2e / max(self.frames, 1)

    @property
    def accuracy_proxy(self) -> float:
        """Mean allocator plan value per stream-frame."""
        return self.sum_plan_value / max(self.frames, 1)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    @property
    def batching_gain(self) -> float:
        """Per-request inference cost over batched cost (>= 1 when
        batching pays; 1.0 when every dispatch had batch 1)."""
        if self.sum_batched_inf_s <= 0:
            return 1.0
        return self.sum_per_request_inf_s / self.sum_batched_inf_s

    @property
    def sharding_gain(self) -> float:
        """Serialised dispatch cost over the device-aware tick cost
        (>= 1; 1.0 on a single-device pod where every tick serialises)."""
        if self.sum_tick_inf_s <= 0:
            return 1.0
        return self.sum_batched_inf_s / self.sum_tick_inf_s

    def group_utilisation(self) -> dict:
        """Per replica group: busy seconds over the pod's tick seconds
        (the idle share is the cost of imbalanced variant load)."""
        if self.sum_tick_inf_s <= 0:
            return {g: 0.0 for g in self.group_busy_s}
        return {g: busy / self.sum_tick_inf_s
                for g, busy in sorted(self.group_busy_s.items())}


def format_group_report(stats: ServeStats, placement) -> list[str]:
    """Human-readable replica-group summary lines (shared by the
    serving drivers so the format can't drift between them).  Device
    counts come from dispatch time, not the final partition, so busy
    seconds accrued before a rebalance keep their real group width."""
    util = ", ".join(
        f"g{g}[{stats.group_devices.get(g, '?')}dev]={u:.0%}"
        for g, u in stats.group_utilisation().items())
    return [
        f"replica groups over {placement.n_devices} devices: "
        f"device-aware tick inference {stats.sum_tick_inf_s:.1f}s "
        f"(sharding gain {stats.sharding_gain:.2f}x, "
        f"{placement.rebalances} rebalances)",
        f"group utilisation: {util}",
    ]


def format_pod_allocation_report(stats: ServeStats) -> str:
    """Human-readable pod-level allocation summary (shared by the
    serving drivers, like :func:`format_group_report`, so the format —
    and the accuracy-proxy units — cannot drift between them)."""
    return (f"pod-level allocation: "
            f"{stats.pod_rounds / max(stats.pod_ticks, 1):.1f} "
            f"fixed-point rounds/tick "
            f"({stats.pod_converged_ticks}/{stats.pod_ticks} ticks "
            f"converged), accuracy proxy "
            f"{stats.accuracy_proxy:.3f}/stream-frame")


class PodServer:
    """Variant-batched tick scheduler over per-stream OmniSense loops.

    ``frame_source(stream_idx, frame_idx)`` optionally supplies real
    frame pixels per stream (the Jax detector path); oracle backends
    sample ground truth and take ``None``.
    """

    def __init__(self, loops: list[OmniSenseLoop], backends: list,
                 max_batch: int = 8, marginal_batch_cost: float | None = None,
                 buckets: ShapeBuckets | None = None,
                 frame_source: Callable[[int, int], np.ndarray] | None = None,
                 placement=None, pod_allocate: bool = False):
        assert len(loops) == len(backends)
        self.loops = loops
        self.backends = backends
        self.max_batch = max_batch
        # opt-in pod-level allocation: each tick, every stream's
        # knapsack is coupled through batched costs + group utilisation
        # by the fixed-point solver (repro.serving.pod_allocation)
        # instead of planning as if it had the edge to itself.  Off by
        # default: the uncoupled path stays byte-identical.
        self.pod_allocate = pod_allocate
        if pod_allocate:
            ladder = tuple(v.name for v in loops[0].variants)
            for loop in loops:
                if tuple(v.name for v in loop.variants) != ladder:
                    raise ValueError(
                        "pod_allocate=True needs every stream on the same "
                        f"variant ladder; got {ladder} vs "
                        f"{tuple(v.name for v in loop.variants)}")
        # repro.serving.placement.VariantPlacement: routes each drained
        # chunk to its variant's replica group and switches the tick
        # model to max-over-groups; None = single-device pod (every
        # dispatch serialises in one implicit group).
        self.placement = placement
        if placement is not None:
            placed = set(placement.variant_names)
            missing = {v.name for loop in loops for v in loop.variants
                       if v.name not in placed}
            if missing:
                raise ValueError(
                    f"placement has no replica group for variants {sorted(missing)}")
        # None = defer to each latency model's batched_inference_delay
        # (the default OmniSenseLatencyModel curve); a float OVERRIDES
        # the curve for every dispatch the server prices.
        self.marginal = marginal_batch_cost
        self.buckets = buckets or ShapeBuckets.for_max_batch(max_batch)
        if self.buckets.max_batch != max_batch:
            raise ValueError(
                f"buckets top out at {self.buckets.max_batch}, "
                f"max_batch is {max_batch}")
        # a drained chunk must be ONE backend dispatch: a backend whose
        # own bucket ladder tops out below the server's would silently
        # split chunks and the priced schedule would diverge from the
        # executed one.
        for b in backends:
            b_buckets = getattr(b, "buckets", None)
            if b_buckets is not None and b_buckets.max_batch < max_batch:
                raise ValueError(
                    f"backend buckets top out at {b_buckets.max_batch} < "
                    f"max_batch {max_batch}; align the backend's "
                    "ShapeBuckets with the server's")
        self.frame_source = frame_source
        self.queues = VariantQueues(self.buckets)
        self.stats = ServeStats()

    def _dispatch_cost(self, dispatch: dict) -> tuple[float, float]:
        """(batched, per-request-sum) inference seconds of one dispatch.

        A chunk of per-stream *simulation* backends (oracle:
        ``semantic_batch``) models one shared-accelerator forward and
        is priced at the chunk's batch size; with real backends every
        executed backend group is its own forward, so pricing follows
        ``group_sizes`` and cannot overstate batching that never ran.
        A dispatch routed to a multi-device replica group shards its
        batch over the group, so the priced forward is the largest
        per-device shard (``sharded_inference_delay``); the
        per-request comparator stays the single-device sum.
        """
        variant = dispatch["items"][0].request.variant
        lat = dispatch["items"][0].latency_model
        group = dispatch.get("group")
        n_dev = group.n_devices if group is not None else 1
        blat = getattr(lat, "batched_inference_delay", None)
        single = blat(variant, 1) if blat is not None else variant.infer_s

        def curve(n: int) -> float:
            n_eff = -(-n // n_dev)  # largest per-device shard
            if self.marginal is not None:  # explicit override
                return single * (1.0 + (n_eff - 1) * self.marginal)
            shard = getattr(lat, "sharded_inference_delay", None)
            if shard is not None:
                return shard(variant, n, n_dev)
            if blat is not None:
                return blat(variant, n_eff)
            return single * (1.0 + (n_eff - 1) * 0.15)

        b = dispatch["b"]
        if dispatch["semantic"]:
            batched = curve(b)
        else:
            batched = sum(curve(g) for g in dispatch["group_sizes"])
        return batched, single * b

    def _pod_plan(self, frames: list) -> list:
        """Coupled emission: collect every stream's planning context,
        solve the pod-level fixed point, emit per the joint plans.

        Coupled prices derive from the FIRST loop's latency model (one
        edge serves the pod, so one batched curve); per-stream base
        matrices still carry each stream's own delivery estimates, and
        the zero-co-stream coupling is the exact identity, so streams
        with private models only ever see pod-relative adjustments."""
        from repro.serving import pod_allocation

        ctxs, ctx_durations = [], []
        for loop, frame in zip(self.loops, frames):
            ctx = loop.frame_context(frame)
            ctx_durations.append(time.perf_counter() - ctx.t0)
            ctxs.append(ctx)
        problems = [pod_allocation.StreamProblem(
            ctx.acc, ctx.d_pre, ctx.d_inf, ctx.budget) for ctx in ctxs]
        util = (self.stats.group_utilisation()
                if self.placement is not None and self.stats.sum_tick_inf_s > 0
                else None)
        t_solve = time.perf_counter()
        sol = pod_allocation.solve_pod(
            problems, self.loops[0].variants, self.loops[0].latency_model,
            buckets=self.buckets, placement=self.placement,
            group_utilisation=util)
        solve_share = (time.perf_counter() - t_solve) / len(self.loops)
        self.stats.pod_ticks += 1
        self.stats.pod_rounds += sol.rounds
        self.stats.pod_converged_ticks += int(sol.converged)
        # re-stamp each context immediately before ITS emission so
        # emit_pending bills the stream its own planning time plus a
        # fair share of the shared solve — never the sequential wall
        # time of the other streams' planning or emission
        out = []
        for loop, ctx, dur, plan in zip(self.loops, ctxs, ctx_durations,
                                        sol.plans):
            ctx.t0 = time.perf_counter() - dur - solve_share
            out.append(loop.emit_pending(ctx, plan))
        return out

    def step(self, frame_idx: int) -> None:
        """Process one frame for every stream (one scheduler tick)."""
        # ---- emission: every loop plans and parks its requests (the
        # pod-allocate path plans all streams jointly first) ----
        frames = []
        for s, backend in enumerate(self.backends):
            if hasattr(backend, "set_frame"):
                backend.set_frame(frame_idx)
            frames.append(self.frame_source(s, frame_idx)
                          if self.frame_source is not None else None)
        if self.pod_allocate:
            emitted = self._pod_plan(frames)
        else:
            emitted = [loop.begin_frame(frame)
                       for loop, frame in zip(self.loops, frames)]
        pendings = []
        for loop, backend, pending in zip(self.loops, self.backends, emitted):
            pendings.append((loop, pending))
            if pending.plan is not None:
                self.stats.sum_plan_value += pending.plan.value
            for req in pending.requests:
                self.queues.put(QueuedRequest(
                    request=req, owner=pending, backend=backend,
                    latency_model=loop.latency_model))

        # ---- placement feedback: fold this tick's variant mix into the
        # popularity EMA and re-balance replica groups if the allocator
        # shifted load (atomic swap: queued requests keep a group) ----
        if self.placement is not None:
            counts: dict[str, int] = {}
            for _, pending in pendings:
                for req in pending.requests:
                    counts[req.variant.name] = counts.get(req.variant.name, 0) + 1
            self.placement.observe(counts)
            self.placement.maybe_rebalance()

        # ---- drain: bucketed batched forwards, one per variant chunk,
        # each routed to (and sharded over) its variant's replica group ----
        results, dispatches = self.queues.drain(self.placement)
        scatter: dict[int, dict[int, list]] = {}
        for item, dets in results:
            scatter.setdefault(id(item.owner), {})[item.request.slot] = dets
        tick_lat = None
        group_costs: dict[int, float] = {}
        for d in dispatches:
            self.stats.dispatches += 1
            self.stats.batch_sizes.append(d["b"])
            batched, per_request = self._dispatch_cost(d)
            self.stats.sum_batched_inf_s += batched
            self.stats.sum_per_request_inf_s += per_request
            group = d.get("group")
            gidx = group.index if group is not None else 0
            group_costs[gidx] = group_costs.get(gidx, 0.0) + batched
            self.stats.group_busy_s[gidx] = (
                self.stats.group_busy_s.get(gidx, 0.0) + batched)
            self.stats.group_devices[gidx] = (
                group.n_devices if group is not None else 1)
            tick_lat = tick_lat or getattr(
                d["items"][0].latency_model, "tick_inference_delay", None)
        # device-aware tick cost: groups run concurrently on disjoint
        # devices, so the tick pays the max over per-group sums (the
        # single-group pod degenerates to the old dispatch sum)
        self.stats.ticks += 1
        self.stats.sum_tick_inf_s += (
            tick_lat(group_costs.values()) if tick_lat is not None
            else max(group_costs.values(), default=0.0))

        # ---- ingestion: scatter detections back, defer suppression ----
        plans = []
        for loop, pending in pendings:
            slots = scatter.get(id(pending), {})
            request_detections = [slots.get(i, [])
                                  for i in range(len(pending.requests))]
            result = loop.finish_frame(pending, request_detections,
                                       defer_nms=True)
            plans.append((loop, result))

        # one batched spherical-NMS dispatch for every stream that
        # produced detections this tick (instead of B Python loops)
        self.stats.sum_overhead += self._suppress_tick(plans)

        for _, result in plans:
            self.stats.frames += 1
            self.stats.total_detections += len(result.detections)
            self.stats.sum_e2e += result.planned_latency
            self.stats.sum_overhead += result.overhead_s

    def _suppress_tick(self, plans: list) -> float:
        """Batched spherical NMS across the tick; returns wall time.

        Streams with detections are padded to a common N and suppressed
        in one ``sph_nms_batch`` call; every loop (including empty ones)
        then gets its keep-mask back via ``finalize_detections`` so the
        per-stream detection feedback matches the inline path exactly.
        Falls back to per-stream single-row calls only if the loops
        disagree on the NMS threshold.
        """
        t0 = time.perf_counter()
        rows = [(loop, res) for loop, res in plans if res.detections]
        thresholds = {loop.nms_threshold for loop, _ in rows}
        keeps: dict[int, np.ndarray] = {}
        if rows and len(thresholds) == 1:
            # bucketed padding bounds the device path's compile shapes:
            # B pins to the stream count, N snaps to the NMS ladder, so
            # a serving lifetime compiles at most len(nms_sizes)
            # programs (pinned by the trace-counter regression test).
            # The host path never compiles, so there padding is skipped
            # instead of wasting O(B*N^2) on masked rows.
            row_dets = [res.detections for _, res in rows]
            n_pad = self.buckets.pad_nms_rows(max(len(d) for d in row_dets))
            if nms_auto_backend(len(plans), n_pad) == "device":
                boxes, scores, mask = pad_detection_rows(
                    row_dets, pad_n=self.buckets.pad_nms_rows,
                    total_rows=len(plans))
            else:
                boxes, scores, mask = pad_detection_rows(row_dets)
            keep = sph_nms_batch(boxes, scores, mask,
                                 iou_threshold=thresholds.pop())
            for r, (_, res) in enumerate(rows):
                keeps[id(res)] = keep[r, : len(res.detections)]
        elif rows:  # heterogeneous thresholds: per-stream single rows
            for loop, res in rows:
                keeps[id(res)] = loop.nms_keep(res.detections)
        for loop, res in plans:
            loop.finalize_detections(res, keeps.get(id(res)))
        return time.perf_counter() - t0

    def run(self, frames: range) -> ServeStats:
        for f in frames:
            self.step(f)
        return self.stats

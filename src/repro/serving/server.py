"""Pod-scale serving loop: many camera streams multiplexed on one mesh.

The paper's testbed serves ONE stream on one edge GPU.  At pod scale the
same per-frame pipeline (SRoI predict -> allocate -> project -> infer ->
NMS) runs for hundreds of streams, and the interesting systems problem
becomes *variant batching*: PI requests from many streams that chose the
same model variant are batched into one accelerator dispatch.

``PodServer`` drives that loop over the event-clock serving runtime
(``repro.serving.runtime``):

  * each stream runs its own ``OmniSenseLoop`` state (history,
    discovery, allocator) against the shared latency model; per tick
    every loop EMITS its planned inference requests
    (``begin_frame``) instead of executing them inline;
  * the requests park in real per-variant queues
    (``repro.serving.batching.VariantQueues``); a pluggable
    ``SchedulePolicy`` owns admission (per-stream knapsacks vs the
    pod-level fixed point), drain ordering and carry-over, and the
    queues drain into chunks of at most ``max_batch``, each chunk
    zero-padded up to a batch-size bucket and executed as ONE batched
    detector forward (``infer_srois_batched``);
  * every dispatch is booked on the ``GroupClock``: it launches when
    its replica group frees (groups serialise internally, run
    concurrently across each other) and the per-tick ``TickTimeline``
    records launch/complete stamps — the sync policy's tick charge is
    bit-identical to the old barrier model
    (``OmniSenseLatencyModel.tick_inference_delay``), and async
    carry-over is priced by the overlap generalisation;
  * the decoded detections scatter back to their owning frames; a
    frame finishes (``finish_frame``) in the tick its LAST request
    resolves — immediately under the sync barrier, possibly a tick
    later under ``AsyncDrainPolicy``, whose residual sub-bucket
    chunks merge into the next tick's fuller batches;
  * spherical NMS is NOT run per stream: every frame finishing in the
    tick defers suppression, the raw detections are padded into one
    ``(B, N, 4)`` stack, and a single ``sph_nms_batch`` dispatch
    suppresses all rows at once;
  * with a ``VariantPlacement`` (``repro.serving.placement``), each
    variant's forward routes to its own replica group — sharded over
    the group's ``data`` axis and launched before any result is
    resolved, so V variants execute concurrently on disjoint device
    groups.

This is the runnable stand-in for the 256-chip serving mesh (the
dry-run proves the detector steps compile on that mesh; this loop
proves the control plane sustains multi-stream operation).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core import allocation
from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import (IncrementalNms, nms_auto_backend,
                               pad_detection_rows, sph_nms_batch)
from repro.serving.batching import QueuedRequest, ShapeBuckets, VariantQueues
from repro.serving.runtime import (DEGRADE, REJECT, DispatchEvent, GroupClock,
                                   SyncTickPolicy, TickTimeline, make_policy)
from repro.serving.telemetry import (SCHEMA_VERSION, TelemetrySink,
                                     detections_digest)


@dataclasses.dataclass
class ServeStats:
    frames: int = 0
    ticks: int = 0
    total_detections: int = 0
    sum_e2e: float = 0.0
    sum_overhead: float = 0.0
    batch_sizes: list = dataclasses.field(default_factory=list)
    # batched-dispatch accounting (one entry of work per tick)
    dispatches: int = 0
    sum_batched_inf_s: float = 0.0      # aggregate device-busy seconds
    sum_per_request_inf_s: float = 0.0  # what B per-request forwards would
    # device-aware tick accounting: replica groups run concurrently, so
    # the tick pays max-over-groups (sync barrier) or the event-clock
    # elapsed time (async overlap) — the policy's close_tick rule
    sum_tick_inf_s: float = 0.0
    group_busy_s: dict = dataclasses.field(default_factory=dict)
    # device count per group index as last seen at dispatch time, so
    # utilisation reports label busy seconds with the partition that
    # actually accrued them (rebalances can change a group's width)
    group_devices: dict = dataclasses.field(default_factory=dict)
    # summed allocator plan values (the paper's objective — the pod
    # bench's accuracy proxy, comparable coupled vs uncoupled because
    # values come from the acc matrices, never from prices)
    sum_plan_value: float = 0.0
    # pod-level allocation accounting (zero when the policy does not
    # pod-allocate)
    pod_rounds: int = 0
    pod_ticks: int = 0
    pod_converged_ticks: int = 0
    # event-clock accounting (repro.serving.runtime)
    policy: str = "sync"
    # per finished frame: completion of its last dispatch minus its
    # emission time on the event clock (the policy-sensitive E2E the
    # bench's policy_grid reports as p50/p95/p99)
    event_e2e: list = dataclasses.field(default_factory=list)
    # UNIQUE requests that waited in a queue past the tick that emitted
    # them (async carry-over reach; 0 under sync/deadline).  A request
    # counts once no matter how many ticks it waits — the old counter
    # snapshotted the whole queue every tick, so one request carried k
    # ticks counted k times.
    carried_requests: int = 0
    # request-ticks spent waiting (the old per-tick queue-snapshot sum:
    # carry-over VOLUME, still useful as a backlog-pressure integral)
    carry_tick_slots: int = 0
    # open-loop traffic accounting (all zero under closed-loop run():
    # ticks admit everything and no SLO is configured)
    slo_s: float | None = None
    admission: str = "admit-all"
    arrivals: int = 0       # frames the traffic offered
    admitted: int = 0       # emitted with a plan (degraded included)
    degraded: int = 0       # admitted but forced to skip/P1
    rejected: int = 0       # shed by the admission policy
    missed: int = 0         # superseded in the depth-1 camera buffer
    empty_frames: int = 0   # admitted with no requests (nothing planned)
    slo_violations: int = 0  # finished frames with event E2E > slo_s
    # per dispatched request: launch minus emission on the event clock
    # (pure queueing delay, before the forward itself runs)
    queue_delays: list = dataclasses.field(default_factory=list)
    # per-task accounting (keys = AnalyticsTask names; a bare detection
    # pod records everything under "detection").  The open-loop
    # conservation invariant holds PER TASK:
    #   arrivals_by_task[t] == admitted + rejected + missed (each [t])
    arrivals_by_task: dict = dataclasses.field(default_factory=dict)
    admitted_by_task: dict = dataclasses.field(default_factory=dict)
    degraded_by_task: dict = dataclasses.field(default_factory=dict)
    rejected_by_task: dict = dataclasses.field(default_factory=dict)
    missed_by_task: dict = dataclasses.field(default_factory=dict)
    frames_by_task: dict = dataclasses.field(default_factory=dict)
    plan_value_by_task: dict = dataclasses.field(default_factory=dict)

    @property
    def mean_e2e(self) -> float:
        return self.sum_e2e / max(self.frames, 1)

    @property
    def goodput_frames(self) -> int:
        """Frames that finished within the SLO (all finished frames
        when no SLO is configured)."""
        return self.frames - self.slo_violations

    @property
    def useful_goodput_frames(self) -> int:
        """Within-SLO frames that did real inference work.

        An admitted frame with an empty plan completes instantly
        (event E2E 0) and so always lands inside the SLO — but it
        delivered no detections.  Under congestion collapse a starved
        predictor plans nothing for most frames, so raw
        :attr:`goodput_frames` REWARDS the collapse; this is the
        honest metric the bench's open-loop gate compares."""
        return self.goodput_frames - self.empty_frames

    @property
    def mean_queue_delay(self) -> float:
        return float(np.mean(self.queue_delays)) if self.queue_delays else 0.0

    @property
    def accuracy_proxy(self) -> float:
        """Mean allocator plan value per stream-frame."""
        return self.sum_plan_value / max(self.frames, 1)

    @property
    def accuracy_proxy_by_task(self) -> dict:
        """Per-task mean plan value per finished stream-frame — the
        mixed-pod bench's no-collapse signal (each task's proxy is in
        ITS OWN ladder's units; compare same-task across pod mixes,
        never across tasks)."""
        return {t: self.plan_value_by_task.get(t, 0.0) / max(n, 1)
                for t, n in sorted(self.frames_by_task.items())}

    @property
    def mean_tick(self) -> float:
        """Mean per-tick inference seconds (flush charges included in
        the numerator but not the tick count, so async pods pay their
        carried tail instead of hiding it)."""
        return self.sum_tick_inf_s / max(self.ticks, 1)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    @property
    def batching_gain(self) -> float:
        """Per-request inference cost over batched cost (>= 1 when
        batching pays; 1.0 when every dispatch had batch 1)."""
        if self.sum_batched_inf_s <= 0:
            return 1.0
        return self.sum_per_request_inf_s / self.sum_batched_inf_s

    @property
    def sharding_gain(self) -> float:
        """Serialised dispatch cost over the device-aware tick cost
        (>= 1; 1.0 on a single-device pod where every tick serialises)."""
        if self.sum_tick_inf_s <= 0:
            return 1.0
        return self.sum_batched_inf_s / self.sum_tick_inf_s

    def group_utilisation(self) -> dict:
        """Per replica group: busy seconds over the pod's tick seconds
        (the idle share is the cost of imbalanced variant load)."""
        if self.sum_tick_inf_s <= 0:
            return {g: 0.0 for g in self.group_busy_s}
        return {g: busy / self.sum_tick_inf_s
                for g, busy in sorted(self.group_busy_s.items())}

    def event_e2e_percentiles(self, qs=(50, 95, 99)) -> dict[int, float]:
        """Event-clock E2E percentiles over the finished frames."""
        if not self.event_e2e:
            return {q: 0.0 for q in qs}
        arr = np.asarray(self.event_e2e)
        return {q: float(np.percentile(arr, q)) for q in qs}


def _bump(counter: dict, task: str, amount=1) -> None:
    """Increment one per-task ServeStats counter dict."""
    counter[task] = counter.get(task, 0) + amount


def format_group_report(stats: ServeStats, placement) -> list[str]:
    """Human-readable replica-group summary lines (shared by the
    serving drivers so the format can't drift between them).  Device
    counts come from dispatch time, not the final partition, so busy
    seconds accrued before a rebalance keep their real group width."""
    util = ", ".join(
        f"g{g}[{stats.group_devices.get(g, '?')}dev]={u:.0%}"
        for g, u in stats.group_utilisation().items())
    return [
        f"replica groups over {placement.n_devices} devices "
        f"[{stats.policy} policy]: "
        f"device-aware tick inference {stats.sum_tick_inf_s:.1f}s "
        f"(sharding gain {stats.sharding_gain:.2f}x, "
        f"{placement.rebalances} rebalances)",
        f"group utilisation: {util}",
    ]


def format_open_loop_report(stats: ServeStats, horizon_s: float) -> list[str]:
    """Human-readable open-loop traffic summary lines (shared by the
    serving drivers so the conservation arithmetic — arrivals =
    admitted + rejected + missed — renders identically everywhere)."""
    pct = stats.event_e2e_percentiles()
    lines = [
        f"open-loop traffic [{stats.admission} admission]: "
        f"{stats.arrivals} arrivals over {horizon_s:.1f}s "
        f"({stats.arrivals / max(horizon_s, 1e-9):.2f} frames/s offered) "
        f"-> {stats.admitted} admitted ({stats.degraded} degraded, "
        f"{stats.empty_frames} empty), "
        f"{stats.rejected} rejected, {stats.missed} missed",
        f"queueing: mean delay {stats.mean_queue_delay * 1e3:.1f}ms, "
        f"event E2E p50/p95/p99 "
        f"{pct[50]:.3f}/{pct[95]:.3f}/{pct[99]:.3f}s",
    ]
    if stats.slo_s is not None:
        useful = stats.useful_goodput_frames
        lines.append(
            f"SLO {stats.slo_s:.2f}s: {useful}/{stats.frames} "
            f"frames served within SLO "
            f"(goodput {useful / max(horizon_s, 1e-9):.2f} "
            f"frames/s, {stats.slo_violations} violations)")
    return lines


def format_pod_allocation_report(stats: ServeStats) -> str:
    """Human-readable pod-level allocation summary (shared by the
    serving drivers, like :func:`format_group_report`, so the format —
    and the accuracy-proxy units — cannot drift between them)."""
    return (f"pod-level allocation: "
            f"{stats.pod_rounds / max(stats.pod_ticks, 1):.1f} "
            f"fixed-point rounds/tick "
            f"({stats.pod_converged_ticks}/{stats.pod_ticks} ticks "
            f"converged), accuracy proxy "
            f"{stats.accuracy_proxy:.3f}/stream-frame")


@dataclasses.dataclass
class _InFlightFrame:
    """A frame emitted but not yet finished (its requests may span
    ticks under a carry-over policy)."""

    loop: OmniSenseLoop
    pending: object               # omnisense.PendingFrame
    emitted_s: float              # event-clock emission time
    done_s: float                 # latest completion among its dispatches
    frame_idx: int | None = None  # stream frame index it was emitted for
    stream: int | None = None     # stream index (diagnostics/open loop)
    slots: dict = dataclasses.field(default_factory=dict)

    @property
    def complete(self) -> bool:
        return len(self.slots) == len(self.pending.requests)


class PodServer:
    """Thin driver over the event-clock serving runtime.

    ``frame_source(stream_idx, frame_idx)`` optionally supplies real
    frame pixels per stream (the Jax detector path); oracle backends
    sample ground truth and take ``None``.

    ``policy`` is a :class:`repro.serving.runtime.SchedulePolicy`
    instance or registered name (``"sync"``/``"deadline"``/``"async"``)
    and owns admission, drain ordering and carry-over; the default
    ``SyncTickPolicy`` reproduces the pre-runtime tick barrier
    bit-identically.  (The PR 5 ``pod_allocate=`` DeprecationWarning
    shim was removed on schedule: pod-level allocation is configured on
    the policy object only — see README "Migration".)

    ``telemetry`` is a :class:`repro.serving.telemetry.TelemetrySink`
    (default no-op): every arrival, admission verdict, emission,
    dispatch launch/complete, carry, rebalance, policy decision, tick
    close and frame finish emits one structured record — the event log
    the replay harness (``repro.serving.replay``) re-drives.  Records
    carry only deterministic quantities (event-clock seconds, model
    prices, detection digests), never wall-clock time.
    """

    def __init__(self, loops: list[OmniSenseLoop], backends: list,
                 max_batch: int = 8, marginal_batch_cost: float | None = None,
                 buckets: ShapeBuckets | None = None,
                 frame_source: Callable[[int, int], np.ndarray] | None = None,
                 placement=None, policy=None, telemetry=None,
                 incremental_nms: bool = True):
        assert len(loops) == len(backends)
        self.loops = loops
        self.backends = backends
        self.max_batch = max_batch
        self.policy = make_policy(policy) if policy is not None \
            else SyncTickPolicy()
        self.telemetry = telemetry if telemetry is not None \
            else TelemetrySink()
        # task dimension: each loop serves ONE analytics task (the
        # registry's loop factories stamp ``loop.task``; bare loops
        # default to detection).  Task ladders own disjoint variant-name
        # spaces, so the plain NAME strings that key the queues,
        # placement groups and telemetry already encode (task, variant).
        self.tasks: tuple[str, ...] = tuple(dict.fromkeys(
            self._task(loop) for loop in loops))
        self._variant_task: dict[str, str] = {}
        for loop in loops:
            task = self._task(loop)
            for v in loop.variants:
                prev = self._variant_task.setdefault(v.name, task)
                if prev != task:
                    raise ValueError(
                        f"variant name {v.name!r} is claimed by tasks "
                        f"{prev!r} and {task!r}; task ladders must own "
                        "disjoint name spaces (names key the queues)")
        if self.policy.pod_allocate:
            ladders: dict[str, tuple] = {}
            for loop in loops:
                task = self._task(loop)
                ladder = tuple(v.name for v in loop.variants)
                if ladders.setdefault(task, ladder) != ladder:
                    raise ValueError(
                        "pod-level allocation needs every stream of a "
                        f"task on the same variant ladder; task {task!r} "
                        f"got {ladders[task]} vs {ladder}")
        # repro.serving.placement.VariantPlacement: routes each drained
        # chunk to its variant's replica group and switches the tick
        # model to max-over-groups; None = single-device pod (every
        # dispatch serialises in one implicit group).
        self.placement = placement
        if placement is not None:
            placed = set(placement.variant_names)
            missing = {v.name for loop in loops for v in loop.variants
                       if v.name not in placed}
            if missing:
                raise ValueError(
                    f"placement has no replica group for variants {sorted(missing)}")
        # None = defer to each latency model's batched_inference_delay
        # (the default OmniSenseLatencyModel curve); a float OVERRIDES
        # the curve for every dispatch the server prices.
        self.marginal = marginal_batch_cost
        self.buckets = buckets or ShapeBuckets.for_max_batch(max_batch)
        if self.buckets.max_batch != max_batch:
            raise ValueError(
                f"buckets top out at {self.buckets.max_batch}, "
                f"max_batch is {max_batch}")
        # a drained chunk must be ONE backend dispatch: a backend whose
        # own bucket ladder tops out below the server's would silently
        # split chunks and the priced schedule would diverge from the
        # executed one.
        for b in backends:
            b_buckets = getattr(b, "buckets", None)
            if b_buckets is not None and b_buckets.max_batch < max_batch:
                raise ValueError(
                    f"backend buckets top out at {b_buckets.max_batch} < "
                    f"max_batch {max_batch}; align the backend's "
                    "ShapeBuckets with the server's")
        self.frame_source = frame_source
        self.queues = VariantQueues(self.buckets)
        self.stats = ServeStats(policy=self.policy.name)
        self.clock = GroupClock()
        # per-tick event records (runs in this repo are short; a
        # long-lived deployment would cap/rotate these)
        self.timelines: list[TickTimeline] = []
        self._inflight: list[_InFlightFrame] = []
        self._by_owner: dict[int, _InFlightFrame] = {}
        # the pod-level allocator's per-group load projection for the
        # CURRENT tick (solve_pod exports it; None -> the policy
        # rebuilds it from the live queues on the same curve)
        self._projected_load: dict | None = None
        # the tick-charge curves are POD-level quantities, so they must
        # come from ONE curve no matter which stream's dispatch happens
        # first — resolved once here, and conflicting curves across the
        # streams' latency models are an error instead of a dispatch-
        # order lottery
        self._tick_lat = self._resolve_curve_hook("tick_inference_delay")
        self._overlap_lat = self._resolve_curve_hook("tick_overlap_delay")
        # open-loop state (run_open_loop): the run's SLO target, the
        # busy horizon already charged to sum_tick_inf_s, and each
        # stream's newest in-flight frame (the depth-1 camera buffer)
        self.slo_s: float | None = None
        # the capacity envelope the pod-level fixed point prices
        # against.  Defaults to the pod's own slo_s; the fleet tier
        # overwrites it per arrival round with the FLEET-global
        # residual envelope (slo minus the fleet's worst busy horizon),
        # so co-scheduled pods stop over-admitting against a private
        # budget the shared tail has already spent.
        self.solve_slo_s: float | None = None
        self._open_horizon = 0.0
        self._stream_frame: dict[int, _InFlightFrame] = {}
        # monotone dispatch id joining each telemetry launch/complete
        # record pair across the whole run
        self._dispatch_seq = 0
        # cross-tick incremental NMS: rows whose detections are exactly
        # last tick's reuse last tick's keep-mask instead of paying the
        # (N, N) SphIoU block again (bit-identical by row independence;
        # see repro.core.sphere.IncrementalNms).  Instantiated lazily at
        # the first single-threshold suppression.
        self.incremental_nms = incremental_nms
        self._nms_inc: IncrementalNms | None = None

    @staticmethod
    def _task(loop) -> str:
        """The analytics task a loop serves (registry loop factories
        stamp ``loop.task``; bare loops are detection)."""
        return getattr(loop, "task", "detection")

    def _emit_run_meta(self, mode: str) -> None:
        """One ``run_meta`` telemetry record per run entry point."""
        if not self.telemetry.enabled:
            return
        self.telemetry.emit(
            "run_meta", schema=SCHEMA_VERSION, mode=mode,
            n_streams=len(self.loops), policy=self.policy.describe(),
            max_batch=self.max_batch,
            devices=self.placement.n_devices if self.placement is not None
            else 0,
            variants=list(self._variant_task),
            tasks=list(self.tasks),
            slo_s=self.slo_s)

    def _resolve_curve_hook(self, attr: str):
        """One pod-wide tick-charge hook across the streams' latency
        models.  Models sharing the same underlying function (e.g. many
        instances of one class) agree by construction; models providing
        DIFFERENT curves cannot price one pod tick, so that's an error.
        Streams whose model lacks the hook have no opinion."""
        hooks: dict = {}
        for loop in self.loops:
            h = getattr(loop.latency_model, attr, None)
            if h is not None:
                hooks.setdefault(getattr(h, "__func__", h), h)
        if len(hooks) > 1:
            models = sorted({type(loop.latency_model).__name__
                             for loop in self.loops
                             if getattr(loop.latency_model, attr, None)
                             is not None})
            raise ValueError(
                f"conflicting {attr} curves across the pod's latency "
                f"models {models}; the tick charge is a pod-level "
                "quantity and must come from one curve — share a "
                "latency model (or at least its tick hooks) across "
                "streams")
        return next(iter(hooks.values()), None)

    def _maybe_rebalance(self, t_s: float) -> None:
        """Placement-rebalance check at one observation point.

        The policy owns the TIMING (``SchedulePolicy.rebalance_point``
        — the old hard-wired ``maybe_rebalance()`` call sites asked
        unconditionally, which is exactly what the base hook returns);
        the placement owns the decision and the atomic device swap.
        """
        if not self.policy.rebalance_point(self.placement, self.clock,
                                           self.queues):
            return
        if self.placement.maybe_rebalance() and self.telemetry.enabled:
            self.telemetry.emit("rebalance", t_s=t_s,
                                groups=self.placement.device_counts())

    @property
    def pod_allocate(self) -> bool:
        """Whether admission runs the pod-level fixed point (lives on
        the policy since the runtime refactor)."""
        return self.policy.pod_allocate

    def _price_curve(self, variant, lat, n_dev: int):
        """(curve, single) — the dispatch pricing curve of one variant
        on one latency model, shared by dispatch billing and the
        policies' pre-dispatch chunk estimates so they cannot drift."""
        blat = getattr(lat, "batched_inference_delay", None)
        single = blat(variant, 1) if blat is not None else variant.infer_s

        def curve(n: int) -> float:
            n_eff = -(-n // n_dev)  # largest per-device shard
            if self.marginal is not None:  # explicit override
                return single * (1.0 + (n_eff - 1) * self.marginal)
            shard = getattr(lat, "sharded_inference_delay", None)
            if shard is not None:
                return shard(variant, n, n_dev)
            if blat is not None:
                return blat(variant, n_eff)
            return single * (1.0 + (n_eff - 1) * 0.15)

        return curve, single

    def _dispatch_cost(self, dispatch: dict) -> tuple[float, float]:
        """(batched, per-request-sum) inference seconds of one dispatch.

        A chunk of per-stream *simulation* backends (oracle:
        ``semantic_batch``) models one shared-accelerator forward and
        is priced at the chunk's batch size; with real backends every
        executed backend group is its own forward, so pricing follows
        ``group_sizes`` and cannot overstate batching that never ran.
        A dispatch routed to a multi-device replica group shards its
        batch over the group, so the priced forward is the largest
        per-device shard (``sharded_inference_delay``); the
        per-request comparator stays the single-device sum.
        """
        variant = dispatch["items"][0].request.variant
        lat = dispatch["items"][0].latency_model
        group = dispatch.get("group")
        n_dev = group.n_devices if group is not None else 1
        curve, single = self._price_curve(variant, lat, n_dev)
        b = dispatch["b"]
        if dispatch["semantic"]:
            batched = curve(b)
        else:
            batched = sum(curve(g) for g in dispatch["group_sizes"])
        return batched, single * b

    def _chunk_cost(self, name: str, b: int) -> float:
        """Pre-dispatch estimate of one queued chunk's batched cost
        (the policies' planning signal; the executed dispatch is
        billed by :meth:`_dispatch_cost` on the same curve)."""
        item = self.queues.head(name)
        if item is None:
            return 0.0
        group = self.placement.group_for(name) if self.placement is not None \
            else None
        curve, _ = self._price_curve(
            item.request.variant, item.latency_model,
            group.n_devices if group is not None else 1)
        return curve(b)

    def _pod_plan(self, frames: list) -> list:
        """Coupled emission: collect every stream's planning context,
        solve the pod-level fixed point, emit per the joint plans.

        Coupled prices derive from the FIRST loop's latency model (one
        edge serves the pod, so one batched curve); per-stream base
        matrices still carry each stream's own delivery estimates, and
        the zero-co-stream coupling is the exact identity, so streams
        with private models only ever see pod-relative adjustments."""
        from repro.serving import pod_allocation

        ctxs, ctx_durations = [], []
        for loop, frame in zip(self.loops, frames):
            ctx = loop.frame_context(frame)
            ctx_durations.append(time.perf_counter() - ctx.t0)
            ctxs.append(ctx)
        # a multi-task pod prices the two ladders' cost curves JOINTLY:
        # each stream's problem carries its own (variants, latency
        # model) override and solve_pod unions them onto one capacity
        # envelope.  Single-task pods pass no overrides, keeping the
        # pre-task solve arithmetic bit-identical.
        multi = len(self.tasks) > 1
        problems = [pod_allocation.StreamProblem(
            ctx.acc, ctx.d_pre, ctx.d_inf, ctx.budget,
            variants=tuple(loop.variants) if multi else None,
            latency_model=loop.latency_model if multi else None)
            for loop, ctx in zip(self.loops, ctxs)]
        util = (self.stats.group_utilisation()
                if self.placement is not None and self.stats.sum_tick_inf_s > 0
                else None)
        t_solve = time.perf_counter()
        sol = pod_allocation.solve_pod(
            problems, self.loops[0].variants, self.loops[0].latency_model,
            buckets=self.buckets, placement=self.placement,
            group_utilisation=util)
        solve_share = (time.perf_counter() - t_solve) / len(self.loops)
        self.stats.pod_ticks += 1
        self.stats.pod_rounds += sol.rounds
        self.stats.pod_converged_ticks += int(sol.converged)
        # the solver already projected this tick's per-group load on
        # the shared curve — hand it to the drain policy instead of
        # letting it recompute the same sums from the queues
        self._projected_load = dict(sol.projected_load)
        # re-stamp each context immediately before ITS emission so
        # emit_pending bills the stream its own planning time plus a
        # fair share of the shared solve — never the sequential wall
        # time of the other streams' planning or emission
        out = []
        for loop, ctx, dur, plan in zip(self.loops, ctxs, ctx_durations,
                                        sol.plans):
            ctx.t0 = time.perf_counter() - dur - solve_share
            out.append(loop.emit_pending(ctx, plan))
        return out

    def step(self, frame_idx: int) -> None:
        """Process one frame for every stream (one scheduler tick)."""
        # ---- emission: every loop plans and parks its requests (the
        # pod-allocate path plans all streams jointly first) ----
        frames = []
        for s, backend in enumerate(self.backends):
            if hasattr(backend, "set_frame"):
                backend.set_frame(frame_idx)
            frames.append(self.frame_source(s, frame_idx)
                          if self.frame_source is not None else None)
        self._projected_load = None
        if self.policy.pod_allocate:
            emitted = self._pod_plan(frames)
        else:
            emitted = [loop.begin_frame(frame)
                       for loop, frame in zip(self.loops, frames)]
        for s, (loop, backend, pending) in enumerate(
                zip(self.loops, self.backends, emitted)):
            entry = _InFlightFrame(loop=loop, pending=pending,
                                   emitted_s=self.clock.now,
                                   done_s=self.clock.now,
                                   frame_idx=frame_idx, stream=s)
            self._inflight.append(entry)
            self._by_owner[id(pending)] = entry
            task = self._task(loop)
            if pending.plan is not None:
                self.stats.sum_plan_value += pending.plan.value
                _bump(self.stats.plan_value_by_task, task,
                      pending.plan.value)
            for req in pending.requests:
                self.queues.put(QueuedRequest(
                    request=req, owner=pending, backend=backend,
                    latency_model=loop.latency_model,
                    deadline=loop.budget_s, emitted_s=self.clock.now,
                    frame_idx=frame_idx, task=task))
            if self.telemetry.enabled:
                self.telemetry.emit(
                    "emit", t_s=self.clock.now, stream=s, task=task,
                    frame_idx=frame_idx, n_requests=len(pending.requests),
                    plan_value=pending.plan.value
                    if pending.plan is not None else 0.0,
                    variants=[req.variant.name for req in pending.requests])

        # ---- placement feedback: fold this tick's variant mix into the
        # popularity EMA and re-balance replica groups if the allocator
        # shifted load (atomic swap: queued requests keep a group).
        # WHEN to rebalance is the policy's call (rebalance_point):
        # sync/deadline check every emission (the pre-hook timing,
        # bit-identical), async only at capacity boundaries ----
        if self.placement is not None:
            counts: dict[str, int] = {}
            for pending in emitted:
                for req in pending.requests:
                    counts[req.variant.name] = counts.get(req.variant.name, 0) + 1
            self.placement.observe(counts)
            self._maybe_rebalance(self.clock.now)

        # ---- drain: the policy picks order and carry-over; every
        # admitted chunk is one batched forward routed to (and sharded
        # over) its variant's replica group ----
        timeline = TickTimeline(len(self.timelines), self.clock.now)
        ops = self.policy.plan_drain(
            self.queues, self.buckets, self.placement, self.clock,
            chunk_cost=self._chunk_cost, projected_load=self._projected_load)
        self._emit_policy_decision(timeline, ops)
        self._execute(ops, timeline, self.policy.close_tick)
        self.stats.ticks += 1
        self.stats.carry_tick_slots += len(self.queues)
        self.stats.carried_requests += self.queues.newly_carried()

        # ---- ingestion: frames whose last request resolved finish now ----
        self._ingest()

    def _emit_policy_decision(self, timeline: TickTimeline, ops) -> None:
        """One ``policy_decision`` record per planned drain (the plan
        as the policy returned it, before execution)."""
        if not self.telemetry.enabled:
            return
        self.telemetry.emit(
            "policy_decision", tick=timeline.tick, t_s=timeline.start,
            policy=self.policy.name,
            ops=[{"variant": op.variant, "take": op.take}
                 if hasattr(op, "variant") else
                 {"variant": op[0], "take": op[1]} for op in ops])

    def _execute(self, ops, timeline: TickTimeline, close) -> None:
        """Dispatch a drain plan, book it on the event clock, charge
        the tick per the policy's close rule."""
        results, dispatches = self.queues.drain_ops(ops, self.placement)
        for d in dispatches:
            self.stats.dispatches += 1
            self.stats.batch_sizes.append(d["b"])
            batched, per_request = self._dispatch_cost(d)
            self.stats.sum_batched_inf_s += batched
            self.stats.sum_per_request_inf_s += per_request
            group = d.get("group")
            gidx = group.index if group is not None else 0
            n_dev = group.n_devices if group is not None else 1
            timeline.open_group(gidx, self.clock.free_at(gidx))
            launch, complete = self.clock.dispatch(gidx, batched)
            event = DispatchEvent(
                variant=d["variant"], b=d["b"], padded=d["padded"],
                group=gidx, n_devices=n_dev, cost_s=batched,
                launch_s=launch, complete_s=complete,
                emitted_s=max(it.emitted_s for it in d["items"]),
                tick=timeline.tick,
                carried=sum(1 for it in d["items"] if it.age > 0))
            timeline.record(event)
            d["event"] = event
            self.stats.group_busy_s[gidx] = (
                self.stats.group_busy_s.get(gidx, 0.0) + batched)
            self.stats.group_devices[gidx] = n_dev
            delays = []
            for it in d["items"]:
                owner = self._by_owner[id(it.owner)]
                owner.done_s = max(owner.done_s, complete)
                delays.append(max(0.0, launch - it.emitted_s))
            self.stats.queue_delays.extend(delays)
            if self.telemetry.enabled:
                self._dispatch_seq += 1
                self.telemetry.emit(
                    "dispatch_launch", tick=event.tick,
                    dispatch=self._dispatch_seq, variant=event.variant,
                    task=self._variant_task.get(event.variant, "detection"),
                    b=event.b, padded=event.padded, group=gidx,
                    n_devices=n_dev, cost_s=batched, launch_s=launch,
                    emitted_s=event.emitted_s, carried=event.carried,
                    queue_delays=delays)
                self.telemetry.emit(
                    "dispatch_complete", tick=event.tick,
                    dispatch=self._dispatch_seq, variant=event.variant,
                    group=gidx, complete_s=complete, cost_s=batched)
        for item, dets in results:
            self._by_owner[id(item.owner)].slots[item.request.slot] = dets
        self.timelines.append(timeline)
        if self.telemetry.enabled and len(self.queues):
            self.telemetry.emit(
                "carry", tick=timeline.tick, t_s=self.clock.now,
                queued={name: c for name, c in self.queues.counts().items()
                        if c},
                total=len(self.queues))
        charge, next_start = close(self.clock, timeline,
                                   self._tick_lat, self._overlap_lat)
        self.stats.sum_tick_inf_s += charge
        self.clock.advance(next_start)
        if self.telemetry.enabled:
            self.telemetry.emit(
                "tick_close", tick=timeline.tick, t_s=timeline.start,
                charge_s=charge, next_start_s=next_start,
                dispatches=len(timeline.events))

    def _ingest(self) -> None:
        """Finish every in-flight frame whose requests all resolved
        (in emission order, so per-stream history stays in frame
        order), with one batched NMS dispatch across them."""
        finishing = [e for e in self._inflight if e.complete]
        if not finishing:
            return
        self._inflight = [e for e in self._inflight if not e.complete]
        plans = []
        for e in finishing:
            del self._by_owner[id(e.pending)]
            request_detections = [e.slots.get(i, [])
                                  for i in range(len(e.pending.requests))]
            # a frame finishing a tick late (carried requests) must run
            # its discovery pass against ITS OWN frame's ground truth,
            # not whatever frame the tick advanced the simulation to
            backend = e.loop.backend
            if e.frame_idx is not None and hasattr(backend, "set_frame"):
                backend.set_frame(e.frame_idx)
            result = e.loop.finish_frame(e.pending, request_detections,
                                         defer_nms=True)
            plans.append((e.loop, result))

        # one batched spherical-NMS dispatch for every frame that
        # finished this tick (instead of B Python loops)
        self.stats.sum_overhead += self._suppress_tick(plans)

        for e, (_, result) in zip(finishing, plans):
            self.stats.frames += 1
            _bump(self.stats.frames_by_task, self._task(e.loop))
            self.stats.total_detections += len(result.detections)
            self.stats.sum_e2e += result.planned_latency
            self.stats.sum_overhead += result.overhead_s
            e2e = max(0.0, e.done_s - e.emitted_s)
            self.stats.event_e2e.append(e2e)
            violated = (self.slo_s is not None
                        and e2e > self.slo_s + 1e-12)
            if violated:
                self.stats.slo_violations += 1
            if (e.stream is not None
                    and self._stream_frame.get(e.stream) is e):
                del self._stream_frame[e.stream]
            if self.telemetry.enabled:
                self.telemetry.emit(
                    "frame_finish", t_s=e.done_s, stream=e.stream,
                    task=self._task(e.loop),
                    frame_idx=e.frame_idx, event_e2e_s=e2e,
                    n_detections=len(result.detections),
                    det_digest=detections_digest(result.detections),
                    slo_violation=violated)

    def _suppress_tick(self, plans: list) -> float:
        """Batched spherical NMS across the tick; returns wall time.

        Frames with detections are padded to a common N and suppressed
        in one ``sph_nms_batch`` call; every loop (including empty ones)
        then gets its keep-mask back via ``finalize_detections`` so the
        per-stream detection feedback matches the inline path exactly.
        Falls back to per-stream single-row calls only if the loops
        disagree on the NMS threshold.
        """
        t0 = time.perf_counter()
        rows = [(loop, res) for loop, res in plans if res.detections]
        thresholds = {loop.nms_threshold for loop, _ in rows}
        keeps: dict[int, np.ndarray] = {}
        if rows and len(thresholds) == 1:
            # bucketed padding bounds the device path's compile shapes:
            # B pins to the stream count, N snaps to the NMS ladder, so
            # a serving lifetime compiles at most len(nms_sizes)
            # programs (pinned by the trace-counter regression test).
            # The host path never compiles, so there padding is skipped
            # instead of wasting O(B*N^2) on masked rows.
            row_dets = [res.detections for _, res in rows]
            n_pad = self.buckets.pad_nms_rows(max(len(d) for d in row_dets))
            if nms_auto_backend(len(plans), n_pad) == "device":
                boxes, scores, mask = pad_detection_rows(
                    row_dets, pad_n=self.buckets.pad_nms_rows,
                    total_rows=len(plans))
            else:
                boxes, scores, mask = pad_detection_rows(row_dets)
            thr = thresholds.pop()
            if self.incremental_nms:
                # per-stream loop identity is the stable row key; the
                # all-masked padding rows get a shared sentinel (their
                # canonical form is empty, so they always reuse)
                if self._nms_inc is None or self._nms_inc.iou_threshold != thr:
                    self._nms_inc = IncrementalNms(thr)
                keys = [id(loop) for loop, _ in rows]
                keys += [("pad", r) for r in range(len(keys), len(boxes))]
                keep = self._nms_inc.suppress(keys, boxes, scores, mask)
            else:
                keep = sph_nms_batch(boxes, scores, mask, iou_threshold=thr)
            for r, (_, res) in enumerate(rows):
                keeps[id(res)] = keep[r, : len(res.detections)]
        elif rows:  # heterogeneous thresholds: per-stream single rows
            for loop, res in rows:
                keeps[id(res)] = loop.nms_keep(res.detections)
        for loop, res in plans:
            loop.finalize_detections(res, keeps.get(id(res)))
        return time.perf_counter() - t0

    def flush(self) -> None:
        """Settle carried work: dispatch every still-queued request in
        one full sorted drain (priced on the overlap model — carried
        work launches when its group frees) and finish the frames left
        in flight.  A strict no-op under policies without carry-over,
        so ``run`` keeps the sync path bit-identical.  Flush charges
        accrue to ``sum_tick_inf_s`` without growing ``ticks``: the
        async mean tick pays its tail instead of hiding it.

        The round bound is keyed to what a drain can actually owe: a
        full drain dispatches every queued request, so one round
        settles everything a well-behaved pod queued, and extra
        headroom covers a policy that carried up to ``max_carry``
        ticks plus the chunked depth of the deepest queue.  A pod
        still unsettled past the bound is a real invariant break
        (e.g. a request whose owner never ingests) and raises a
        diagnostic ``RuntimeError`` naming the unsettled streams."""
        deepest = max(self.queues.counts().values(), default=0)
        rounds = (2 + int(getattr(self.policy, "max_carry", 0))
                  + -(-deepest // self.buckets.max_batch))
        for _ in range(rounds):
            if not len(self.queues) and not self._inflight:
                break
            if len(self.queues):
                timeline = TickTimeline(len(self.timelines), self.clock.now)
                self._execute(self.queues.full_drain_ops(), timeline,
                              self._flush_close)
            self._ingest()
        if len(self.queues) or self._inflight:
            raise RuntimeError(
                f"flush failed to settle the pod after {rounds} "
                f"drain rounds: {self._unsettled_report()}")

    def _unsettled_report(self) -> str:
        """What flush left behind, by stream — the diagnostic payload
        of the flush-depth RuntimeError."""
        queued = {name: c for name, c in self.queues.counts().items() if c}
        frames = []
        for e in self._inflight:
            stream = e.stream if e.stream is not None \
                else self.loops.index(e.loop)
            frames.append(
                f"stream {stream} frame {e.frame_idx} "
                f"({len(e.slots)}/{len(e.pending.requests)} requests "
                "resolved)")
        return (f"queued requests by variant: {queued or '{}'}; "
                f"in-flight frames: {', '.join(frames) or 'none'}")

    @staticmethod
    def _flush_close(clock: GroupClock, timeline: TickTimeline,
                     tick_lat=None, overlap_lat=None) -> tuple[float, float]:
        """Flush charge: the overlap-generalised barrier — each touched
        group pays its carry-in plus its serialised drain, max over
        groups, via the latency model's closed form
        (``tick_overlap_delay``) when it provides one.  The event
        horizon is kept as the floor: it additionally covers busy
        groups the flush had nothing left to drain on, so the carried
        tail can never go unbilled."""
        del tick_lat
        horizon = clock.horizon()
        charge = max(0.0, horizon - timeline.start)
        if overlap_lat is not None:
            charge = max(charge,
                         overlap_lat(timeline.group_costs, timeline.carry_in))
        return charge, horizon

    def run(self, frames: range) -> ServeStats:
        self._emit_run_meta("closed")
        for f in frames:
            self.step(f)
        self.flush()
        return self.stats

    # -- open-loop (arrival-clocked) serving -------------------------------

    def run_open_loop(self, traffic, *, slo_s: float | None = None
                      ) -> ServeStats:
        """Arrival-driven serving: the event clock advances to each
        arrival instead of a global frame barrier.

        ``traffic`` is a :class:`repro.serving.traffic.ArrivalProcess`
        (or any iterable of time-ordered ``Arrival``s): streams
        join/leave per its churn trace, each arrival carries its own
        per-stream ``frame_idx``, and a frame whose predecessor still
        occupies the stream's depth-1 camera buffer is counted
        ``missed`` — never fabricated, never queued behind it.  Every
        surviving arrival consults the policy's
        :class:`~repro.serving.runtime.AdmissionPolicy` against the
        SLO envelope (``slo_s``): admit the full allocator plan,
        degrade to skip+P1, or reject.  The conservation invariant:
        ``arrivals == admitted + rejected + missed``.

        Unlike closed-loop ticks, drains here never block arrivals —
        work is booked on the busy groups and the clock keeps tracking
        arrival time, so queueing delay (launch minus emission) and
        SLO violations are real, not artifacts of a barrier.

        Pod-allocate policies are served too: arrivals landing at the
        same instant are planned JOINTLY through the pod-level fixed
        point with ``slo_s`` as its capacity envelope
        (``solve_pod(..., slo_s=...)``); running one without an SLO is
        deprecated (see :meth:`open_loop_begin`).

        The loop is a thin driver over :meth:`open_loop_begin` /
        :meth:`serve_open_batch` / :meth:`open_loop_end` — the fleet
        tier (``repro.serving.fleet``) drives the same three phases
        per pod with a router splitting the global arrival stream.
        """
        arrivals = traffic.arrivals() if hasattr(traffic, "arrivals") \
            else list(traffic)
        self.open_loop_begin(slo_s)
        i, n = 0, len(arrivals)
        while i < n:
            self.clock.advance(arrivals[i].t_s)
            # arrivals landing at the same instant share one admission
            # + drain round, so their requests can batch together
            batch = []
            while i < n and arrivals[i].t_s <= self.clock.now + 1e-12:
                batch.append(arrivals[i])
                i += 1
            self.serve_open_batch(batch)
        return self.open_loop_end()

    def open_loop_begin(self, slo_s: float | None = None) -> None:
        """Enter open-loop serving: record the SLO target and emit the
        run's ``run_meta`` telemetry.  Called once per run by
        :meth:`run_open_loop`; the fleet tier calls it directly on
        every pod it creates (including pods added mid-run by the
        elastic controller)."""
        if self.policy.pod_allocate and slo_s is None:
            import warnings
            warnings.warn(
                "open-loop serving with a pod_allocate policy but no "
                "slo_s leaves the pod-level fixed point without a "
                "service-level capacity envelope (the round-0 "
                "self-referential cap only); pass slo_s= to "
                "run_open_loop so solve_pod can clamp the envelope. "
                "This will become an error in the next release — see "
                "README 'Migration'.", DeprecationWarning, stacklevel=3)
        self.slo_s = slo_s
        self.solve_slo_s = slo_s
        self.stats.slo_s = slo_s
        self.stats.admission = self.policy.admission.name
        self._emit_run_meta("open")
        self._open_horizon = self.clock.now

    def serve_open_batch(self, batch: list) -> None:
        """Serve one same-instant arrival round: advance the event
        clock, admit every arrival (jointly under a pod-allocate
        policy), then drain and ingest."""
        self.clock.advance(batch[0].t_s)
        if self.policy.pod_allocate:
            self._admit_batch_coupled(batch)
        else:
            for a in batch:
                self._admit_arrival(a)
        self._open_drain()
        self._ingest()

    def open_loop_end(self) -> ServeStats:
        """Leave open-loop serving: settle carried work and finish the
        in-flight tail.  Every busy second up to the horizon is already
        charged; jump the clock there so the settling flush only bills
        new work."""
        self.clock.advance(self.clock.horizon())
        self.flush()
        return self.stats

    def _admit_batch_coupled(self, batch: list) -> None:
        """Joint admission of one same-instant arrival round under a
        pod-allocate policy: the surviving arrivals' planning contexts
        run through the pod-level fixed point together (with the run's
        SLO as the capacity envelope), then each arrival passes the
        usual marginal admission pricing with its coupled plan.  A
        single-arrival round hits ``solve_pod``'s one-stream
        short-circuit, so it prices exactly like the per-stream path."""
        from repro.serving import pod_allocation

        survivors = []
        for arrival in batch:
            s = arrival.stream
            loop, backend = self.loops[s], self.backends[s]
            self.stats.arrivals += 1
            _bump(self.stats.arrivals_by_task, self._task(loop))
            if self.telemetry.enabled:
                self.telemetry.emit("arrival", t_s=arrival.t_s, stream=s,
                                    frame_idx=arrival.frame_idx)
            prev = self._stream_frame.get(s)
            if prev is not None and not prev.complete:
                self.stats.missed += 1
                _bump(self.stats.missed_by_task, self._task(loop))
                if self.telemetry.enabled:
                    self._emit_admission(arrival, "missed", None, None,
                                         None)
                continue
            if hasattr(backend, "set_frame"):
                backend.set_frame(arrival.frame_idx)
            frame = (self.frame_source(s, arrival.frame_idx)
                     if self.frame_source is not None else None)
            survivors.append((arrival, loop, backend,
                              loop.frame_context(frame)))
        if not survivors:
            return
        multi = len(self.tasks) > 1
        problems = [pod_allocation.StreamProblem(
            ctx.acc, ctx.d_pre, ctx.d_inf, ctx.budget,
            variants=tuple(loop.variants) if multi else None,
            latency_model=loop.latency_model if multi else None)
            for _, loop, _, ctx in survivors]
        util = (self.stats.group_utilisation()
                if self.placement is not None
                and self.stats.sum_tick_inf_s > 0 else None)
        sol = pod_allocation.solve_pod(
            problems, self.loops[0].variants, self.loops[0].latency_model,
            buckets=self.buckets, placement=self.placement,
            group_utilisation=util, slo_s=self.solve_slo_s)
        self.stats.pod_ticks += 1
        self.stats.pod_rounds += sol.rounds
        self.stats.pod_converged_ticks += int(sol.converged)
        for (arrival, loop, backend, ctx), plan in zip(survivors,
                                                       sol.plans):
            self._admit_planned(arrival, loop, backend, ctx, plan)

    def _admit_arrival(self, arrival) -> None:
        """Admission-check one arrival, emitting its requests if the
        verdict allows (see :meth:`run_open_loop`)."""
        s = arrival.stream
        loop, backend = self.loops[s], self.backends[s]
        self.stats.arrivals += 1
        _bump(self.stats.arrivals_by_task, self._task(loop))
        if self.telemetry.enabled:
            self.telemetry.emit("arrival", t_s=arrival.t_s, stream=s,
                                frame_idx=arrival.frame_idx)
        prev = self._stream_frame.get(s)
        if prev is not None and not prev.complete:
            self.stats.missed += 1
            _bump(self.stats.missed_by_task, self._task(loop))
            if self.telemetry.enabled:
                self._emit_admission(arrival, "missed", None, None, None)
            return
        if hasattr(backend, "set_frame"):
            backend.set_frame(arrival.frame_idx)
        frame = (self.frame_source(s, arrival.frame_idx)
                 if self.frame_source is not None else None)
        ctx = loop.frame_context(frame)
        plan = None
        if ctx.srois:
            plan = allocation.allocate(ctx.acc, ctx.d_pre, ctx.d_inf,
                                       ctx.budget)
        self._admit_planned(arrival, loop, backend, ctx, plan)

    def _admit_planned(self, arrival, loop, backend, ctx, plan) -> None:
        """Admission pricing + emission of one arrival whose candidate
        plan is already chosen (per-stream knapsack or pod-coupled)."""
        s = arrival.stream
        dplan = None
        if ctx.srois:
            # the degraded alternative: rows 0..1 = skip + the P1
            # variant only (model indices stay valid on the full
            # ladder, so emit_pending needs no special casing)
            dplan = allocation.allocate(ctx.acc[:2], ctx.d_pre[:2],
                                        ctx.d_inf[:2], ctx.budget)
        # plan costs are MARGINAL: joint backlog (plan batched with the
        # queued demand, the way the drain executes) minus the bare one
        backlog = self._open_backlog()
        plan_cost = max(
            0.0, self._open_backlog(self._plan_counts(loop, plan)) - backlog)
        degraded_cost = max(
            0.0, self._open_backlog(self._plan_counts(loop, dplan)) - backlog)
        verdict = self.policy.admission.decide(
            backlog_s=backlog, plan_cost_s=plan_cost,
            degraded_cost_s=degraded_cost, slo_s=self.slo_s)
        if self.telemetry.enabled:
            self._emit_admission(arrival, verdict, backlog, plan_cost,
                                 degraded_cost)
        task = self._task(loop)
        if verdict == REJECT:
            self.stats.rejected += 1
            _bump(self.stats.rejected_by_task, task)
            return
        if verdict == DEGRADE:
            plan = dplan
            self.stats.degraded += 1
            _bump(self.stats.degraded_by_task, task)
        self.stats.admitted += 1
        _bump(self.stats.admitted_by_task, task)
        pending = loop.emit_pending(ctx, plan)
        if not pending.requests:
            self.stats.empty_frames += 1
        entry = _InFlightFrame(loop=loop, pending=pending,
                               emitted_s=arrival.t_s, done_s=arrival.t_s,
                               frame_idx=arrival.frame_idx, stream=s)
        self._inflight.append(entry)
        self._by_owner[id(pending)] = entry
        self._stream_frame[s] = entry
        if pending.plan is not None:
            self.stats.sum_plan_value += pending.plan.value
            _bump(self.stats.plan_value_by_task, task, pending.plan.value)
        for req in pending.requests:
            self.queues.put(QueuedRequest(
                request=req, owner=pending, backend=backend,
                latency_model=loop.latency_model,
                deadline=loop.budget_s, emitted_s=arrival.t_s,
                frame_idx=arrival.frame_idx, task=task))
        if self.telemetry.enabled:
            self.telemetry.emit(
                "emit", t_s=arrival.t_s, stream=s, task=task,
                frame_idx=arrival.frame_idx,
                n_requests=len(pending.requests),
                plan_value=pending.plan.value
                if pending.plan is not None else 0.0,
                variants=[req.variant.name for req in pending.requests])
        if self.placement is not None and pending.requests:
            counts: dict[str, int] = {}
            for req in pending.requests:
                counts[req.variant.name] = counts.get(req.variant.name, 0) + 1
            self.placement.observe(counts)
            self._maybe_rebalance(arrival.t_s)

    def _emit_admission(self, arrival, verdict: str, backlog_s,
                        plan_cost_s, degraded_cost_s) -> None:
        """One ``admission`` record per arrival verdict (``missed``
        frames never reach the policy, so their cost fields are null)."""
        self.telemetry.emit(
            "admission", t_s=arrival.t_s, stream=arrival.stream,
            task=self._task(self.loops[arrival.stream]),
            frame_idx=arrival.frame_idx, verdict=verdict,
            backlog_s=backlog_s, plan_cost_s=plan_cost_s,
            degraded_cost_s=degraded_cost_s, slo_s=self.slo_s)

    def _open_backlog(self, extra: dict | None = None) -> float:
        """The admission policy's load signal: per replica group, busy
        carry-in past ``now`` plus the queued demand's chunked drain
        cost on the server's pricing curve — max over groups (groups
        run concurrently, so the slowest one bounds any new frame's
        wait).

        ``extra`` (``{variant_name: (variant, latency_model, count)}``,
        see :meth:`_plan_counts`) folds a candidate plan's requests
        into the queued counts BEFORE pricing, so the plan batches
        with the queued demand exactly like the drain will execute it
        — the admission cost of a plan is the joint backlog minus the
        bare one (its true marginal), not a standalone serial price.
        """
        counts = {name: c for name, c in self.queues.counts().items() if c}
        pricing: dict[str, tuple] = {}
        for name in counts:
            item = self.queues.head(name)
            pricing[name] = (item.request.variant, item.latency_model)
        for name, (variant, lat, n) in (extra or {}).items():
            counts[name] = counts.get(name, 0) + n
            pricing.setdefault(name, (variant, lat))
        load: dict[int, float] = {}
        for name, count in counts.items():
            variant, lat = pricing[name]
            group = self.placement.group_for(name) \
                if self.placement is not None else None
            g = group.index if group is not None else 0
            curve, _ = self._price_curve(
                variant, lat, group.n_devices if group is not None else 1)
            load[g] = load.get(g, 0.0) + sum(
                curve(b) for b in self.buckets.split(count))
        carry = self.clock.carry()
        return max((carry.get(g, 0.0) + load.get(g, 0.0)
                    for g in set(load) | set(carry)), default=0.0)

    @staticmethod
    def _plan_counts(loop, plan) -> dict:
        """A plan's demand as :meth:`_open_backlog` ``extra`` input:
        per variant name, ``(variant, latency_model, request_count)``."""
        out: dict = {}
        if plan is None:
            return out
        for model_idx in plan.models:
            if model_idx == 0:
                continue
            v = loop.variants[model_idx - 1]
            _, _, n = out.get(v.name, (v, loop.latency_model, 0))
            out[v.name] = (v, loop.latency_model, n + 1)
        return out

    def _open_drain(self) -> None:
        """One arrival-round drain: the policy picks order/carry as in
        closed loop, but the close rule never jumps the arrival clock —
        work books onto the busy groups and the charge is the busy-
        horizon extension (so overlapping rounds never double-bill)."""
        if not len(self.queues):
            return
        self._projected_load = None
        timeline = TickTimeline(len(self.timelines), self.clock.now)
        ops = self.policy.plan_drain(
            self.queues, self.buckets, self.placement, self.clock,
            chunk_cost=self._chunk_cost, projected_load=None)
        self._emit_policy_decision(timeline, ops)
        self._execute(ops, timeline, self._open_close)
        if timeline.events:
            self.stats.ticks += 1
        self.stats.carry_tick_slots += len(self.queues)
        self.stats.carried_requests += self.queues.newly_carried()

    def _open_close(self, clock: GroupClock, timeline: TickTimeline,
                    tick_lat=None, overlap_lat=None) -> tuple[float, float]:
        del tick_lat, overlap_lat
        horizon = clock.horizon()
        charge = max(0.0, horizon - max(self._open_horizon, timeline.start))
        self._open_horizon = max(self._open_horizon, horizon)
        return charge, clock.now

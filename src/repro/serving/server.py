"""Pod-scale serving loop: many camera streams multiplexed on one mesh.

The paper's testbed serves ONE stream on one edge GPU.  At pod scale the
same per-frame pipeline (SRoI predict -> allocate -> project -> infer ->
NMS) runs for hundreds of streams, and the interesting systems problem
becomes *variant batching*: PI requests from many streams that chose the
same model variant are batched into one accelerator dispatch.

``PodServer`` runs that loop against a virtual clock:

  * each stream runs its own ``OmniSenseLoop`` state (history,
    discovery, allocator) against the shared latency model; per tick
    every loop EMITS its planned inference requests
    (``begin_frame``) instead of executing them inline;
  * the requests park in real per-variant queues
    (``repro.serving.batching.VariantQueues``) and drain into chunks of
    at most ``max_batch``, each chunk zero-padded up to a batch-size
    bucket and executed as ONE batched detector forward
    (``infer_srois_batched``) — S streams choosing V distinct variants
    issue exactly V batched forwards when V queues fit their buckets;
  * the decoded detections scatter back to their owning loops
    (``finish_frame``), which run discovery and defer suppression;
  * spherical NMS is NOT run per stream: every stream finishing in
    the tick defers suppression, the raw detections are padded into one
    ``(B, N, 4)`` stack, and a single ``sph_nms_batch`` dispatch
    suppresses all rows at once — the inference dispatch and the NMS
    dispatch share one tick schedule;
  * the tick's inference time is charged per DISPATCH via
    ``OmniSenseLatencyModel.batched_inference_delay`` (per-batch fixed
    cost + per-item marginal), not as a per-request ``_inf`` sum;
    utilisation, queue depths and per-stream E2E are reported.

This is the runnable stand-in for the 256-chip serving mesh (the
dry-run proves the detector steps compile on that mesh; this loop
proves the control plane sustains multi-stream operation).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import pad_detection_rows, sph_nms_batch
from repro.serving.batching import QueuedRequest, ShapeBuckets, VariantQueues


@dataclasses.dataclass
class ServeStats:
    frames: int = 0
    total_detections: int = 0
    sum_e2e: float = 0.0
    sum_overhead: float = 0.0
    batch_sizes: list = dataclasses.field(default_factory=list)
    # batched-dispatch accounting (one entry of work per tick)
    dispatches: int = 0
    sum_batched_inf_s: float = 0.0      # what the pod actually pays
    sum_per_request_inf_s: float = 0.0  # what B per-request forwards would

    @property
    def mean_e2e(self) -> float:
        return self.sum_e2e / max(self.frames, 1)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    @property
    def batching_gain(self) -> float:
        """Per-request inference cost over batched cost (>= 1 when
        batching pays; 1.0 when every dispatch had batch 1)."""
        if self.sum_batched_inf_s <= 0:
            return 1.0
        return self.sum_per_request_inf_s / self.sum_batched_inf_s


class PodServer:
    """Variant-batched tick scheduler over per-stream OmniSense loops.

    ``frame_source(stream_idx, frame_idx)`` optionally supplies real
    frame pixels per stream (the Jax detector path); oracle backends
    sample ground truth and take ``None``.
    """

    def __init__(self, loops: list[OmniSenseLoop], backends: list,
                 max_batch: int = 8, marginal_batch_cost: float | None = None,
                 buckets: ShapeBuckets | None = None,
                 frame_source: Callable[[int, int], np.ndarray] | None = None):
        assert len(loops) == len(backends)
        self.loops = loops
        self.backends = backends
        self.max_batch = max_batch
        # None = defer to each latency model's batched_inference_delay
        # (the default OmniSenseLatencyModel curve); a float OVERRIDES
        # the curve for every dispatch the server prices.
        self.marginal = marginal_batch_cost
        self.buckets = buckets or ShapeBuckets.for_max_batch(max_batch)
        if self.buckets.max_batch != max_batch:
            raise ValueError(
                f"buckets top out at {self.buckets.max_batch}, "
                f"max_batch is {max_batch}")
        # a drained chunk must be ONE backend dispatch: a backend whose
        # own bucket ladder tops out below the server's would silently
        # split chunks and the priced schedule would diverge from the
        # executed one.
        for b in backends:
            b_buckets = getattr(b, "buckets", None)
            if b_buckets is not None and b_buckets.max_batch < max_batch:
                raise ValueError(
                    f"backend buckets top out at {b_buckets.max_batch} < "
                    f"max_batch {max_batch}; align the backend's "
                    "ShapeBuckets with the server's")
        self.frame_source = frame_source
        self.queues = VariantQueues(self.buckets)
        self.stats = ServeStats()

    def _dispatch_cost(self, dispatch: dict) -> tuple[float, float]:
        """(batched, per-request-sum) inference seconds of one dispatch.

        A chunk of per-stream *simulation* backends (oracle:
        ``semantic_batch``) models one shared-accelerator forward and
        is priced at the chunk's batch size; with real backends every
        executed backend group is its own forward, so pricing follows
        ``group_sizes`` and cannot overstate batching that never ran.
        """
        variant = dispatch["items"][0].request.variant
        lat = dispatch["items"][0].latency_model
        blat = getattr(lat, "batched_inference_delay", None)
        single = blat(variant, 1) if blat is not None else variant.infer_s

        def curve(n: int) -> float:
            if self.marginal is not None:  # explicit override
                return single * (1.0 + (n - 1) * self.marginal)
            if blat is not None:
                return blat(variant, n)
            return single * (1.0 + (n - 1) * 0.15)

        b = dispatch["b"]
        if dispatch["semantic"]:
            batched = curve(b)
        else:
            batched = sum(curve(g) for g in dispatch["group_sizes"])
        return batched, single * b

    def step(self, frame_idx: int) -> None:
        """Process one frame for every stream (one scheduler tick)."""
        # ---- emission: every loop plans and parks its requests ----
        pendings = []
        for s, (loop, backend) in enumerate(zip(self.loops, self.backends)):
            if hasattr(backend, "set_frame"):
                backend.set_frame(frame_idx)
            frame = (self.frame_source(s, frame_idx)
                     if self.frame_source is not None else None)
            pending = loop.begin_frame(frame)
            pendings.append((loop, pending))
            for req in pending.requests:
                self.queues.put(QueuedRequest(
                    request=req, owner=pending, backend=backend,
                    latency_model=loop.latency_model))

        # ---- drain: bucketed batched forwards, one per variant chunk ----
        results, dispatches = self.queues.drain()
        scatter: dict[int, dict[int, list]] = {}
        for item, dets in results:
            scatter.setdefault(id(item.owner), {})[item.request.slot] = dets
        for d in dispatches:
            self.stats.dispatches += 1
            self.stats.batch_sizes.append(d["b"])
            batched, per_request = self._dispatch_cost(d)
            self.stats.sum_batched_inf_s += batched
            self.stats.sum_per_request_inf_s += per_request

        # ---- ingestion: scatter detections back, defer suppression ----
        plans = []
        for loop, pending in pendings:
            slots = scatter.get(id(pending), {})
            request_detections = [slots.get(i, [])
                                  for i in range(len(pending.requests))]
            result = loop.finish_frame(pending, request_detections,
                                       defer_nms=True)
            plans.append((loop, result))

        # one batched spherical-NMS dispatch for every stream that
        # produced detections this tick (instead of B Python loops)
        self.stats.sum_overhead += self._suppress_tick(plans)

        for _, result in plans:
            self.stats.frames += 1
            self.stats.total_detections += len(result.detections)
            self.stats.sum_e2e += result.planned_latency
            self.stats.sum_overhead += result.overhead_s

    def _suppress_tick(self, plans: list) -> float:
        """Batched spherical NMS across the tick; returns wall time.

        Streams with detections are padded to a common N and suppressed
        in one ``sph_nms_batch`` call; every loop (including empty ones)
        then gets its keep-mask back via ``finalize_detections`` so the
        per-stream detection feedback matches the inline path exactly.
        Falls back to per-stream single-row calls only if the loops
        disagree on the NMS threshold.
        """
        t0 = time.perf_counter()
        rows = [(loop, res) for loop, res in plans if res.detections]
        thresholds = {loop.nms_threshold for loop, _ in rows}
        keeps: dict[int, np.ndarray] = {}
        if rows and len(thresholds) == 1:
            boxes, scores, mask = pad_detection_rows(
                [res.detections for _, res in rows])
            keep = sph_nms_batch(boxes, scores, mask,
                                 iou_threshold=thresholds.pop())
            for r, (_, res) in enumerate(rows):
                keeps[id(res)] = keep[r, : len(res.detections)]
        elif rows:  # heterogeneous thresholds: per-stream single rows
            for loop, res in rows:
                keeps[id(res)] = loop.nms_keep(res.detections)
        for loop, res in plans:
            loop.finalize_detections(res, keeps.get(id(res)))
        return time.perf_counter() - t0

    def run(self, frames: range) -> ServeStats:
        for f in frames:
            self.step(f)
        return self.stats

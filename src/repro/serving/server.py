"""Pod-scale serving loop: many camera streams multiplexed on one mesh.

The paper's testbed serves ONE stream on one edge GPU.  At pod scale the
same per-frame pipeline (SRoI predict -> allocate -> project -> infer ->
NMS) runs for hundreds of streams, and the interesting systems problem
becomes *variant batching*: PI requests from many streams that chose the
same model variant are batched into one accelerator dispatch.

``PodServer`` simulates that loop with a virtual clock:
  * each stream runs its own ``OmniSenseLoop`` state (history,
    discovery, allocator) against the shared latency model;
  * per tick, the scheduler drains the per-variant queues, forms
    batches up to ``max_batch``, and charges
    ``batch_latency = infer_s * (1 + (batch-1) * marginal)`` — the
    standard sub-linear batching curve;
  * spherical NMS is NOT run per stream: every stream finishing in
    the tick defers suppression (``process_frame(defer_nms=True)``),
    the raw detections are padded into one ``(B, N, 4)`` stack, and a
    single ``sph_nms_batch`` dispatch suppresses all rows at once
    before the keep-masks are handed back to each loop's history;
  * utilisation, queue depths and per-stream E2E are reported.

This is the runnable stand-in for the 256-chip serving mesh (the
dry-run proves the detector steps compile on that mesh; this loop
proves the control plane sustains multi-stream operation).
"""

from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.core.sphere import pad_detection_rows, sph_nms_batch


@dataclasses.dataclass
class ServeStats:
    frames: int = 0
    total_detections: int = 0
    sum_e2e: float = 0.0
    sum_overhead: float = 0.0
    batch_sizes: list = dataclasses.field(default_factory=list)

    @property
    def mean_e2e(self) -> float:
        return self.sum_e2e / max(self.frames, 1)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0


class PodServer:
    def __init__(self, loops: list[OmniSenseLoop], backends: list,
                 max_batch: int = 8, marginal_batch_cost: float = 0.15):
        assert len(loops) == len(backends)
        self.loops = loops
        self.backends = backends
        self.max_batch = max_batch
        self.marginal = marginal_batch_cost
        self.stats = ServeStats()
        self._queues: dict[str, collections.deque] = collections.defaultdict(
            collections.deque)

    def step(self, frame_idx: int) -> None:
        """Process one frame for every stream (one scheduler tick)."""
        plans = []
        for loop, backend in zip(self.loops, self.backends):
            backend.set_frame(frame_idx)
            captured = {}
            loop.on_plan = lambda plan, srois, c=captured: c.update(
                plan=plan, srois=srois)
            result = loop.process_frame(None, defer_nms=True)
            plans.append((loop, captured, result))

        # one batched spherical-NMS dispatch for every stream that
        # produced detections this tick (instead of B Python loops)
        self.stats.sum_overhead += self._suppress_tick(plans)

        for _, _, result in plans:
            self.stats.frames += 1
            self.stats.total_detections += len(result.detections)
            self.stats.sum_e2e += result.planned_latency
            self.stats.sum_overhead += result.overhead_s

        # variant batching across streams: count how each variant's
        # queue would batch this tick
        per_variant = collections.Counter()
        for loop, captured, _ in plans:
            plan = captured.get("plan")
            if plan is None:
                continue
            for mi in plan.models:
                if mi > 0:
                    per_variant[loop.variants[mi - 1].name] += 1
        for name, count in per_variant.items():
            while count > 0:
                b = min(count, self.max_batch)
                self.stats.batch_sizes.append(b)
                count -= b

    def _suppress_tick(self, plans: list) -> float:
        """Batched spherical NMS across the tick; returns wall time.

        Streams with detections are padded to a common N and suppressed
        in one ``sph_nms_batch`` call; every loop (including empty ones)
        then gets its keep-mask back via ``finalize_detections`` so the
        per-stream detection feedback matches the inline path exactly.
        Falls back to per-stream single-row calls only if the loops
        disagree on the NMS threshold.
        """
        t0 = time.perf_counter()
        rows = [(loop, res) for loop, _, res in plans if res.detections]
        thresholds = {loop.nms_threshold for loop, _ in rows}
        keeps: dict[int, np.ndarray] = {}
        if rows and len(thresholds) == 1:
            boxes, scores, mask = pad_detection_rows(
                [res.detections for _, res in rows])
            keep = sph_nms_batch(boxes, scores, mask,
                                 iou_threshold=thresholds.pop())
            for r, (_, res) in enumerate(rows):
                keeps[id(res)] = keep[r, : len(res.detections)]
        elif rows:  # heterogeneous thresholds: per-stream single rows
            for loop, res in rows:
                keeps[id(res)] = loop.nms_keep(res.detections)
        for loop, _, res in plans:
            loop.finalize_detections(res, keeps.get(id(res)))
        return time.perf_counter() - t0

    def run(self, frames: range) -> ServeStats:
        for f in frames:
            self.step(f)
        return self.stats

"""Spherical mAP (Sph-mAP) — the paper's accuracy metric (section V-B).

Standard VOC-style mean Average Precision with the rectangular IoU
replaced by SphIoU (AAAI'20 spherical criteria).  Matching threshold
0.5; all-point interpolation; mAP averages over categories that appear
in the ground truth.
"""

from __future__ import annotations

import collections

import numpy as np

from repro.core.sphere import sph_iou_matrix_np
from repro.core.sroi import Detection


def sph_ap(preds: list[tuple[int, Detection]],
           gts: list[tuple[int, Detection]],
           iou_threshold: float = 0.5) -> float:
    """AP for one category.  Items are (frame_idx, detection).

    IoUs are precomputed as ONE vectorised (preds x gts) matrix per
    frame on the host (the matching loop itself is sequential because
    greedy matching consumes ground truths in score order, but it only
    reads cached rows — no per-prediction jax dispatch).
    """
    if not gts:
        return float("nan")
    gt_by_frame: dict[int, list[Detection]] = collections.defaultdict(list)
    for f, d in gts:
        gt_by_frame[f].append(d)
    matched: dict[int, np.ndarray] = {
        f: np.zeros(len(v), bool) for f, v in gt_by_frame.items()}

    preds_sorted = sorted(preds, key=lambda fd: -fd[1].score)

    # one IoU matrix per frame: rows = that frame's predictions in
    # global (score-sorted) order, columns = its ground truths
    pred_rows: dict[int, list[int]] = collections.defaultdict(list)
    for i, (f, _) in enumerate(preds_sorted):
        pred_rows[f].append(i)
    iou_rows: dict[int, np.ndarray] = {}
    for f, idxs in pred_rows.items():
        cands = gt_by_frame.get(f)
        if not cands:
            continue
        mat = sph_iou_matrix_np(
            np.stack([preds_sorted[i][1].box for i in idxs]),
            np.stack([c.box for c in cands]))
        for row, i in enumerate(idxs):
            iou_rows[i] = mat[row]

    tp = np.zeros(len(preds_sorted))
    fp = np.zeros(len(preds_sorted))
    for i, (f, det) in enumerate(preds_sorted):
        ious = iou_rows.get(i)
        if ious is None:
            fp[i] = 1
            continue
        best = int(np.argmax(ious))
        if ious[best] >= iou_threshold and not matched[f][best]:
            matched[f][best] = True
            tp[i] = 1
        else:
            fp[i] = 1

    n_gt = len(gts)
    tp_cum = np.cumsum(tp)
    fp_cum = np.cumsum(fp)
    recall = tp_cum / n_gt
    precision = tp_cum / np.maximum(tp_cum + fp_cum, 1e-9)
    # all-point interpolation
    mrec = np.concatenate([[0.0], recall, [1.0]])
    mpre = np.concatenate([[0.0], precision, [0.0]])
    for i in range(len(mpre) - 2, -1, -1):
        mpre[i] = max(mpre[i], mpre[i + 1])
    idx = np.nonzero(mrec[1:] != mrec[:-1])[0]
    return float(np.sum((mrec[idx + 1] - mrec[idx]) * mpre[idx + 1]))


def action_top1(preds: list[tuple[int, Detection]],
                gts: list[tuple[int, Detection]],
                iou_threshold: float = 0.5) -> float:
    """Top-1 action accuracy over localised ground-truth instances.

    The action task's offline proxy (``repro.serving.tasks``): items
    are (frame_idx, detection) with ``category`` = action class.  A
    ground-truth instance counts as correct when some same-frame
    prediction overlaps it at ``iou_threshold`` SphIoU AND carries its
    action label — classification accuracy conditioned on
    localisation, the top-1 analogue of detection's Sph-mAP matching.
    """
    if not gts:
        return float("nan")
    preds_by_frame: dict[int, list[Detection]] = collections.defaultdict(list)
    for f, d in preds:
        preds_by_frame[f].append(d)
    correct = 0
    for f, gt in gts:
        cands = preds_by_frame.get(f)
        if not cands:
            continue
        ious = sph_iou_matrix_np(
            np.stack([c.box for c in cands]), gt.box[None])[:, 0]
        order = np.argsort([-c.score for c in cands], kind="stable")
        for i in order:
            if ious[i] >= iou_threshold:
                if cands[i].category == gt.category:
                    correct += 1
                break  # top-1: only the best-scored overlap counts
    return correct / len(gts)


def sph_map(predictions: list[tuple[int, Detection]],
            ground_truth: list[tuple[int, Detection]],
            iou_threshold: float = 0.5) -> float:
    """Sph-mAP over all categories present in the ground truth."""
    cats = sorted({d.category for _, d in ground_truth})
    aps = []
    for c in cats:
        ap = sph_ap([(f, d) for f, d in predictions if d.category == c],
                    [(f, d) for f, d in ground_truth if d.category == c],
                    iou_threshold)
        if not np.isnan(ap):
            aps.append(ap)
    return float(np.mean(aps)) if aps else 0.0

"""Offline latency profiles (paper section IV-B).

The paper profiles each pipeline stage offline on its testbed (Jetson
TX2 mobile + GTX 1080Ti edge).  Neither device exists here, so the
default profile is *calibrated to the paper's reported numbers*:

  * Table II model ladder with the input sizes 416/512/640/896/1280;
  * CubeMap-with-model-2 E2E ~1.4 s, CubeMap-with-model-4 ~4.4 s,
    CubeMap-with-model-5 ~8.2 s (Fig. 7 text points);
  * 17.9 Mbps uplink (T-Mobile 5G average used by the paper).

``measure_host_profile`` additionally profiles the *real* JAX detector
ladder on this container's CPU, which the end-to-end examples use; the
reproduction benchmark uses the paper-regime profile so latency budgets
(T_e4, T_c2..T_c4) live in the paper's range.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import accuracy as acc_mod
from repro.models import detector as det_mod


@dataclasses.dataclass(frozen=True)
class StageCosts:
    """Per-variant stage costs; sizes in pixels, times in seconds."""

    project_s_per_mpix: float  # gnomonic projection on the mobile device
    encode_s_per_mpix: float  # lossless PNG encode
    bytes_per_pixel: float  # compressed wire size
    infer_s: dict  # variant name -> model inference seconds


# FLOPs-derived inference times: mobile ~0.14 TFLOP/s effective,
# edge 1080Ti ~3.4 TFLOP/s effective (30% of 11.3 TFLOPs fp32).
_MOBILE_EFF = 0.14e12
_EDGE_EFF = 3.4e12


def paper_profile() -> StageCosts:
    infer = {}
    for i, cfg in enumerate(det_mod.PAPER_LADDER):
        flops = det_mod.flops_per_image(cfg)
        eff = _MOBILE_EFF if i == 0 else _EDGE_EFF
        infer[cfg.name] = float(flops / eff)
    return StageCosts(
        project_s_per_mpix=0.055,   # OpenCV remap on TX2-class CPU
        encode_s_per_mpix=0.080,    # PNG on TX2-class CPU
        bytes_per_pixel=1.5,        # lossless PNG of natural video
        infer_s=infer,
    )


def jpeg_profile(quality: int) -> StageCosts:
    """Lossy-compression variant for the Fig. 9a sensitivity study."""
    base = paper_profile()
    # JPEG is cheaper to encode and much smaller on the wire.
    ratio = {100: 0.55, 75: 0.25, 50: 0.18, 25: 0.12}.get(quality, 0.55)
    return dataclasses.replace(
        base,
        encode_s_per_mpix=0.035,
        bytes_per_pixel=3.0 * ratio,
    )


def make_ladder(n_categories: int = acc_mod.N_CATEGORIES,
                seed: int = 0,
                costs: StageCosts | None = None,
                quality_penalty: float = 1.0) -> list[acc_mod.ModelProfile]:
    """The paper's Table II as ModelProfiles (gav ladder + latencies).

    ``quality_penalty`` scales the gav (used by the JPEG sensitivity
    study: degraded inputs degrade every model's accuracy).
    """
    costs = costs or paper_profile()
    gavs = acc_mod.synthetic_gav_table(len(det_mod.PAPER_LADDER),
                                       n_categories, seed)
    out = []
    locations = ["device", "edge", "edge", "edge", "edge"]
    sizes_mb = [23, 202, 202, 271, 487]
    for i, cfg in enumerate(det_mod.PAPER_LADDER):
        out.append(acc_mod.ModelProfile(
            name=cfg.name,
            index=i + 1,
            input_size=cfg.input_size,
            location=locations[i],
            gav=gavs[i] * quality_penalty,
            infer_s=costs.infer_s[cfg.name],
            model_bytes=sizes_mb[i] * 1024 * 1024,
        ))
    return out


def measure_host_profile(reduced: bool = True, repeats: int = 3) -> dict:
    """Profile the real JAX detector ladder on this host (seconds/image).

    Used by the runnable examples; ``reduced`` shrinks input sizes so
    the measurement finishes quickly on CPU.
    """
    import jax
    import jax.numpy as jnp

    out = {}
    for cfg in det_mod.PAPER_LADDER[:3] if reduced else det_mod.PAPER_LADDER:
        size = cfg.input_size // 4 if reduced else cfg.input_size
        size = max(64, size // 32 * 32)
        c = dataclasses.replace(cfg, input_size=size)
        params = det_mod.init_params(jax.random.PRNGKey(0), c)
        img = jnp.zeros((1, size, size, 3), jnp.float32)
        fn = jax.jit(lambda p, x: det_mod.apply(p, x, c))
        fn(params, img)[0].block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn(params, img)[0].block_until_ready()
        out[cfg.name] = (time.perf_counter() - t0) / repeats
    return out
